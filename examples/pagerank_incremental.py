"""Incremental PageRank over an evolving graph (the paper's flagship
workload), served through `repro.stream.StreamSession`.

    PYTHONPATH=src python examples/pagerank_incremental.py [--vertices 4096]

A web graph evolves over several epochs.  The `pr.make_stream` adapter
(shared with `benchmarks/stream_latency.py`) emits one signed delta record
per epoch; the StreamSession micro-batches and coalesces them, and the
refresh scheduler picks incremental `update()` vs full `rerun()` per
micro-batch.  Each refresh starts from the prior converged state +
preserved MRBGraph, re-computes only affected vertices (with
change-propagation control), and auto-checkpoints.  Every refresh is
compared against from-scratch recomputation, and one more delta is
replayed through a restored session to prove fault recovery.
"""
import argparse
import shutil

import numpy as np
import jax.numpy as jnp

from repro.api import RunConfig, Session, StreamConfig, make_delta
from repro.apps import pagerank as pr
from repro.stream import StreamSession

ap = argparse.ArgumentParser()
ap.add_argument("--vertices", type=int, default=4096)
ap.add_argument("--epochs", type=int, default=3)
ap.add_argument("--backend", default=None, choices=(None, "xla", "pallas"))
ap.add_argument("--policy", default="paper",
                choices=("latency", "throughput", "paper"))
ap.add_argument("--ckpt-dir", default="/tmp/pr_session_ckpts")
args = ap.parse_args()

S, FRAC = args.vertices, 0.02
nbrs = pr.random_graph(S, 4, seed=1, p_edge=0.5)
shutil.rmtree(args.ckpt_dir, ignore_errors=True)

spec, struct, source = pr.make_stream(nbrs, frac=FRAC, seed=7,
                                      epochs=args.epochs)
config = RunConfig(max_iters=150, tol=1e-7, refresh_max_iters=80,
                   cpc_threshold=0.01, value_bytes=8, backend=args.backend,
                   checkpoint_dir=args.ckpt_dir, checkpoint_every=1)
rows_per_epoch = 2 * max(1, int(S * FRAC))   # '-' + '+' per mutated vertex
session = StreamSession(
    spec, struct, source=source, config=config,
    stream=StreamConfig(policy=args.policy,
                        max_batch_records=rows_per_epoch,
                        max_batch_delay=0.01))

with session:                                # initial converge + worker
    rep0 = session.report(include_result=False)
    print(f"job A_0 converged in {rep0.iters} iterations "
          f"(auto-checkpointed -> {args.ckpt_dir})")
    session.drain(timeout=600)

# align the (bounded) report and decision tails: epoch-0 reports carry no
# decision, and both lists keep only their newest entries
reports = [r for r in session.session.history if r.epoch >= 1]
decisions = session.scheduler.decisions[-len(reports):]
for rep, dec in zip(reports, decisions):
    affected = [l.n_affected_dks for l in rep.logs]
    print(f"job A_{rep.epoch}: mode={rep.mode} iters={rep.iters} "
          f"action={dec.action} (|Δ|/|D|={dec.delta_ratio:.3f}) "
          f"affected/iter={affected[:8]}{'...' if len(affected) > 8 else ''}")

want = pr.oracle(source.values["nbrs"], iters=300)
got = session.result["r"]
rel = (np.abs(got - want) / np.maximum(want, 1e-9)).mean()
m = session.metrics.snapshot()
print(f"mean rel err vs recompute: {rel:.2e}")
print(f"stream: {m['rows_in']} rows in {m['batches']} micro-batches, "
      f"{m['updates_per_sec']:.0f} rows/s sustained, "
      f"refresh p50={m['refresh_p50_ms']:.1f}ms "
      f"p95={m['refresh_p95_ms']:.1f}ms")

# fault recovery: lose the serving node, restore the auto-checkpoint,
# replay the next delta from the (replayable) stream — same answer
restored = Session.restore(spec, args.ckpt_dir, config)
print(f"restored session at epoch {restored.epoch}")
rid, vals, sign = source.stream.delta()      # one more graph edit
report = restored.update(make_delta(rid, {"nbrs": jnp.asarray(vals["nbrs"])},
                                    sign))
want = pr.oracle(source.values["nbrs"], iters=300)
rel = (np.abs(restored.result["r"] - want) / np.maximum(want, 1e-9)).mean()
print(f"post-recovery refresh: mode={report.mode} "
      f"mean rel err {rel:.2e} ✓")
