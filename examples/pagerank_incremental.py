"""End-to-end incremental PageRank over an evolving graph (the paper's
flagship workload), driven entirely through the `repro.api` Session.

    PYTHONPATH=src python examples/pagerank_incremental.py [--vertices 4096]

A web graph evolves over several epochs; each `update` starts from the
prior converged state + preserved MRBGraph, re-computes only affected
vertices (with change-propagation control), and auto-checkpoints per epoch.
Every refresh is compared against from-scratch recomputation, and the last
epoch is replayed from a restored session to prove fault recovery.
"""
import argparse
import shutil

import numpy as np
import jax.numpy as jnp

from repro.api import RunConfig, Session, make_delta
from repro.apps import pagerank as pr
from repro.data import DeltaStream

ap = argparse.ArgumentParser()
ap.add_argument("--vertices", type=int, default=4096)
ap.add_argument("--epochs", type=int, default=3)
ap.add_argument("--backend", default=None, choices=(None, "xla", "pallas"))
ap.add_argument("--ckpt-dir", default="/tmp/pr_session_ckpts")
args = ap.parse_args()

S, F = args.vertices, 4
nbrs = pr.random_graph(S, F, seed=1, p_edge=0.5)
shutil.rmtree(args.ckpt_dir, ignore_errors=True)

spec, struct = pr.make_job(nbrs)
config = RunConfig(max_iters=150, tol=1e-7, refresh_max_iters=80,
                   cpc_threshold=0.01, value_bytes=8, backend=args.backend,
                   checkpoint_dir=args.ckpt_dir, checkpoint_every=1)
session = Session(spec, config)

report = session.run(struct)
print(f"job A_0 converged in {report.iters} iterations "
      f"(auto-checkpointed -> {args.ckpt_dir})")

stream = DeltaStream({"nbrs": nbrs}, frac=0.02, seed=7,
                     mutator=lambda rng, rows, old: {
                         "nbrs": np.where(rng.random(old["nbrs"].shape) < 0.5,
                                          rng.integers(0, S,
                                                       old["nbrs"].shape),
                                          -1).astype(np.int32)})

delta = None
for epoch in range(1, args.epochs + 1):
    rid, vals, sign = stream.delta()
    delta = make_delta(rid, {"nbrs": jnp.asarray(vals["nbrs"])}, sign)
    report = session.update(delta)
    affected = [l.n_affected_dks for l in report.logs]
    print(f"job A_{epoch}: mode={report.mode} iters={report.iters} "
          f"affected/iter={affected[:8]}{'...' if len(affected) > 8 else ''}")

    want = pr.oracle(stream.values["nbrs"], iters=300)
    got = session.result["r"]
    rel = (np.abs(got - want) / np.maximum(want, 1e-9)).mean()
    print(f"         mean rel err vs recompute: {rel:.2e}")

# fault recovery: lose the session, restore the auto-checkpoint of the
# previous epoch, replay the last delta — same converged answer
restored = Session.restore(spec, args.ckpt_dir, config)
print(f"restored session at epoch {restored.epoch}")
rid, vals, sign = stream.delta()
delta = make_delta(rid, {"nbrs": jnp.asarray(vals["nbrs"])}, sign)
report = restored.update(delta)
want = pr.oracle(stream.values["nbrs"], iters=300)
rel = (np.abs(restored.result["r"] - want) / np.maximum(want, 1e-9)).mean()
print(f"post-recovery refresh: mode={report.mode} "
      f"mean rel err {rel:.2e} ✓")
