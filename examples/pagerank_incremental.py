"""End-to-end incremental PageRank over an evolving graph (the paper's
flagship workload).

    PYTHONPATH=src python examples/pagerank_incremental.py

A web graph evolves over 3 epochs; each refresh job starts from the prior
converged state + preserved MRBGraph, re-computes only affected vertices
(with change-propagation control), and checkpoints per epoch for fault
tolerance.  Compares every refresh against from-scratch recomputation.
"""
import numpy as np
import jax.numpy as jnp

from repro.apps import pagerank as pr
from repro.core.ft import checkpoint_job, restore_job
from repro.core.incr_iter import IncrIterJob
from repro.core.incremental import make_delta
from repro.data import DeltaStream

S, F = 4096, 4
nbrs = pr.random_graph(S, F, seed=1, p_edge=0.5)
spec = pr.make_spec(S)

job = IncrIterJob(spec, pr.make_struct(nbrs), value_bytes=8)
st, hist = job.initial_converge(max_iters=150, tol=1e-7)
print(f"job A_0 converged in {hist['iters']} iterations")

stream = DeltaStream({"nbrs": nbrs}, frac=0.02, seed=7,
                     mutator=lambda rng, rows, old: {
                         "nbrs": np.where(rng.random(old["nbrs"].shape) < 0.5,
                                          rng.integers(0, S,
                                                       old["nbrs"].shape),
                                          -1).astype(np.int32)})

for epoch in range(1, 4):
    rid, vals, sign = stream.delta()
    delta = make_delta(rid, rid, {"nbrs": jnp.asarray(vals["nbrs"])}, sign)
    st, h = job.refresh(delta, max_iters=80, tol=1e-7, cpc_threshold=0.01)
    affected = [l.n_affected_dks for l in h["logs"]]
    print(f"job A_{epoch}: mode={h['mode']} iters={h['iters']} "
          f"affected/iter={affected[:8]}{'...' if len(affected) > 8 else ''}")

    want = pr.oracle(stream.values["nbrs"], iters=300)
    got = np.asarray(st.values["r"])
    rel = (np.abs(got - want) / np.maximum(want, 1e-9)).mean()
    print(f"         mean rel err vs recompute: {rel:.2e}")

    ck = checkpoint_job(job, "/tmp/pr_ckpts", epoch)
    print(f"         checkpointed -> {ck}")

# fault recovery: lose the job object, restore, keep refreshing
job = restore_job(spec, "/tmp/pr_ckpts")
rid, vals, sign = stream.delta()
delta = make_delta(rid, rid, {"nbrs": jnp.asarray(vals["nbrs"])}, sign)
st, h = job.refresh(delta, max_iters=80, tol=1e-7, cpc_threshold=0.01)
want = pr.oracle(stream.values["nbrs"], iters=300)
rel = (np.abs(np.asarray(st.values["r"]) - want) /
       np.maximum(want, 1e-9)).mean()
print(f"post-recovery refresh: mode={h['mode']} mean rel err {rel:.2e} ✓")
