"""Incremental equi-join through the `repro.dql` query algebra.

    PYTHONPATH=src python examples/incremental_join.py [--users 1024]

Build the plan once — ``scan(spend) ⋈ scan(visits)`` — compile it into a
Query (just another Session kind: RunReport, checkpointing and the
streaming scheduler all apply), run it, then refresh it with signed
deltas on either side.  The join stage keeps its own MRBG slice, so the
refresh is |Δ|-proportional; ``rerun()`` is the full-recompute
alternative past the update-vs-rerun crossover (paper Fig. 8; see
``benchmarks/query_latency.py``).
"""
import argparse

import numpy as np

from repro import dql
from repro.api import RunConfig, make_delta
from repro.dql import workloads as wl

ap = argparse.ArgumentParser()
ap.add_argument("--users", type=int, default=1024)
ap.add_argument("--backend", default=None, choices=(None, "xla", "pallas"))
args = ap.parse_args()

USERS = args.users
rng = np.random.default_rng(0)

# ---- declare once: spend ⋈ visits on the user id ----
plan = dql.scan("spend").join(dql.scan("visits"), num_keys=USERS,
                              name="user_join")
q = plan.compile(RunConfig(backend=args.backend, value_bytes=4))
print(q.explain())

datas = wl.join_data(USERS, seed=3)
q.run(datas)
vals, valid = q.relation()
print(f"initial join: {int(valid.sum())}/{USERS} users on both sides")

# ---- delta on one side only: '-' old row, '+' new value ----
rows = rng.choice(USERS, size=max(1, USERS // 100), replace=False)
rows = rows.astype(np.int32)
old = np.asarray(datas["spend"].values["amt"])[rows]
new = rng.uniform(1, 100, len(rows)).astype(np.float32)
buf = np.empty(2 * len(rows), np.float32)
buf[0::2], buf[1::2] = old, new
delta = make_delta(np.repeat(rows, 2), {"amt": buf},
                   np.tile(np.array([-1, 1], np.int8), len(rows)))
report = q.update({"spend": delta})
print(report.summary())

# ---- verify against the dense oracle ----
sp = np.asarray(datas["spend"].values["amt"]).copy()
sp[rows] = new
vals, valid = q.relation()
want = np.asarray(datas["spend"].valid) & np.asarray(datas["visits"].valid)
assert np.array_equal(valid, want)
assert np.allclose(np.where(valid, vals["amt"], 0), np.where(want, sp, 0))
assert np.allclose(np.where(valid, vals["n"], 0),
                   np.where(want, np.asarray(datas["visits"].values["n"]), 0))
print("incremental join refresh == recompute ✓")

# ---- past the crossover, rerun() recomputes from the input mirrors ----
q.rerun()
vals2, valid2 = q.relation()
assert np.array_equal(valid2, valid)
assert np.allclose(np.where(valid2, vals2["amt"], 0),
                   np.where(valid, vals["amt"], 0))
print("rerun() == update() ✓")
