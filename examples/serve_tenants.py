"""A three-class tenant fleet through `repro.serve.ServeTier`.

    PYTHONPATH=src python examples/serve_tenants.py [--tenants 12]

One latency-class tenant (interactive, p95 target), one throughput-class
tenant, and a herd of best-effort tenants share one serving tier.  The
demo walks the tier's three mechanisms:

1. **Batched cross-tenant refresh** — the best-effort herd's updates
   land in one padded multi-tenant kernel launch (watch
   ``batched_launches`` vs ``batched_refreshes``), bit-for-bit identical
   to refreshing each tenant alone.
2. **Admission control** — a burst at the tier beyond its backlog budget
   sheds best-effort submits (``submit()`` returns False) while the
   latency tenant keeps being admitted.
3. **Cold-store spill** — under a deliberately tiny store budget, idle
   tenants' MRBG stores spill to disk and transparently reload on their
   next delta.
"""
import argparse
import tempfile

import numpy as np

from repro.serve import AdmissionController, ServeTier, SLOClass
from repro.serve import loadgen

ap = argparse.ArgumentParser()
ap.add_argument("--tenants", type=int, default=12)
ap.add_argument("--backend", default=None, choices=(None, "xla", "pallas"))
args = ap.parse_args()


def slo_of(i):
    if i == 0:
        return SLOClass.latency(target_p95_ms=250.0)
    if i == 1:
        return SLOClass.throughput()
    return SLOClass.best_effort()


spill_dir = tempfile.mkdtemp(prefix="serve_spill_")
tier = ServeTier(spill_dir=spill_dir,
                 admission=AdmissionController(max_backlog_seconds=0.25))
mirrors = loadgen.make_fleet(tier, args.tenants, backend=args.backend,
                             seed=0, slo_of=slo_of)
names = list(mirrors)
print(f"fleet: {names[0]}=latency {names[1]}=throughput "
      f"{len(names) - 2}x best-effort")

# -- 1. batched cross-tenant refresh ----------------------------------------
rng = np.random.default_rng(1)
for name in names:
    loadgen.submit_update(tier, mirrors, name, rng, 64)
tier.drain()                       # synchronous sweep: everything due at once
stats = tier.stats()
print(f"batched: {stats['batched_refreshes']} tenant refreshes in "
      f"{stats['batched_launches']} kernel launch(es)")

# -- 2. admission control under a burst --------------------------------------
# a burst budget of ~2ms of predicted refresh work: queued best-effort
# rows overflow it almost immediately, interactive rows never count.
# Two warm rounds first — admission prices tenants with no clean cost
# sample yet at zero, and the compile-tainted first refreshes don't count
for _ in range(2):
    for name in names:
        loadgen.submit_update(tier, mirrors, name, rng, 64,
                              rows_per_update=1 if name == names[0] else 4)
    tier.drain()
tier.admission.max_backlog_seconds = 0.002
tier.handle(names[0]).reset_window()
with tier:                                  # scheduler thread on
    admitted = shed = 0
    for _ in range(60):
        for name in names[2:]:              # hammer the best-effort herd
            if loadgen.submit_update(tier, mirrors, name, rng, 64,
                                     rows_per_update=4):
                admitted += 1
            else:
                shed += 1
        # the interactive tenant stays admitted throughout
        assert loadgen.submit_update(tier, mirrors, names[0], rng, 64)
    tier.drain()
lat_p95 = tier.handle(names[0]).snapshot()["latency_p95_ms"]
print(f"burst: {admitted} best-effort updates admitted, {shed} shed; "
      f"latency tenant never shed (burst-window p95 {lat_p95:.1f}ms)")

# -- 3. cold-store spill under budget pressure -------------------------------
tier.admission.max_backlog_seconds = 0.25   # back to a sane burst budget
tier.store_budget_bytes = 1                 # everything is over budget now
tier._enforce_budget()
spilled = [n for n, h in tier.handles.items() if h.spilled]
print(f"spill: {len(spilled)}/{len(names)} tenants spilled to {spill_dir} "
      f"(resident store bytes: {tier.total_store_bytes()})")
tier.store_budget_bytes = None

for name in spilled:                        # next delta reloads, bit-for-bit
    loadgen.submit_update(tier, mirrors, name, rng, 64)
tier.drain()
assert not any(h.spilled for h in tier.handles.values())
print("spill: every spilled tenant reloaded on its next delta; "
      f"stats: {tier.stats()['spill']}")
