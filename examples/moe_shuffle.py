"""The paper's technique inside the LM: MoE token dispatch IS the MapReduce
shuffle.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/moe_shuffle.py

Runs the same Llama-4-Scout-family MoE layer two ways on an 8-device mesh:
  * ``gather``: GSPMD scatter/gather dispatch (baseline),
  * ``a2a``: the shard_map shuffle — tokens hash-partitioned by K2 = expert
    id, ONE all_to_all each way, segment-reduce combine (identical to
    repro.core.distributed's engine),
and verifies bit-level forward agreement + gradient agreement.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

import repro.configs as C
from repro.models import blocks as B, meshctx
from repro.models.common import tree_init
from repro.models.config import smoke_config

if len(jax.devices()) < 8:
    raise SystemExit("run with XLA_FLAGS=--xla_force_host_platform_device_count=8")

cfg = smoke_config(C.get("llama4_scout_17b_a16e"))
cfg = cfg.replace(
    sharding=dataclasses.replace(cfg.sharding, batch=("data",)),
    moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
meshctx.set_mesh(mesh)

params = tree_init(B.plan_moe(cfg), jax.random.PRNGKey(0), jnp.float32)
x = jnp.asarray(np.random.default_rng(0).normal(0, 0.5, (4, 16, cfg.d_model)),
                jnp.float32)

with mesh:
    y_gather = B.apply_moe_gather(cfg, params, x)
    y_a2a = jax.jit(lambda p, xx: B.apply_moe_a2a(cfg, p, xx, mesh))(params, x)
    g_gather = jax.grad(lambda p: B.apply_moe_gather(cfg, p, x).sum())(params)
    g_a2a = jax.jit(jax.grad(
        lambda p: B.apply_moe_a2a(cfg, p, x, mesh).sum()))(params)

print("forward max |Δ|:",
      float(jnp.abs(y_gather - y_a2a).max()))
for k in g_gather:
    d = float(jnp.abs(g_gather[k] - g_a2a[k]).max())
    print(f"grad {k:12s} max |Δ| = {d:.3e}")
print("\nThe a2a path is the production EP dispatch: on the 256-chip pod "
      "DeepSeek-V3's 256 experts live one-per-chip and dispatch is a single "
      "256-way all_to_all — the paper's shuffle at pod scale.")
