"""End-to-end driver: train a ~100M-param qwen3-family model for a few
hundred steps on CPU with the full production stack — deterministic sharded
data pipeline, AdamW, rolling checkpoints, straggler watchdog — then kill it
and prove restart reproduces the trajectory.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import shutil

import repro.configs as C
from repro.launch.train import preset_config, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300,
                help="a few hundred steps ~ hours on 1 CPU core; the same "
                     "driver runs the production mesh on a pod")
ap.add_argument("--arch", default="qwen3-1.7b")
ap.add_argument("--ckpt-every", type=int, default=10)
args = ap.parse_args()

cfg = preset_config(C.get(args.arch), "100m")
n_params = sum(
    int(__import__("numpy").prod(s.shape))
    for s in __import__("jax").tree.leaves(
        __import__("repro.models.lm", fromlist=["plan_model"])
        .plan_model(cfg),
        is_leaf=lambda x: hasattr(x, "axes")))
print(f"training {cfg.name}-100m ({n_params/1e6:.0f}M params) "
      f"for {args.steps} steps")

out = "/tmp/repro_train_example"
shutil.rmtree(out, ignore_errors=True)

# train halfway, then "crash"
try:
    train(cfg, steps=args.steps, global_batch=8, seq_len=256, out=out,
          ckpt_every=args.ckpt_every, fail_at=args.steps // 2, log_every=20)
except RuntimeError as e:
    print(f"!! {e} — restarting from the latest checkpoint")

# resume to completion
losses = train(cfg, steps=args.steps, global_batch=8, seq_len=256, out=out,
               ckpt_every=args.ckpt_every, log_every=20)
print(f"final loss {losses[-1]:.4f} (from {losses[0]:.4f} at resume)")
