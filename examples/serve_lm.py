"""Batched serving example: prefill + decode with preserved per-request
state — the LM-side instance of the paper's incremental principle (decode =
|Δ|=1 refresh against the preserved KV/recurrent state).

    PYTHONPATH=src python examples/serve_lm.py [--arch recurrentgemma-2b]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

import repro.configs as C
from repro.launch.steps import make_serve_step
from repro.models import lm
from repro.models.config import smoke_config

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="recurrentgemma-2b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=16)
ap.add_argument("--gen", type=int, default=24)
args = ap.parse_args()

cfg = smoke_config(C.get(args.arch))
params = lm.init_params(cfg, jax.random.PRNGKey(0))
serve = jax.jit(make_serve_step(cfg))

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, cfg.vocab,
                                   (args.batch, args.prompt_len)), jnp.int32)

# prefill by stepping (a production server would batch-prefill; the cache
# discipline is identical)
caches = lm.init_caches(cfg, args.batch, args.prompt_len + args.gen + 1)
logits = None
for t in range(args.prompt_len):
    logits, caches = serve(params, caches, prompts[:, t:t + 1])

# greedy decode
out = []
tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
for _ in range(args.gen):
    out.append(np.asarray(tok)[:, 0])
    logits, caches = serve(params, caches, tok)
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)

gen = np.stack(out, axis=1)
print(f"{cfg.name} (reduced): decoded {args.gen} tokens for "
      f"{args.batch} requests")
print(gen)
print("state preserved per request:",
      jax.tree.reduce(lambda a, b: a + b,
                      jax.tree.map(lambda x: x.size, caches)), "elements")
