"""Quickstart: incremental WordCount in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Runs a MapReduce WordCount, preserves the fine-grain MRBGraph, applies a
signed delta (delete one doc, edit another, add two), and refreshes the
counts incrementally — work proportional to the delta, not the corpus.
"""
import numpy as np
import jax.numpy as jnp

from repro.apps import wordcount as wc
from repro.core.incremental import IncrementalJob, make_delta

VOCAB, L = 100, 12
rng = np.random.default_rng(0)
docs = rng.integers(0, VOCAB, size=(500, L)).astype(np.int32)

# ---- initial job: map -> shuffle -> reduce, preserving the MRBGraph ----
job = IncrementalJob(wc.make_spec(VOCAB), value_bytes=4)
view = job.initial_run(wc.make_input(np.arange(500), docs))
print("initial top word:", int(np.argmax(view.as_dict()["c"])))

# ---- delta: '-' deletes, '-'+'+' updates, '+' inserts ----
edit = rng.integers(0, VOCAB, (1, L)).astype(np.int32)
new = rng.integers(0, VOCAB, (2, L)).astype(np.int32)
rid = np.array([7, 42, 42, 500, 501], np.int32)
sign = np.array([-1, -1, 1, 1, 1], np.int8)
vals = np.concatenate([docs[[7]], docs[[42]], edit, new])
job.incremental_run(make_delta(rid, rid, {"w": jnp.asarray(vals)}, sign))

# ---- verify against recomputation ----
docs2 = docs.copy()
docs2[42] = edit[0]
valid = np.ones(502, bool)
valid[7] = False
want = wc.oracle(np.concatenate([docs2, new]), VOCAB, valid)
got = job.view.as_dict()["c"]
assert np.allclose(got, want)
print("incremental refresh == recompute ✓")
print("MRBG-Store:", job.refresh_stats())
