"""Quickstart: incremental WordCount through the `repro.api` Session.

    PYTHONPATH=src python examples/quickstart.py [--docs 500]

Declare the job once, `run` it, then `update` with a signed delta
(delete one doc, edit another, add two) — the engine refreshes the counts
with work proportional to the delta, not the corpus, and the same Session
surface would drive iterative, incremental-iterative, or distributed jobs.
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro.api import RunConfig, Session, make_delta
from repro.apps import wordcount as wc

ap = argparse.ArgumentParser()
ap.add_argument("--docs", type=int, default=500)
ap.add_argument("--backend", default=None, choices=(None, "xla", "pallas"))
args = ap.parse_args()

VOCAB, L, N = 100, 12, args.docs
rng = np.random.default_rng(0)
docs = rng.integers(0, VOCAB, size=(N, L)).astype(np.int32)

# ---- declare once; run the initial map -> shuffle -> reduce ----
spec, data = wc.make_job(docs, VOCAB)
session = Session(spec, RunConfig(onestep_path="mrbg", value_bytes=4,
                                  backend=args.backend))
session.run(data)
print("initial top word:", int(np.argmax(session.result["c"])))

# ---- delta: '-' deletes, '-'+'+' updates, '+' inserts ----
edit = rng.integers(0, VOCAB, (1, L)).astype(np.int32)
new = rng.integers(0, VOCAB, (2, L)).astype(np.int32)
rid = np.array([7, 42, 42, N, N + 1], np.int32)
sign = np.array([-1, -1, 1, 1, 1], np.int8)
vals = np.concatenate([docs[[7]], docs[[42]], edit, new])
report = session.update(make_delta(rid, {"w": jnp.asarray(vals)}, sign))

# ---- verify against recomputation ----
docs2 = docs.copy()
docs2[42] = edit[0]
valid = np.ones(N + 2, bool)
valid[7] = False
want = wc.oracle(np.concatenate([docs2, new]), VOCAB, valid)
assert np.allclose(session.result["c"], want)
print("incremental refresh == recompute ✓")
print(report.summary())
