"""Fig. 8: normalized runtime of refresh after a 10% delta —
plainMR recomp / iterMR recomp / i²MR for PageRank, SSSP, Kmeans, GIM-V.

Methodology notes (CPU container vs the paper's 32-node EC2 cluster):
  * all engines are warmed first (XLA compile excluded — the analogue of
    i²MapReduce keeping jobs alive across iterations; Hadoop job-startup
    cost is likewise not what Fig. 8 measures);
  * all three modes recompute on the *updated* structure from the *previous
    converged state* where applicable (paper §8.1.5);
  * besides wall time we report **work** = Σ re-executed Reduce instances,
    the scale-free signal of fine-grain incrementality (wall-clock speedups
    at 8k-vertex CPU scale under-state the cluster-scale win because each
    full pass is a single fused vector op here, while the incremental path
    pays per-iteration host/device hops).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, graph_update_delta, timed
from repro.core.incr_iter import IncrIterJob
from repro.core.incremental import make_delta
from repro.core.iterative import State, run_iterative, run_plain


def _bench(name, spec, struct_fn, delta_fn, tol, cpc, value_bytes=8):
    # ---- warm every jit cache with a throwaway job ----
    warm = IncrIterJob(spec, struct_fn(), value_bytes=value_bytes)
    warm.initial_converge(max_iters=200, tol=tol)
    warm.refresh(delta_fn(), max_iters=200, tol=tol, cpc_threshold=cpc)

    # ---- measured job ----
    job = IncrIterJob(spec, struct_fn(), value_bytes=value_bytes)
    st0, _ = job.initial_converge(max_iters=200, tol=tol)
    st0_vals = {k: jnp.asarray(np.array(v)) for k, v in st0.values.items()}

    _, t_i2 = timed(lambda: job.refresh(delta_fn(), max_iters=200, tol=tol,
                                        cpc_threshold=cpc))
    hist = job.logs
    work_i2 = sum(l.n_affected_dks for l in hist)
    mode = "i2" if all(l.mrbg_on for l in hist) else "fallback"

    struct2 = job._struct_kv()     # structure after the delta
    (_, h_plain), t_plain = timed(lambda: run_plain(
        spec, struct2, None, max_iters=200, tol=tol))
    (_, h_iter), t_iter = timed(lambda: run_iterative(
        spec, struct2, State(st0_vals, st0.valid), max_iters=200, tol=tol))
    work_plain = h_plain["iters"] * spec.num_state
    work_iter = h_iter["iters"] * spec.num_state

    emit(f"fig8.{name}.plainMR_s", t_plain * 1e6,
         f"norm=1.0,reduce_instances={work_plain}")
    emit(f"fig8.{name}.iterMR_s", t_iter * 1e6,
         f"norm={t_iter/t_plain:.3f},reduce_instances={work_iter}")
    emit(f"fig8.{name}.i2MR_s", t_i2 * 1e6,
         f"norm={t_i2/t_plain:.3f},reduce_instances={work_i2},"
         f"work_saving={work_plain/max(work_i2,1):.1f}x,mode={mode}")


def run():
    # ---- PageRank (one-to-one) ----
    from repro.apps import pagerank as pr
    S, F = 8192, 4
    nbrs = pr.random_graph(S, F, seed=3, p_edge=0.5)
    _bench("pagerank", pr.make_spec(S), lambda: pr.make_struct(nbrs),
           lambda: graph_update_delta(nbrs, 0.10)[0], tol=1e-6, cpc=0.02)

    # ---- SSSP (one-to-one, min-reduce) ----
    from repro.apps import sssp
    nbrs2, w = sssp.random_weighted_graph(4096, 4, seed=2, p_edge=0.4)

    def sssp_delta():
        rng = np.random.default_rng(9)
        k = 409
        rows = rng.choice(4096, k, replace=False)
        new_rows = nbrs2[rows].copy()
        new_rows[rng.random(new_rows.shape) < 0.3] = -1
        dk = np.repeat(rows.astype(np.int32) + 1, 2)
        sg = np.tile(np.array([-1, 1], np.int8), k)
        nb = np.empty((2 * k, 4), np.int32)
        nb[0::2] = nbrs2[rows]
        nb[1::2] = new_rows
        wb = np.repeat(w[rows], 2, axis=0)
        return make_delta(dk, {"nbrs": jnp.asarray(nb),
                               "w": jnp.asarray(wb)}, sg)

    _bench("sssp", sssp.make_spec(4096),
           lambda: sssp.make_struct(nbrs2, w, src=0), sssp_delta,
           tol=1e-6, cpc=0.0)

    # ---- Kmeans (all-to-one; auto falls back to iterMR, paper Fig. 8) ----
    from repro.apps import kmeans
    rng = np.random.default_rng(0)
    kcl, dim = 8, 16
    centers = rng.normal(0, 5, (kcl, dim))
    pts = np.concatenate([rng.normal(c, 0.4, (2000, dim)) for c in centers]
                         ).astype(np.float32)
    init = pts[rng.choice(len(pts), kcl, replace=False)]

    def kmeans_delta():
        rng2 = np.random.default_rng(4)
        rows = rng2.choice(len(pts), len(pts) // 10, replace=False)
        new_p = rng2.normal(centers[1], 0.4,
                            (rows.size, dim)).astype(np.float32)
        dk = np.repeat(rows.astype(np.int32), 2)
        sg = np.tile(np.array([-1, 1], np.int8), rows.size)
        buf = np.empty((2 * rows.size, dim), np.float32)
        buf[0::2] = pts[rows]
        buf[1::2] = new_p
        return make_delta(dk, {"p": jnp.asarray(buf)}, sg)

    _bench("kmeans", kmeans.make_spec(kcl, dim, init),
           lambda: kmeans.make_struct(pts), kmeans_delta, tol=1e-5, cpc=0.0,
           value_bytes=4 * (dim + 1))

    # ---- GIM-V (many-to-one) ----
    from repro.apps import gimv
    nb_, bs = 16, 32
    blocks = gimv.random_blocks(nb_, bs, seed=4, density=0.3)
    bvec = np.ones((nb_, bs), np.float32)

    def gimv_delta():
        rids = np.arange(0, nb_ * nb_, 10, dtype=np.int32)
        newb = blocks[rids] * 0.5
        dk = np.repeat(rids, 2)
        sg = np.tile(np.array([-1, 1], np.int8), rids.size)
        mb = np.empty((2 * rids.size, bs, bs), np.float32)
        mb[0::2] = blocks[rids]
        mb[1::2] = newb
        return make_delta(dk, {"m": jnp.asarray(mb)}, sg)

    _bench("gimv", gimv.make_spec(nb_, bs, bvec),
           lambda: gimv.make_struct(blocks, nb_), gimv_delta, tol=1e-8,
           cpc=0.0, value_bytes=4 * bs)
