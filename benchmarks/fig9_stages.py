"""Fig. 9: per-stage time (map / shuffle-sort / reduce / merge) for PageRank
under iterMR recompute vs i²MR incremental."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, graph_update_delta, pagerank_workload
from repro.core.incr_iter import IncrIterJob, _delta_map_iter
from repro.core.iterative import State
from repro.core.kvstore import KV, segment_reduce, sort_edges


def run():
    spec, struct, nbrs = pagerank_workload(s=8192, f=4)
    job = IncrIterJob(spec, struct, value_bytes=8)
    st0, _ = job.initial_converge(max_iters=100, tol=1e-6)

    # ---- full-pass stage timings (iterMR) ----
    dks = spec.project(struct.keys)
    dv = {"r": jnp.take(st0.values["r"], dks)}
    sign = jnp.ones(struct.capacity, jnp.int8)

    map_jit = jax.jit(lambda s_, d_: spec.map_fn(s_, d_, sign))
    edges = map_jit(struct, dv)
    jax.block_until_ready(edges)
    t0 = time.perf_counter()
    for _ in range(5):
        edges = map_jit(struct, dv)
        jax.block_until_ready(edges)
    t_map = (time.perf_counter() - t0) / 5

    sort_jit = jax.jit(sort_edges)
    s_edges = sort_jit(edges)
    jax.block_until_ready(s_edges)
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(sort_jit(edges))
    t_sort = (time.perf_counter() - t0) / 5

    red_jit = jax.jit(lambda e: segment_reduce(
        spec.reducer, e.k2, e.v2, e.valid, spec.num_state))
    jax.block_until_ready(red_jit(s_edges))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(red_jit(s_edges))
    t_reduce = (time.perf_counter() - t0) / 5

    emit("fig9.iterMR.map_s", t_map * 1e6, "")
    emit("fig9.iterMR.shuffle_sort_s", t_sort * 1e6, "")
    emit("fig9.iterMR.reduce_s", t_reduce * 1e6, "")

    # ---- incremental stage timings (i2MR, 10% delta) ----
    delta, _ = graph_update_delta(nbrs, 0.10)
    sel_dks = jax.jit(spec.project)(delta.keys)
    dm = lambda: jax.block_until_ready(_delta_map_iter(
        (spec.map_fn, spec.replicate_state), KV(delta.keys, delta.values,
                                                delta.valid),
        delta.record_ids, delta.sign, sel_dks, st0.values))
    dm()
    t0 = time.perf_counter()
    for _ in range(5):
        dm()
    t_dmap = (time.perf_counter() - t0) / 5
    emit("fig9.i2MR.delta_map_plus_sort_s", t_dmap * 1e6,
         f"vs full map+sort {(t_map + t_sort) * 1e6:.0f}us "
         f"({(t_map + t_sort) / t_dmap:.1f}x less work)")

    # reduce+merge incl. MRBG-Store access (the paper's extra i2 cost)
    job.store.reset_stats()
    t0 = time.perf_counter()
    job.refresh(delta, max_iters=1, tol=0.0, cpc_threshold=0.01)
    t_incr_it1 = time.perf_counter() - t0
    emit("fig9.i2MR.merge_reduce_s", t_incr_it1 * 1e6,
         f"reads={job.store.stats.n_reads},bytes={job.store.stats.bytes_read}")
