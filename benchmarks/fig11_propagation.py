"""Fig. 11: per-iteration propagated kv-pairs and runtime, with and without
change propagation control (1% delta, as in the paper)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, graph_update_delta, pagerank_workload
from repro.core.incr_iter import IncrIterJob


def run():
    for label, ft, pdelta in (("noCPC", 0.0, 1.01), ("FT0.01", 0.01, 0.5),
                              ("FT0.05", 0.05, 0.5)):
        spec, struct, nbrs = pagerank_workload(s=8192, f=4)
        job = IncrIterJob(spec, struct, value_bytes=8,
                          pdelta_threshold=pdelta)
        job.initial_converge(max_iters=100, tol=1e-6)
        delta, _ = graph_update_delta(nbrs, 0.01)
        st, hist = job.refresh(delta, max_iters=12, tol=1e-7,
                               cpc_threshold=ft)
        prop = [l.n_affected_dks for l in hist["logs"]]
        times = [round(l.seconds * 1e3) for l in hist["logs"]]
        emit(f"fig11.{label}.total_s",
             sum(l.seconds for l in hist["logs"]) * 1e6,
             f"prop={prop},ms_per_iter={times}")
