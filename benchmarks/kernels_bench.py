"""Kernel micro-benchmarks: interpret-mode wall time (correctness-scale) +
analytic TPU-v5e roofline estimates per kernel (the real perf claim)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.launch.mesh import HBM_BW, PEAK_FLOPS


def run():
    rng = np.random.default_rng(0)

    # segment_reduce: one [R,K]x[R,D] matmul per tile
    from repro.kernels.segment_reduce import segment_reduce_mxu
    n, d, k = 4096, 64, 1024
    seg = jnp.asarray(np.sort(rng.integers(0, k, n)), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    out, dt = timed(lambda: segment_reduce_mxu(seg, vals, k, rows=512,
                                               kblk=512).block_until_ready())
    flops = 2 * n * 512 * d * (k // 512)
    tpu_s = max(flops / PEAK_FLOPS, (n * d * 4 + k * d * 4) / HBM_BW)
    emit("kernel.segment_reduce.interp_s", dt * 1e6,
         f"tpu_est={tpu_s*1e6:.1f}us,flops={flops:.2e}")

    # flash attention
    from repro.kernels.flash_attention import flash_attention
    b, h, s, hd = 1, 4, 512, 64
    q = jnp.asarray(rng.normal(0, 1, (b, h, s, hd)), jnp.float32)
    kk = jnp.asarray(rng.normal(0, 1, (b, h, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, h, s, hd)), jnp.float32)
    out, dt = timed(lambda: flash_attention(q, kk, v, q_blk=128,
                                            kv_blk=128).block_until_ready())
    flops = 4 * b * h * s * s * hd
    tpu_s = max(flops / PEAK_FLOPS, 3 * b * h * s * hd * 4 / HBM_BW)
    emit("kernel.flash_attention.interp_s", dt * 1e6,
         f"tpu_est={tpu_s*1e6:.1f}us,flops={flops:.2e}")

    # bitonic sort
    from repro.kernels.sort_u32 import sort_kv32
    n = 4096
    keys = jnp.asarray(rng.integers(0, 2**30, n), jnp.uint32)
    payload = jnp.arange(n, dtype=jnp.int32)
    out, dt = timed(lambda: sort_kv32(keys, payload)[0].block_until_ready())
    stages = int(np.log2(n)) * (int(np.log2(n)) + 1) // 2
    tpu_s = stages * n * 8 / HBM_BW          # VPU-bound estimate
    emit("kernel.sort_kv32.interp_s", dt * 1e6,
         f"tpu_est={tpu_s*1e6:.1f}us,stages={stages}")

    # spmv
    from repro.kernels.spmv_ell import spmv_ell
    s_, f_, v_ = 4096, 8, 4096
    nbrs = rng.integers(0, v_, (s_, f_))
    nbrs[rng.random((s_, f_)) < 0.3] = -1
    contrib = rng.normal(0, 1, (s_, f_)).astype(np.float32)
    out, dt = timed(lambda: spmv_ell(jnp.asarray(nbrs, jnp.int32),
                                     jnp.asarray(contrib), v_,
                                     rows=256, kblk=1024
                                     ).block_until_ready())
    flops = 2 * s_ * f_ * 1024 * (v_ // 1024)
    tpu_s = max(flops / PEAK_FLOPS, (s_ * f_ * 8 + v_ * 4) / HBM_BW)
    emit("kernel.spmv_ell.interp_s", dt * 1e6,
         f"tpu_est={tpu_s*1e6:.1f}us")
