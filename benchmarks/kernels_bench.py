"""Kernel micro-benchmarks: interpret-mode wall time (correctness-scale) +
analytic TPU-v5e roofline estimates per kernel (the real perf claim).

Run directly with ``--backend {xla,pallas,both}`` to time the dispatcher hot
paths (``ops.sort_pairs`` / ``ops.segment_reduce``) plus an end-to-end
``incremental_onestep`` refresh under each backend and record the comparison
to ``BENCH_backend.json`` — the start of the perf trajectory.
"""
from __future__ import annotations

import argparse
import json

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.launch.mesh import HBM_BW, PEAK_FLOPS


def run():
    rng = np.random.default_rng(0)

    # segment_reduce: one [R,K]x[R,D] matmul per tile
    from repro.kernels.segment_reduce import segment_reduce_mxu
    n, d, k = 4096, 64, 1024
    seg = jnp.asarray(np.sort(rng.integers(0, k, n)), jnp.int32)
    vals = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
    out, dt = timed(lambda: segment_reduce_mxu(seg, vals, k, rows=512,
                                               kblk=512).block_until_ready())
    flops = 2 * n * 512 * d * (k // 512)
    tpu_s = max(flops / PEAK_FLOPS, (n * d * 4 + k * d * 4) / HBM_BW)
    emit("kernel.segment_reduce.interp_s", dt * 1e6,
         f"tpu_est={tpu_s*1e6:.1f}us,flops={flops:.2e}")

    # flash attention
    from repro.kernels.flash_attention import flash_attention
    b, h, s, hd = 1, 4, 512, 64
    q = jnp.asarray(rng.normal(0, 1, (b, h, s, hd)), jnp.float32)
    kk = jnp.asarray(rng.normal(0, 1, (b, h, s, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, h, s, hd)), jnp.float32)
    out, dt = timed(lambda: flash_attention(q, kk, v, q_blk=128,
                                            kv_blk=128).block_until_ready())
    flops = 4 * b * h * s * s * hd
    tpu_s = max(flops / PEAK_FLOPS, 3 * b * h * s * hd * 4 / HBM_BW)
    emit("kernel.flash_attention.interp_s", dt * 1e6,
         f"tpu_est={tpu_s*1e6:.1f}us,flops={flops:.2e}")

    # bitonic sort
    from repro.kernels.sort_u32 import sort_kv32
    n = 4096
    keys = jnp.asarray(rng.integers(0, 2**30, n), jnp.uint32)
    payload = jnp.arange(n, dtype=jnp.int32)
    out, dt = timed(lambda: sort_kv32(keys, payload)[0].block_until_ready())
    stages = int(np.log2(n)) * (int(np.log2(n)) + 1) // 2
    tpu_s = stages * n * 8 / HBM_BW          # VPU-bound estimate
    emit("kernel.sort_kv32.interp_s", dt * 1e6,
         f"tpu_est={tpu_s*1e6:.1f}us,stages={stages}")

    # spmv
    from repro.kernels.spmv_ell import spmv_ell
    s_, f_, v_ = 4096, 8, 4096
    nbrs = rng.integers(0, v_, (s_, f_))
    nbrs[rng.random((s_, f_)) < 0.3] = -1
    contrib = rng.normal(0, 1, (s_, f_)).astype(np.float32)
    out, dt = timed(lambda: spmv_ell(jnp.asarray(nbrs, jnp.int32),
                                     jnp.asarray(contrib), v_,
                                     rows=256, kblk=1024
                                     ).block_until_ready())
    flops = 2 * s_ * f_ * 1024 * (v_ // 1024)
    tpu_s = max(flops / PEAK_FLOPS, (s_ * f_ * 8 + v_ * 4) / HBM_BW)
    emit("kernel.spmv_ell.interp_s", dt * 1e6,
         f"tpu_est={tpu_s*1e6:.1f}us")


# ---------------------------------------------------------------------------
# Backend comparison: dispatcher hot paths + end-to-end incremental refresh
# ---------------------------------------------------------------------------

def _bench_ops(backend: str, results: dict) -> None:
    from repro.kernels import ops
    rng = np.random.default_rng(0)

    n = 4096
    k2 = jnp.asarray(rng.integers(0, 256, n), jnp.int32)
    mk = jnp.asarray(rng.integers(0, 1 << 20, n), jnp.int32)
    payload = {"v": jnp.asarray(rng.normal(0, 1, (n, 4)), jnp.float32)}
    fn = lambda: ops.sort_pairs(k2, mk, payload,
                                backend=backend).k2.block_until_ready()
    fn()                                     # compile
    _, dt = timed(fn, repeat=3)
    emit(f"ops.sort_pairs.{backend}_s", dt * 1e6)
    results["sort_pairs_us"] = dt * 1e6

    seg = jnp.asarray(np.sort(rng.integers(0, 1024, n)), jnp.int32)
    vals = {"v": jnp.asarray(rng.normal(0, 1, (n, 64)), jnp.float32)}
    valid = jnp.ones(n, bool)
    fn = lambda: ops.segment_reduce("sum", seg, vals, valid, 1024,
                                    backend=backend)[1].block_until_ready()
    fn()
    _, dt = timed(fn, repeat=3)
    emit(f"ops.segment_reduce.{backend}_s", dt * 1e6)
    results["segment_reduce_us"] = dt * 1e6


def _sweep_ops(backend: str, sizes, *, repeat: int = 2) -> list:
    """Size sweep of the dispatcher hot paths (2^10..2^20 rows by default).

    Records, per size: the shuffle sort, the segment reduce, and (pallas)
    the fused vs composed ``shuffle_reduce``.  The point of the sweep is
    the *shape* of the curves — before the multi-tile sort, pallas fell
    off a cliff past one VMEM tile (pad-to-pow2-of-total); now the cost
    should scale as n log² n with no discontinuity at the old tile limit.
    """
    from repro.kernels import ops

    class _Sum:
        kind = "sum"

    rng = np.random.default_rng(0)
    key_cap, d = 1024, 8
    rows = []
    for n in sizes:
        rec = {"n": n}
        k2 = jnp.asarray(rng.integers(0, key_cap, n), jnp.int32)
        mk = jnp.asarray(rng.integers(0, 1 << 20, n), jnp.int32)
        vals = {"v": jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)}
        valid = jnp.ones(n, bool)
        sign = jnp.ones(n, jnp.int8)
        keys = jnp.asarray(np.arange(key_cap, dtype=np.int32))

        fn = lambda: ops.sort_pairs(k2, mk, vals,
                                    backend=backend).k2.block_until_ready()
        fn()
        _, dt = timed(fn, repeat=repeat)
        rec["sort_us"] = dt * 1e6

        seg = jnp.asarray(np.sort(rng.integers(0, key_cap, n)), jnp.int32)
        fn = lambda: ops.segment_reduce(
            "sum", seg, vals, valid, key_cap,
            backend=backend)[1].block_until_ready()
        fn()
        _, dt = timed(fn, repeat=repeat)
        rec["segment_reduce_us"] = dt * 1e6

        fn = lambda: ops.shuffle_reduce(
            _Sum(), k2, mk, vals, valid, sign, keys,
            backend=backend).counts.block_until_ready()
        fn()
        _, dt = timed(fn, repeat=repeat)
        rec["shuffle_reduce_us"] = dt * 1e6
        if backend == "pallas":
            fn = lambda: ops.shuffle_reduce(
                _Sum(), k2, mk, vals, valid, sign, keys, backend=backend,
                fused=False).counts.block_until_ready()
            fn()
            _, dt = timed(fn, repeat=repeat)
            rec["shuffle_reduce_unfused_us"] = dt * 1e6
        emit(f"ops.sweep.{backend}.n{n}.sort_us", rec["sort_us"],
             ",".join(f"{k}={v:.0f}" for k, v in rec.items()
                      if k.endswith("_us") and k != "sort_us"))
        rows.append(rec)
    return rows


def _bench_incremental_onestep(backend: str, results: dict) -> None:
    """End-to-end one-step refresh (wordcount, paper Section 3.3) through
    the repro.api Session façade."""
    from repro.api import RunConfig, Session, make_delta
    from repro.apps import wordcount as wc

    rng = np.random.default_rng(7)
    n_docs, vocab, length = 512, 256, 16
    docs = rng.integers(0, vocab, size=(n_docs, length)).astype(np.int32)
    spec, data = wc.make_job(docs, vocab)
    session = Session(spec, RunConfig(onestep_path="mrbg", value_bytes=4,
                                      backend=backend))

    _, dt = timed(lambda: session.run(data))
    emit(f"incremental_onestep.initial.{backend}_s", dt * 1e6)
    results["initial_us"] = dt * 1e6

    def delta_for(row, seed):
        new = np.random.default_rng(seed).integers(
            0, vocab, (1, length)).astype(np.int32)
        dk = np.repeat(np.asarray([row], np.int32), 2)
        sg = np.tile(np.array([-1, 1], np.int8), 1)
        buf = np.empty((2, length), docs.dtype)
        buf[0::2] = docs[[row]]
        buf[1::2] = new
        return make_delta(dk, {"w": jnp.asarray(buf)}, sg)

    session.update(delta_for(3, 1))          # compile the delta path
    _, dt = timed(lambda: session.update(delta_for(5, 2)), repeat=3)
    emit(f"incremental_onestep.refresh.{backend}_s", dt * 1e6)
    results["refresh_us"] = dt * 1e6


def run_backend_compare(backends, out_path: str = "BENCH_backend.json",
                        sweep_sizes=None):
    import jax
    report = {"platform": jax.default_backend(), "backends": {}}
    for bk in backends:
        res: dict = {}
        _bench_ops(bk, res)
        _bench_incremental_onestep(bk, res)
        if sweep_sizes:
            res["sweep"] = _sweep_ops(bk, sweep_sizes)
        report["backends"][bk] = res
    if ("xla" in report["backends"] and "pallas" in report["backends"]):
        x = report["backends"]["xla"]["refresh_us"]
        p = report["backends"]["pallas"]["refresh_us"]
        report["refresh_speedup_xla_over_pallas"] = p / max(x, 1e-9)
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"# wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--backend", choices=("xla", "pallas", "both"),
                    default="both",
                    help="which shuffle/reduce backend(s) to time")
    ap.add_argument("--out", default="BENCH_backend.json")
    ap.add_argument("--micro", action="store_true",
                    help="also run the legacy kernel micro-benchmarks")
    ap.add_argument("--sweep", action="store_true",
                    help="size sweep 2^10..2^20 rows of the dispatcher "
                         "hot paths (the tile-cliff witness)")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke: sweep 2^10..2^14 only")
    args = ap.parse_args()
    if args.micro:
        run()
    backends = ("xla", "pallas") if args.backend == "both" else (args.backend,)
    sizes = None
    if args.tiny:
        sizes = [1 << p for p in range(10, 15)]
    elif args.sweep:
        sizes = [1 << p for p in range(10, 21)]
    run_backend_compare(backends, args.out, sweep_sizes=sizes)


if __name__ == "__main__":
    main()
