"""Shared benchmark scaffolding: workloads, deltas, timing, CSV rows."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import numpy as np
import jax.numpy as jnp

ROWS: List[Dict] = []


def emit(name: str, value: float, derived: str = ""):
    ROWS.append({"name": name, "us_per_call": value, "derived": derived})
    print(f"{name},{value:.1f},{derived}", flush=True)


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeat
    return out, dt


def pagerank_workload(s: int = 4096, f: int = 4, seed: int = 3,
                      p_edge: float = 0.6):
    from repro.apps import pagerank as pr
    nbrs = pr.random_graph(s, f, seed=seed, p_edge=p_edge)
    return pr.make_spec(s), pr.make_struct(nbrs), nbrs


def graph_update_delta(nbrs: np.ndarray, frac: float, seed: int = 9):
    """Paper-style delta: randomly rewire ``frac`` of the vertices."""
    from repro.core.incremental import make_delta
    s, f = nbrs.shape
    rng = np.random.default_rng(seed)
    k = max(1, int(s * frac))
    rows = rng.choice(s, k, replace=False)
    new_rows = np.where(rng.random((k, f)) < 0.6,
                        rng.integers(0, s, (k, f)), -1).astype(np.int32)
    dk = np.repeat(rows.astype(np.int32), 2)
    sg = np.tile(np.array([-1, 1], np.int8), k)
    buf = np.empty((2 * k, f), np.int32)
    buf[0::2] = nbrs[rows]
    buf[1::2] = new_rows
    nbrs2 = nbrs.copy()
    nbrs2[rows] = new_rows
    return make_delta(dk, {"nbrs": jnp.asarray(buf)}, sg), nbrs2
