"""Benchmark harness entrypoint: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (fig8_overall, fig9_stages, fig10_cpc,
                            fig11_propagation, fig12_scaling, fig13_fault,
                            kernels_bench, onestep_apriori, table4_store)
    modules = [
        ("table4_store", table4_store),
        ("fig9_stages", fig9_stages),
        ("onestep_apriori", onestep_apriori),
        ("fig11_propagation", fig11_propagation),
        ("fig10_cpc", fig10_cpc),
        ("fig12_scaling", fig12_scaling),
        ("fig13_fault", fig13_fault),
        ("kernels_bench", kernels_bench),
        ("fig8_overall", fig8_overall),
    ]
    only = sys.argv[1] if len(sys.argv) > 1 else None
    print("name,us_per_call,derived")
    failures = []
    for name, mod in modules:
        if only and only != name:
            continue
        t0 = time.time()
        try:
            mod.run()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            traceback.print_exc()
            failures.append(name)
    if failures:
        print("# FAILED:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
