"""Distributed fine-grain refresh vs warm re-converge (Fig. 8 on a mesh).

Two meshed sessions receive the identical delta stream on a forced
8-device CPU mesh:

  * ``fine`` — ``MeshConfig(refresh="fine")``: delta-only all_to_all +
    per-shard MRBG merges (the tentpole path; auto MRBG-off may still
    fall back at the largest ratios, and that is part of the story).
  * ``warm`` — ``MeshConfig(refresh="warm")``: host-mirror repartition +
    warm re-converge from the current state (the pre-fine baseline and
    the rerun side of the paper's Fig. 8 crossover).

Per delta ratio the benchmark reports p50/p95 update wall-clock for both,
plus shuffle traffic (the fine path should move |Δ|-proportional bytes,
the warm path |D|-proportional) and the modes actually taken.  Results
land in ``BENCH_dist.json``:

    PYTHONPATH=src:. python benchmarks/dist_refresh.py --out BENCH_dist.json
    PYTHONPATH=src:. python benchmarks/dist_refresh.py --tiny   # CI smoke
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse            # noqa: E402
import json                # noqa: E402
import time                # noqa: E402

import jax                 # noqa: E402
import numpy as np         # noqa: E402

from benchmarks.common import emit                       # noqa: E402
from jax.sharding import Mesh                            # noqa: E402
from repro.api import MeshConfig, RunConfig, Session     # noqa: E402
from repro.apps import pagerank as pr                    # noqa: E402
from repro.core.incremental import make_delta            # noqa: E402


def _mesh() -> Mesh:
    devs = jax.devices()
    assert len(devs) >= 8, (
        "dist_refresh needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
        "set before jax initializes")
    return Mesh(np.array(devs[:8]), ("data",))


def _graph_delta(mirror: np.ndarray, rng, n_rows: int):
    s, f = mirror.shape
    rows = rng.choice(s, n_rows, replace=False)
    new = np.where(rng.random((n_rows, f)) < 0.6,
                   rng.integers(0, s, (n_rows, f)), -1).astype(np.int32)
    rid = np.repeat(rows.astype(np.int32), 2)
    buf = np.empty((2 * n_rows, f), np.int32)
    buf[0::2] = mirror[rows]
    buf[1::2] = new
    mirror[rows] = new
    return make_delta(rid, {"nbrs": buf},
                      np.tile(np.array([-1, 1], np.int8), n_rows))


def _pcts(xs) -> dict:
    a = np.asarray(xs, np.float64) * 1e3
    return {"p50_ms": float(np.percentile(a, 50)),
            "p95_ms": float(np.percentile(a, 95)),
            "mean_ms": float(a.mean())}


def run_ratio(backend: str, mesh: Mesh, nbrs: np.ndarray, ratio: float,
              epochs: int, shuffle_cap: int) -> dict:
    s = nbrs.shape[0]
    n_rows = max(1, int(s * ratio))
    # cpc_threshold is sized to the O(1) rank mass of this graph: small
    # enough for sub-0.1% rank error, large enough that delta propagation
    # dies out instead of tripping the §5.2 auto-off on every epoch
    kw = dict(backend=backend, max_iters=120, tol=1e-6,
              refresh_max_iters=60, cpc_threshold=1e-3)
    sessions = {
        "fine": Session(pr.make_job(nbrs)[0], RunConfig(
            mesh=MeshConfig(mesh, shuffle_cap=shuffle_cap), **kw)),
        # identical fine path with the phase-2 shard merges forced
        # sequential: the before/after of the threaded host loop
        "fine_seq": Session(pr.make_job(nbrs)[0], RunConfig(
            mesh=MeshConfig(mesh, shuffle_cap=shuffle_cap,
                            merge_workers=1), **kw)),
        "warm": Session(pr.make_job(nbrs)[0], RunConfig(
            mesh=MeshConfig(mesh, shuffle_cap=shuffle_cap,
                            refresh="warm"), **kw)),
    }
    out = {"ratio": ratio, "delta_rows": n_rows}
    converge_s = {}
    for name, sess in sessions.items():
        _, struct = pr.make_job(nbrs)
        t0 = time.perf_counter()
        sess.run(struct)
        converge_s[name] = time.perf_counter() - t0

    # identical delta stream for all sessions (+1 warm-up epoch so the
    # percentiles measure steady-state, not first-bucket compiles).
    # Sessions are interleaved per delta with a rotating order: the XLA
    # executable cache is process-global, so whichever session goes
    # first pays any fresh bucket compile that the others then reuse —
    # rotation spreads that cost evenly instead of biasing the A/B.
    rng = np.random.default_rng(17)
    mirror = nbrs.copy()
    deltas = [_graph_delta(mirror, rng, n_rows) for _ in range(epochs + 1)]
    names = list(sessions)
    stats = {n: {"secs": [], "modes": {}, "edges": 0, "bytes": 0}
             for n in names}
    for i, d in enumerate(deltas):
        r = i % len(names)
        for name in names[r:] + names[:r]:
            t0 = time.perf_counter()
            rep = sessions[name].update(d)
            dt = time.perf_counter() - t0
            if i == 0:
                continue               # warm-up epoch
            st = stats[name]
            st["secs"].append(dt)
            st["modes"][rep.mode] = st["modes"].get(rep.mode, 0) + 1
            st["edges"] += rep.shuffle.edges_exchanged
            st["bytes"] += rep.shuffle.bytes_moved
    for name in names:
        st = stats[name]
        out[name] = {**_pcts(st["secs"]), "modes": st["modes"],
                     "initial_converge_ms": converge_s[name] * 1e3,
                     "edges_exchanged": st["edges"],
                     "bytes_moved": st["bytes"]}
        emit(f"dist.{backend}.r{ratio:g}.{name}.p50_ms",
             out[name]["p50_ms"],
             f"p95={out[name]['p95_ms']:.1f}ms,modes={st['modes']}")
    f, w = out["fine"], out["warm"]
    out["speedup_p50"] = w["p50_ms"] / max(f["p50_ms"], 1e-9)
    out["bytes_ratio"] = f["bytes_moved"] / max(w["bytes_moved"], 1)
    out["merge_thread_speedup_p50"] = (
        out["fine_seq"]["p50_ms"] / max(f["p50_ms"], 1e-9))
    emit(f"dist.{backend}.r{ratio:g}.speedup_p50", out["speedup_p50"],
         f"bytes fine/warm={out['bytes_ratio']:.3f},"
         f"merge_threads={out['merge_thread_speedup_p50']:.2f}x")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "both"))
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_dist.json here")
    args = ap.parse_args()

    mesh = _mesh()
    s, f, epochs, cap = (256, 4, 3, 512) if args.tiny \
        else (4096, 4, 8, 8192)
    # spans the Fig. 8 crossover: fine-grain refresh wins the small
    # ratios; past ~1% propagation trips the §5.2 auto-off and both
    # columns converge warm (by design)
    ratios = (0.01, 0.05) if args.tiny else (0.0005, 0.002, 0.01, 0.05)
    nbrs = pr.random_graph(s, f, seed=3, p_edge=0.6)

    backends = (("xla", "pallas") if args.backend == "both"
                else (args.backend,))
    results = {"platform": jax.default_backend(),
               "devices": len(jax.devices()),
               "note": "8 forced CPU host devices; wall-clock includes "
                       "host merge + device exchange",
               "tiny": args.tiny, "graph": {"s": s, "f": f},
               "epochs": epochs, "backends": {}}
    for bk in backends:
        results["backends"][bk] = [
            run_ratio(bk, mesh, nbrs, r, epochs, cap) for r in ratios]

    if args.out:
        with open(args.out, "w") as fh:
            json.dump(results, fh, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
