"""Fig. 10: change-propagation-control threshold vs runtime vs mean error
(larger threshold => faster refresh, larger — but bounded — mean error)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, graph_update_delta, pagerank_workload
from repro.apps import pagerank as pr
from repro.core.incr_iter import IncrIterJob


def run():
    spec, struct, nbrs = pagerank_workload(s=8192, f=4)
    delta0, nbrs2 = graph_update_delta(nbrs, 0.05)
    want = pr.oracle(nbrs2, iters=300)

    # warm
    wjob = IncrIterJob(spec, pr.make_struct(nbrs), value_bytes=8)
    wjob.initial_converge(max_iters=100, tol=1e-6)
    wjob.refresh(graph_update_delta(nbrs, 0.05)[0], max_iters=40, tol=1e-6,
                 cpc_threshold=0.02)

    for ft in (0.01, 0.03, 0.1):
        job = IncrIterJob(spec, pr.make_struct(nbrs), value_bytes=8)
        job.initial_converge(max_iters=100, tol=1e-6)
        d, _ = graph_update_delta(nbrs, 0.05)
        t0 = time.perf_counter()
        st, hist = job.refresh(d, max_iters=40, tol=1e-6, cpc_threshold=ft)
        dt = time.perf_counter() - t0
        got = np.asarray(st.values["r"])
        mean_err = float((np.abs(got - want) / np.maximum(want, 1e-9)).mean())
        emit(f"fig10.ft_{ft}.time_s", dt * 1e6,
             f"mean_err={mean_err:.5f},mode={hist['mode']},"
             f"iters={hist['iters']}")
