"""Table 4: MRBG-Store retrieval policies on an iterative incremental
PageRank — #reads, bytes read, elapsed merge time per policy.

The paper's qualitative ordering to reproduce: index-only does the most
(small) reads; single-fix-window reads the most bytes; multi-dynamic-window
does fewest reads with modest bytes and the best time.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, graph_update_delta, pagerank_workload
from repro.core.incr_iter import IncrIterJob
from repro.core.mrbg_store import POLICIES


def _one(policy, warm_only=False):
    spec, struct, nbrs = pagerank_workload(s=8192, f=4)
    job = IncrIterJob(spec, struct, value_bytes=8, policy=policy)
    job.initial_converge(max_iters=100, tol=1e-6)
    delta, _ = graph_update_delta(nbrs, 0.10)
    t0 = time.perf_counter()
    job.refresh(delta, max_iters=30, tol=1e-6, cpc_threshold=0.02)
    dt = time.perf_counter() - t0
    reads = sum(l.io_reads for l in job.logs)
    rbytes = sum(l.io_bytes for l in job.logs)
    return dt, reads, rbytes


def run():
    _one("multi-dynamic-window")          # warm all jit caches once
    for policy in POLICIES:
        dt, reads, rbytes = _one(policy)
        emit(f"table4.{policy}.time_s", dt * 1e6,
             f"reads={reads},rsize_bytes={rbytes}")
