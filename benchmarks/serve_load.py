"""Serving-tier benchmark: batched vs sequential cross-tenant refresh,
plus SLO behavior under overload.

Two kinds of cells, per backend:

  * ``tenants_N``  — closed-loop fleets of N small wordcount tenants,
    one update per tenant per round.  ``batched`` runs the tier's
    cross-tenant batched refresh (one kernel launch per compatible
    group); ``sequential`` forces the per-tenant path
    (``batch_refresh=False`` — the old MultiSessionServer behavior).
    The headline is the updates/sec ratio: past ~100 tenants the
    per-tenant path is dispatch-bound and batching must win.
  * ``overload``   — one latency-class tenant (p95 target) in a fleet of
    best-effort tenants, driven open-loop at 2x the tier's measured
    capacity.  Admission control must shed best-effort submits while the
    latency tenant's p95 holds.  xla only: interpret-mode pallas launch
    granularity is seconds, so no latency target there is meaningful.

Results land in ``BENCH_serve.json``:

    PYTHONPATH=src:. python benchmarks/serve_load.py                # full
    PYTHONPATH=src:. python benchmarks/serve_load.py --tiny         # CI smoke
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit
from repro.serve import ServeTier, SLOClass
from repro.serve import loadgen


def throughput_cell(backend: str, n_tenants: int, rounds: int,
                    cache_dir: str | None) -> dict:
    cell = {}
    for mode in ("batched", "sequential"):
        tier = ServeTier(batch_refresh=(mode == "batched"))
        mirrors = loadgen.make_fleet(tier, n_tenants, backend=backend,
                                     cache_dir=cache_dir, seed=n_tenants)
        # two warm rounds: the affected-key bucket (key_cap) can differ
        # between rounds, so one round leaves compiles in the measurement
        loadgen.run_rounds(tier, mirrors, 2)
        res = loadgen.run_rounds(tier, mirrors, rounds, seed=9)
        stats = tier.stats()
        res["batched_launches"] = stats["batched_launches"]
        res["batched_refreshes"] = stats["batched_refreshes"]
        res["latency_p95_ms_median"] = float(np.median(
            [t["latency_p95_ms"] for t in stats["tenants"].values()]))
        cell[mode] = res
        emit(f"serve.{backend}.tenants_{n_tenants}.{mode}.updates_per_sec",
             res["updates_per_sec"],
             f"wall={res['wall_s']:.2f}s,"
             f"batched_launches={res['batched_launches']}")
    cell["speedup"] = (cell["batched"]["updates_per_sec"]
                       / max(cell["sequential"]["updates_per_sec"], 1e-9))
    emit(f"serve.{backend}.tenants_{n_tenants}.speedup", cell["speedup"],
         "batched vs sequential updates/sec")
    return cell


def overload_cell(backend: str, n_best_effort: int, duration_s: float,
                  cache_dir: str | None) -> dict:
    def slo_of(i: int) -> SLOClass:
        if i == 0:
            return SLOClass.latency(target_p95_ms=500.0, deadline_ms=500.0)
        return SLOClass.best_effort()

    tier = ServeTier()
    # the latency tenant refreshes solo (its own batch group): its p95
    # must not ride the best-effort herd's group-size bucket ladder.
    # Best-effort records are wide (many row-pairs of long documents) so
    # the refresh engine — not the Python submit loop — is what
    # saturates: per-row refresh cost scales with doc_len while the
    # submit path stays one cheap array copy.
    rows_per_update = 8
    vocab = 512
    n_docs, doc_len = 64, 128
    mirrors = loadgen.make_fleet(
        tier, n_best_effort + 1, backend=backend, cache_dir=cache_dir,
        seed=7, n_docs=n_docs, doc_len=doc_len, vocab=vocab, slo_of=slo_of,
        group_of=lambda i: "latency" if i == 0 else None)
    latency_tenant = "t0000"
    with tier:                                        # scheduler thread on
        loadgen.run_rounds(tier, mirrors, 2,          # warm / compile rounds
                           vocab=vocab, rows_per_update=rows_per_update)
        # first open-loop burst still compiles the full-batch coalesce
        # buckets; the second one is the honest saturation rate
        loadgen.open_loop_rate(tier, mirrors,
                               updates=8 * (n_best_effort + 1),
                               vocab=vocab, rows_per_update=rows_per_update)
        capacity = loadgen.open_loop_rate(
            tier, mirrors, updates=8 * (n_best_effort + 1), seed=4,
            vocab=vocab, rows_per_update=rows_per_update)
        # backend-calibrated SLO: a p95 target below one refresh is
        # unachievable by construction (pallas interpret mode is orders
        # of magnitude slower per launch than compiled xla), so target
        # 10x the latency tenant's own median refresh, floored at the
        # headline 500ms.  The trickle rate is scaled the same way so the
        # latency tenant measures herd interference, not self-overload.
        ref_p95_s = tier[latency_tenant].metrics.refresh_pct(50)
        target_p95_ms = max(500.0, 1e4 * ref_p95_s)
        tier.handle(latency_tenant).slo = SLOClass.latency(
            target_p95_ms=target_p95_ms, deadline_ms=target_p95_ms)
        # reset breach/shed/latency accounting accumulated during
        # calibration — the SLO verdict is about the overload window only
        for h in tier.handles.values():
            h.reset_window()
        res = loadgen.overload_run(
            tier, mirrors, latency_tenant=latency_tenant,
            duration_s=duration_s, offered_per_sec=2.0 * capacity,
            latency_interval_s=max(0.05, 2.0 * ref_p95_s),
            vocab=vocab, rows_per_update=rows_per_update)
    stats = tier.stats()
    lat = stats["classes"][latency_tenant]
    out = {
        "capacity_updates_per_sec": capacity,
        "offered_updates_per_sec": 2.0 * capacity,
        **res,
        "latency_tenant": {
            "target_p95_ms": target_p95_ms,
            # windowed (overload-only) p95 from the tier-side reservoir,
            # not the session-lifetime StreamMetrics percentile, which
            # still holds the calibration bursts
            "latency_p95_ms": lat["latency_p95_ms"],
            "breach_rate": lat["breach_rate"],
            "refreshes": lat["observed"],
        },
        "best_effort": {
            "shed_submits": sum(c["shed_submits"]
                                for c in stats["classes"].values()),
            "shed_rows": sum(c["shed_rows"]
                             for c in stats["classes"].values()),
        },
    }
    emit(f"serve.{backend}.overload.latency_p95_ms",
         lat["latency_p95_ms"],
         f"target={target_p95_ms}ms,breach_rate={lat['breach_rate']:.3f}")
    emit(f"serve.{backend}.overload.shed_fraction", res["shed_fraction"],
         f"offered={res['offered']},admitted={res['admitted']}")
    return out


def run_backend(backend: str, tiny: bool, cache_dir: str | None) -> dict:
    out = {}
    sizes = (10,) if tiny else (10, 100, 1000)
    rounds = 2 if tiny else 3
    for n in sizes:
        out[f"tenants_{n}"] = throughput_cell(backend, n, rounds, cache_dir)
    if backend == "xla":
        out["overload"] = overload_cell(
            backend, n_best_effort=6 if tiny else 32,
            duration_s=3.0 if tiny else 15.0, cache_dir=cache_dir)
    else:
        # the SLO verdict needs a latency-representative backend: in
        # pallas interpret mode a single best-effort batched launch — the
        # unit preemption cannot split — takes seconds, so no sub-second
        # p95 target is achievable by construction
        out["overload"] = {"skipped":
                           "pallas interpret-mode launch granularity "
                           "exceeds any latency-representative p95 target"}
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "both"))
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_serve.json here")
    ap.add_argument("--cache-dir", default=".jax_cache",
                    help="persistent XLA executable cache directory "
                         "('' disables)")
    args = ap.parse_args()

    backends = (("xla", "pallas") if args.backend == "both"
                else (args.backend,))
    results = {"platform": jax.default_backend(),
               "note": "CPU wall-clock; pallas runs in interpret mode off-TPU",
               "tiny": args.tiny, "backends": {}}
    for bk in backends:
        results["backends"][bk] = run_backend(bk, args.tiny,
                                              args.cache_dir or None)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
