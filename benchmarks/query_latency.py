"""Incremental-query benchmark: ``Query.update(delta)`` vs ``Query.rerun()``
(the Fig. 8 crossover, restated for the dql workload family), per backend.

Two workloads from :mod:`repro.dql.workloads`:

  * ``join``     — incremental equi-join (two sources, join-stage refresh
    through per-stage MRBG slices);
  * ``windowed`` — sliding-window aggregation (single-stage lowering: the
    window is key-space expansion, so the engine's accumulator/MRBG
    one-step paths carry the refresh).

For each delta fraction the update path must be |Δ|-proportional, so at
small fractions (≤1%) ``update`` has to beat ``rerun`` — that is the
acceptance gate this file witnesses into ``BENCH_query.json``.  The
steady-state retrace counter (:func:`repro.kernels.jitcache.generation`)
is sampled around the timed updates: with the PR-6 bucketed delta ladder
any nonzero delta is a latency-tail bug.

    PYTHONPATH=src:. python benchmarks/query_latency.py --backend both \
        --out BENCH_query.json                                  # full
    PYTHONPATH=src:. python benchmarks/query_latency.py --tiny  # CI smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.api import RunConfig
from repro.dql import workloads as wl
from repro.kernels import jitcache

REPS = 3


def _time_each(fn, args_list):
    """Median seconds of ``fn(a)`` over ``args_list`` (one call each)."""
    ts = []
    for a in args_list:
        t0 = time.perf_counter()
        fn(a)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _crossover(name, make_query, data, make_delta_fn, fracs, backend):
    out = {}
    for frac in fracs:
        q = make_query().compile(RunConfig(backend=backend, value_bytes=4))
        q.run(data)
        # prewarm both paths: compiles land outside the timed region
        q.update(make_delta_fn(frac, seed=1000))
        q.rerun()
        gen0 = jitcache.generation()
        dt_up = _time_each(q.update, [make_delta_fn(frac, seed=2000 + i)
                                      for i in range(REPS)])
        retraces = jitcache.generation() - gen0
        dt_re = _time_each(lambda _: q.rerun(), range(REPS))
        speedup = dt_re / dt_up if dt_up > 0 else float("inf")
        tag = f"query.{name}.{backend}.f{frac:g}"
        emit(f"{tag}.update_ms", dt_up * 1e3,
             f"retraces_steady={retraces}")
        emit(f"{tag}.rerun_ms", dt_re * 1e3, f"speedup={speedup:.2f}x")
        out[f"{frac:g}"] = {
            "update_ms": dt_up * 1e3, "rerun_ms": dt_re * 1e3,
            "speedup": speedup, "retraces_steady": int(retraces)}
    return out


def run_backend(backend: str, tiny: bool) -> dict:
    out = {}

    # -- incremental equi-join ---------------------------------------------
    users = 256 if tiny else (512 if backend == "pallas" else 2048)
    fracs = (0.01, 0.1) if tiny else (0.005, 0.01, 0.05, 0.25)
    datas = wl.join_data(users, seed=3)
    out["join"] = _crossover(
        "join", lambda: wl.join_query(users), datas,
        lambda frac, seed: wl.join_delta(datas, frac, seed=seed),
        fracs, backend)

    # -- windowed aggregation ----------------------------------------------
    if tiny:
        n, keys, wins, slide = 256, 8, 8, 4
    elif backend == "pallas":
        n, keys, wins, slide = 1024, 16, 16, 4
    else:
        n, keys, wins, slide = 8192, 64, 32, 4
    events = wl.events_data(n, keys, t_max=wins * slide, seed=2)
    out["windowed"] = _crossover(
        "windowed",
        lambda: wl.windowed_query(keys, size=2 * slide, slide=slide,
                                  num_windows=wins),
        events,
        lambda frac, seed: wl.events_delta(events, frac,
                                           t_max=wins * slide, seed=seed),
        fracs, backend)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "both"))
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--out", default=None,
                    help="write the results JSON here")
    args = ap.parse_args()

    backends = (("xla", "pallas") if args.backend == "both"
                else (args.backend,))
    results = {"platform": jax.default_backend(),
               "note": "CPU wall-clock; pallas runs in interpret mode "
                       "off-TPU (smaller full sizes)",
               "tiny": args.tiny, "backends": {}}
    for bk in backends:
        results["backends"][bk] = run_backend(bk, args.tiny)
    results["jit"] = jitcache.snapshot()

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
