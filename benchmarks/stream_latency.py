"""Streaming serving benchmark: sustained updates/sec + refresh-latency
percentiles through `repro.stream.StreamSession`, per backend.

Two workloads cover both engine families, via the same app adapters the
examples use: wordcount (one-step / accumulator refresh) and incremental
PageRank (iterative refresh with CPC).  Results land in
``BENCH_stream.json``:

    PYTHONPATH=src:. python benchmarks/stream_latency.py            # full
    PYTHONPATH=src:. python benchmarks/stream_latency.py --tiny     # CI smoke
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit
from repro.api import RunConfig, StreamConfig
from repro.apps import pagerank as pr, wordcount as wc
from repro.stream import StreamSession


def _serve(name: str, spec, data, source, config, stream) -> dict:
    ss = StreamSession(spec, data, source=source, config=config,
                       stream=stream)
    with ss:
        ss.drain(timeout=1200)
    m = ss.metrics.snapshot()
    actions = {d.action for d in ss.scheduler.decisions}
    emit(f"{name}.updates_per_sec", m["updates_per_sec"],
         f"batches={m['batches']},rows={m['rows_in']},actions={sorted(actions)}")
    emit(f"{name}.refresh_p50_ms", m["refresh_p50_ms"],
         f"p95={m['refresh_p95_ms']:.2f}ms")
    emit(f"{name}.latency_p50_ms", m["latency_p50_ms"],
         f"p95={m['latency_p95_ms']:.2f}ms")
    return {"updates_per_sec": m["updates_per_sec"],
            "refresh_p50_ms": m["refresh_p50_ms"],
            "refresh_p95_ms": m["refresh_p95_ms"],
            "latency_p50_ms": m["latency_p50_ms"],
            "latency_p95_ms": m["latency_p95_ms"],
            "batches": m["batches"], "rows_in": m["rows_in"],
            "coalesce_savings": m["coalesce_savings"],
            "refreshes": m["refreshes"]}


def run_backend(backend: str, tiny: bool) -> dict:
    rng = np.random.default_rng(0)
    out = {}

    n_docs, vocab, epochs = (64, 32, 3) if tiny else (1024, 512, 6)
    docs = rng.integers(0, vocab, (n_docs, 8)).astype(np.int32)
    spec, data, source = wc.make_stream(docs, vocab, frac=0.05, seed=1,
                                        epochs=epochs)
    out["wordcount"] = _serve(
        f"stream.wordcount.{backend}", spec, data, source,
        RunConfig(backend=backend, value_bytes=4),
        StreamConfig(max_batch_records=2 * max(1, int(n_docs * 0.05)),
                     max_batch_delay=0.005, policy="latency"))

    s = 128 if tiny else 1024
    nbrs = pr.random_graph(s, 4, seed=3, p_edge=0.5)
    spec, struct, source = pr.make_stream(nbrs, frac=0.02, seed=5,
                                          epochs=epochs)
    out["pagerank"] = _serve(
        f"stream.pagerank.{backend}", spec, struct, source,
        RunConfig(backend=backend, max_iters=120, tol=1e-6,
                  refresh_max_iters=60, cpc_threshold=0.01, value_bytes=4),
        StreamConfig(max_batch_records=2 * max(1, int(s * 0.02)),
                     max_batch_delay=0.005, policy="latency"))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "both"))
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_stream.json here (default: only when "
                         "running --backend both full-size)")
    args = ap.parse_args()

    backends = (("xla", "pallas") if args.backend == "both"
                else (args.backend,))
    results = {"platform": jax.default_backend(),
               "note": "CPU wall-clock; pallas runs in interpret mode off-TPU",
               "tiny": args.tiny, "backends": {}}
    for bk in backends:
        results["backends"][bk] = run_backend(bk, args.tiny)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
