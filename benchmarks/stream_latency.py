"""Streaming serving benchmark: sustained updates/sec + refresh-latency
percentiles through `repro.stream.StreamSession`, per backend.

Four workloads:

  * ``wordcount``       — one-step / accumulator refresh over an evolving
    corpus (the steady-state latency-tail target: with bucketed delta
    shapes and a prewarmed ladder, p95 must sit near p50, with zero
    retraces after start()).
  * ``pagerank``        — iterative refresh with CPC (scheduler-heavy).
  * ``wordcount_hot``   — adversarial repeated-key bursts: each hot doc is
    rewritten several times inside one micro-batch, so the coalescer's
    first-'-'/last-'+' rule must cancel the interior rows.
  * ``wordcount_churn`` — adversarial insert-then-delete churn: docs are
    created and destroyed on previously-empty slots within one batch
    (full cancellation), mixed with live updates.

Retrace/recompile counters come from :mod:`repro.kernels.jitcache`; the
"steady" counters are taken after ``start()`` (initial run + prewarm), so
any nonzero value is a latency-tail bug, not warm-up.  Results land in
``BENCH_stream.json``:

    PYTHONPATH=src:. python benchmarks/stream_latency.py            # full
    PYTHONPATH=src:. python benchmarks/stream_latency.py --tiny     # CI smoke
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import emit
from repro.api import RunConfig, StreamConfig
from repro.apps import pagerank as pr, wordcount as wc
from repro.kernels import jitcache
from repro.stream import DeltaRecord, QueueSource, StreamSession


def _serve(name: str, spec, data, source, config, stream) -> dict:
    ss = StreamSession(spec, data, source=source, config=config,
                       stream=stream)
    ss.start(background=False)      # initial run + prewarm compile here
    jit0 = jitcache.snapshot()      # steady-state baseline
    ss.drain(timeout=1200)          # sync mode: drain() is the consumer
    jit1 = jitcache.snapshot()
    m = ss.metrics.snapshot()
    actions = {d.action for d in ss.scheduler.decisions}
    emit(f"{name}.updates_per_sec", m["updates_per_sec"],
         f"batches={m['batches']},rows={m['rows_in']},actions={sorted(actions)}")
    emit(f"{name}.refresh_p50_ms", m["refresh_p50_ms"],
         f"p95={m['refresh_p95_ms']:.2f}ms")
    emit(f"{name}.latency_p50_ms", m["latency_p50_ms"],
         f"p95={m['latency_p95_ms']:.2f}ms")
    emit(f"{name}.retraces_steady", jit1["traces"] - jit0["traces"],
         f"compiles={jit1['compiles'] - jit0['compiles']},"
         f"retrace_batches={m['retrace_batches']}")
    if m["coalesce_savings"] > 0:
        emit(f"{name}.coalesce_savings", m["coalesce_savings"],
             f"rows_in={m['rows_in']},rows_engine={m['rows_engine']}")
    return {"updates_per_sec": m["updates_per_sec"],
            "refresh_p50_ms": m["refresh_p50_ms"],
            "refresh_p95_ms": m["refresh_p95_ms"],
            "latency_p50_ms": m["latency_p50_ms"],
            "latency_p95_ms": m["latency_p95_ms"],
            "batches": m["batches"], "rows_in": m["rows_in"],
            "coalesce_savings": m["coalesce_savings"],
            "refreshes": m["refreshes"],
            "retraces_steady": jit1["traces"] - jit0["traces"],
            "compiles_steady": jit1["compiles"] - jit0["compiles"],
            "retrace_batches": m["retrace_batches"],
            "compile_skips": ss.scheduler.compile_skips}


def _hot_source(mirror: np.ndarray, vocab: int, rng, epochs: int,
                hot: int, reps: int) -> QueueSource:
    """Repeated-key bursts: ``hot`` docs each rewritten ``reps`` times in a
    single record — only the first '-' and last '+' per doc matter."""
    src = QueueSource(capacity=epochs + 1)
    for e in range(epochs):
        rows = rng.choice(len(mirror), size=hot, replace=False)
        rids, bufs, signs = [], [], []
        for r in rows:
            cur = mirror[r].copy()
            for _ in range(reps):
                new = rng.integers(0, vocab, cur.shape).astype(np.int32)
                rids += [r, r]
                bufs += [cur, new]
                signs += [-1, 1]
                cur = new
            mirror[r] = cur
        src.push(DeltaRecord(record_ids=np.asarray(rids, np.int32),
                             values={"w": np.stack(bufs)},
                             sign=np.asarray(signs, np.int8), epoch=e))
    src.seal()
    return src


def _churn_source(mirror: np.ndarray, valid: np.ndarray, vocab: int, rng,
                  epochs: int, n_churn: int, n_live: int) -> QueueSource:
    """Insert-then-delete churn on initially-empty slots (first '+', last
    '-': the coalescer drops both rows) mixed with live updates."""
    src = QueueSource(capacity=epochs + 1)
    empty = np.nonzero(~valid)[0]
    live = np.nonzero(valid)[0]
    width = mirror.shape[1:]
    for e in range(epochs):
        rids, bufs, signs = [], [], []
        for s in rng.choice(empty, size=n_churn, replace=False):
            doc = rng.integers(0, vocab, width).astype(np.int32)
            rids += [s, s]
            bufs += [doc, doc]
            signs += [1, -1]            # created and destroyed in-batch
        for r in rng.choice(live, size=n_live, replace=False):
            new = rng.integers(0, vocab, width).astype(np.int32)
            rids += [r, r]
            bufs += [mirror[r].copy(), new]
            signs += [-1, 1]
            mirror[r] = new
        src.push(DeltaRecord(record_ids=np.asarray(rids, np.int32),
                             values={"w": np.stack(bufs)},
                             sign=np.asarray(signs, np.int8), epoch=e))
    src.seal()
    return src


def run_backend(backend: str, tiny: bool, cache_dir: str | None) -> dict:
    rng = np.random.default_rng(0)
    out = {}

    def rc(**kw) -> RunConfig:
        return RunConfig(backend=backend, value_bytes=4,
                         compilation_cache_dir=cache_dir, **kw)

    # -- wordcount: the steady-state latency target ------------------------
    n_docs, vocab, epochs = (64, 32, 3) if tiny else (1024, 512, 24)
    docs = rng.integers(0, vocab, (n_docs, 8)).astype(np.int32)
    spec, data, source = wc.make_stream(docs, vocab, frac=0.05, seed=1,
                                        epochs=epochs)
    batch_rows = 2 * max(1, int(n_docs * 0.05))
    out["wordcount"] = _serve(
        f"stream.wordcount.{backend}", spec, data, source,
        rc(),
        StreamConfig(max_batch_records=batch_rows,
                     max_batch_delay=0.005, policy="latency",
                     prewarm=True))

    # -- pagerank: iterative refresh ---------------------------------------
    s, pr_epochs = (128, 3) if tiny else (1024, 12)
    nbrs = pr.random_graph(s, 4, seed=3, p_edge=0.5)
    spec, struct, source = pr.make_stream(nbrs, frac=0.02, seed=5,
                                          epochs=pr_epochs)
    out["pagerank"] = _serve(
        f"stream.pagerank.{backend}", spec, struct, source,
        rc(max_iters=120, tol=1e-6, refresh_max_iters=60,
           cpc_threshold=0.01),
        StreamConfig(max_batch_records=2 * max(1, int(s * 0.02)),
                     max_batch_delay=0.005, policy="latency",
                     prewarm=True))

    # -- adversarial: repeated-key bursts ----------------------------------
    hot, reps, hot_epochs = (4, 4, 3) if tiny else (16, 4, 12)
    hot_docs = rng.integers(0, vocab, (n_docs, 8)).astype(np.int32)
    spec, data = wc.make_job(hot_docs, vocab)
    src = _hot_source(hot_docs.copy(), vocab, rng, hot_epochs, hot, reps)
    out["wordcount_hot"] = _serve(
        f"stream.wordcount_hot.{backend}", spec, data, src,
        rc(),
        StreamConfig(max_batch_records=2 * hot * reps,
                     max_batch_delay=0.005, policy="latency",
                     prewarm=True))

    # -- adversarial: insert-then-delete churn -----------------------------
    n_churn, n_live, ch_epochs = (2, 4, 3) if tiny else (8, 16, 12)
    ch_docs = rng.integers(0, vocab, (n_docs, 8)).astype(np.int32)
    ch_valid = np.arange(n_docs) < (3 * n_docs) // 4   # empty tail quarter
    spec = wc.make_spec(vocab)
    data = wc.make_input(np.arange(n_docs), ch_docs, ch_valid)
    src = _churn_source(ch_docs.copy(), ch_valid, vocab, rng, ch_epochs,
                        n_churn, n_live)
    out["wordcount_churn"] = _serve(
        f"stream.wordcount_churn.{backend}", spec, data, src,
        rc(),
        StreamConfig(max_batch_records=2 * (n_churn + n_live),
                     max_batch_delay=0.005, policy="latency",
                     prewarm=True))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="xla",
                    choices=("xla", "pallas", "both"))
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke sizes (seconds, not minutes)")
    ap.add_argument("--out", default=None,
                    help="write BENCH_stream.json here (default: only when "
                         "running --backend both full-size)")
    ap.add_argument("--cache-dir", default=".jax_cache",
                    help="persistent XLA executable cache directory "
                         "('' disables)")
    args = ap.parse_args()

    backends = (("xla", "pallas") if args.backend == "both"
                else (args.backend,))
    results = {"platform": jax.default_backend(),
               "note": "CPU wall-clock; pallas runs in interpret mode off-TPU",
               "tiny": args.tiny, "backends": {}}
    for bk in backends:
        results["backends"][bk] = run_backend(bk, args.tiny,
                                              args.cache_dir or None)
    results["jit"] = jitcache.snapshot()

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
