"""Fig. 12: input-size scaling of plainMR vs iterMR (the Spark-vs-iterMR
experiment's shape: relative advantage grows with structure size)."""
from __future__ import annotations

from benchmarks.common import emit, pagerank_workload, timed
from repro.core.iterative import State, run_iterative, run_plain


def run():
    for label, s in (("xs", 2048), ("s", 8192), ("m", 32768)):
        spec, struct, nbrs = pagerank_workload(s=s, f=4, p_edge=0.5)
        st0, _ = run_iterative(spec, struct, max_iters=30, tol=1e-6)
        _, t_plain = timed(lambda: run_plain(spec, struct, None,
                                             max_iters=30, tol=1e-6))
        _, t_iter = timed(lambda: run_iterative(
            spec, struct, State(dict(st0.values), st0.valid),
            max_iters=30, tol=1e-6))
        emit(f"fig12.{label}.plainMR_s", t_plain * 1e6, f"vertices={s}")
        emit(f"fig12.{label}.iterMR_s", t_iter * 1e6,
             f"speedup={t_plain / max(t_iter, 1e-9):.2f}x")
