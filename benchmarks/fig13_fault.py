"""Fig. 13: failure injection + recovery.  A worker failure mid-refresh is
recovered from the Session checkpoint; recovery cost is a small constant
(paper: ~12 s on EC2), not a job restart."""
from __future__ import annotations

import shutil
import time

from benchmarks.common import emit, graph_update_delta, pagerank_workload
from repro.api import RunConfig, Session


def run():
    spec, struct, nbrs = pagerank_workload(s=8192, f=4)
    cfg = RunConfig(max_iters=100, tol=1e-6, refresh_max_iters=30,
                    cpc_threshold=0.01, value_bytes=8)
    shutil.rmtree("/tmp/repro_fig13", ignore_errors=True)
    session = Session(spec, cfg)
    session.run(struct)
    delta, _ = graph_update_delta(nbrs, 0.10)

    t0 = time.perf_counter()
    session.checkpoint("/tmp/repro_fig13")
    t_ckpt = time.perf_counter() - t0

    t0 = time.perf_counter()
    session.update(delta)
    t_refresh = time.perf_counter() - t0

    # failure: the session object dies; restore + rerun the refresh
    t0 = time.perf_counter()
    session2 = Session.restore(spec, "/tmp/repro_fig13", cfg)
    t_restore = time.perf_counter() - t0
    t0 = time.perf_counter()
    session2.update(delta)
    t_recover = time.perf_counter() - t0

    import numpy as np
    drift = float(np.abs(session.result["r"] - session2.result["r"]).max())
    emit("fig13.checkpoint_s", t_ckpt * 1e6, "per-epoch MRBG+state")
    emit("fig13.restore_s", t_restore * 1e6,
         f"vs refresh {t_refresh*1e6:.0f}us")
    emit("fig13.recovered_refresh_s", t_recover * 1e6,
         f"result_drift={drift:.2e}")
