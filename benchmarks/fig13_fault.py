"""Fig. 13: failure injection + recovery.  A worker failure mid-refresh is
recovered from the per-iteration checkpoint; recovery cost is a small
constant (paper: ~12 s on EC2), not a job restart."""
from __future__ import annotations

import time

from benchmarks.common import emit, graph_update_delta, pagerank_workload
from repro.core.ft import checkpoint_job, restore_job
from repro.core.incr_iter import IncrIterJob


def run():
    spec, struct, nbrs = pagerank_workload(s=8192, f=4)
    job = IncrIterJob(spec, struct, value_bytes=8)
    job.initial_converge(max_iters=100, tol=1e-6)
    delta, _ = graph_update_delta(nbrs, 0.10)

    # uninterrupted refresh
    import copy
    t0 = time.perf_counter()
    ck = checkpoint_job(job, "/tmp/repro_fig13", 0)
    t_ckpt = time.perf_counter() - t0

    t0 = time.perf_counter()
    st, _ = job.refresh(delta, max_iters=30, tol=1e-6, cpc_threshold=0.01)
    t_refresh = time.perf_counter() - t0

    # failure: job object dies; restore + rerun refresh
    t0 = time.perf_counter()
    job2 = restore_job(spec, "/tmp/repro_fig13")
    t_restore = time.perf_counter() - t0
    t0 = time.perf_counter()
    st2, _ = job2.refresh(delta, max_iters=30, tol=1e-6, cpc_threshold=0.01)
    t_recover = time.perf_counter() - t0

    import numpy as np
    drift = float(np.abs(np.asarray(st.values["r"]) -
                         np.asarray(st2.values["r"])).max())
    emit("fig13.checkpoint_s", t_ckpt * 1e6, "per-iteration MRBG+state")
    emit("fig13.restore_s", t_restore * 1e6,
         f"vs refresh {t_refresh*1e6:.0f}us")
    emit("fig13.recovered_refresh_s", t_recover * 1e6,
         f"result_drift={drift:.2e}")
