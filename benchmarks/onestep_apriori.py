"""§8.2 one-step APriori: recompute vs accumulator-incremental on a weekly
delta (paper: 7.9% of the corpus, 12x speedup), driven through repro.api."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.api import RunConfig, Session, make_delta
from repro.apps import apriori
from repro.core.engine import run_onestep


def run():
    rng = np.random.default_rng(1)
    V, L, N = 2000, 24, 400000
    tweets = rng.integers(0, V, (N, L)).astype(np.int32)
    tweets[rng.random((N, L)) < 0.2] = -1
    pairs = apriori.candidate_pairs(tweets[:20000], V, top=64)
    spec, inp0 = apriori.make_job(tweets, pairs)

    session = Session(spec, RunConfig(onestep_path="accumulator"))
    session.run(inp0)

    dn = int(N * 0.079)
    new = rng.integers(0, V, (dn, L)).astype(np.int32)
    new[rng.random((dn, L)) < 0.2] = -1
    ids = np.arange(N, N + dn, dtype=np.int32)
    delta = make_delta(ids, {"w": jnp.asarray(new)}, np.ones(dn, np.int8))

    # warm both paths
    session.update(delta)
    all_tweets = np.concatenate([tweets, new])
    inp = apriori.make_input(np.arange(N + dn), all_tweets)
    # raw recompute baseline (whitebox: measures the engine internals)
    run_onestep(spec, inp)
    _, t_recomp = timed(lambda: run_onestep(spec, inp)
                        .results.values["c"].block_until_ready(),
                        repeat=3)

    session2 = Session(spec, RunConfig(onestep_path="accumulator"))
    session2.run(inp0)
    _, t_incr = timed(lambda: session2.update(delta))
    emit("apriori.recompute_s", t_recomp * 1e6, f"tweets={N+dn}")
    emit("apriori.incremental_s", t_incr * 1e6,
         f"speedup={t_recomp / t_incr:.1f}x,map_work_saving={(N+dn)/dn:.1f}x"
         " (paper: 12x on 7.9% delta)")
