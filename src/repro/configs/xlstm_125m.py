"""xLSTM-125M [arXiv:2405.04517]: 12L, d=768, 4 heads, alternating
mLSTM (matrix memory) / sLSTM (scalar memory) blocks, vocab 50304.
d_ff=0 in the assignment: blocks carry their own projections (mLSTM
projection factor 2; sLSTM post-GLU factor 4/3).

Linear-time: runs the long_500k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50304,
    block_pattern=("mlstm", "slstm"),
    tie_embeddings=True,
)
