"""Chameleon-34B [arXiv:2405.09818]: early-fusion token-based mixed-modal,
48L, d=8192, 64H (GQA kv=8), d_ff=22016, vocab 65536 including VQ image
tokens (image tokenizer frontend stubbed).  Uses qk-norm for stability,
per the paper.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=65536,
    qk_norm=True,
    block_pattern=("attn_dense",),
    loss_chunk=512,
)
