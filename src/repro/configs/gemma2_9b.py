"""Gemma-2 9B [arXiv:2408.00118]: 42L, d=3584, 16H (GQA kv=8, head 256),
GeGLU d_ff=14336, vocab 256000; alternating local(4096)/global attention,
attention softcap 50, final-logit softcap 30, pre+post RMSNorms, tied
embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab=256000,
    ffn_kind="geglu",
    local_window=4096,
    block_pattern=("attn_local", "attn_dense"),
    attn_softcap=50.0,
    logit_softcap=30.0,
    post_norms=True,
    tie_embeddings=True,
    loss_chunk=512,
)
