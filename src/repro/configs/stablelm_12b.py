"""StableLM-2-12B [hf:stabilityai]: 40L, d=5120, 32H (GQA kv=8),
d_ff=13824 (SwiGLU), vocab 100352.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    block_pattern=("attn_dense",),
    loss_chunk=512,
)
