"""DeepSeek-V3 671B [arXiv:2412.19437]: 61L, d=7168, 128 MLA heads,
MoE 1 shared + 256 routed top-8 (d_ff_expert=2048), first 3 layers dense
(d_ff=18432), vocab 129280, MTP.

Experts are sharded over (data, model) = 256-way EP: each chip owns exactly
one routed expert on the single-pod mesh.
"""
from repro.models.config import MLAConfig, ModelConfig, MoEConfig, ShardingRules

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    d_ff_dense=18432,
    vocab=129280,
    prefix_blocks=("mla_dense",) * 3,
    block_pattern=("attn_moe",),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048, num_shared=1,
                  d_ff_shared=2048, ep_axes=("data", "model"),
                  capacity_factor=1.25),
    mtp=True,
    rope_theta=10000.0,
    loss_chunk=512,
    sharding=ShardingRules(expert=("data", "model")),
)
