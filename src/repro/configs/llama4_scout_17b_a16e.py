"""Llama-4 Scout 17B-active 16E [hf:meta-llama/Llama-4-Scout-17B-16E]:
48L, d=5120, 40H (GQA kv=8), MoE 16 experts top-1 + shared expert
(d_ff=8192), vocab 202048, early fusion (vision frontend stubbed —
image patches arrive as tokens in the shared vocab).
"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    block_pattern=("attn_moe",),
    moe=MoEConfig(num_experts=16, top_k=1, d_ff_expert=8192, num_shared=1,
                  d_ff_shared=8192, ep_axes=("model",),
                  capacity_factor=1.25),
    rope_theta=500000.0,
    loss_chunk=512,
)
