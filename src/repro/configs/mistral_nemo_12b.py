"""Mistral-NeMo-12B [hf:mistralai/Mistral-Nemo-Base-2407]: 40L, d=5120,
32H (GQA kv=8, head 128), SwiGLU d_ff=14336, vocab 131072, 128k context
(rope theta 1M).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1000000.0,
    block_pattern=("attn_dense",),
    loss_chunk=512,
)
