"""RecurrentGemma-2B / Griffin [arXiv:2402.19427]: 26L, d=2560, pattern
(rec, rec, local-attn) 1:2, 10 heads MQA (kv=1, head_dim 256), GeGLU
d_ff=7680, RG-LRU width 2560, local window 2048, vocab 256000.

Sub-quadratic: runs the long_500k cell.
"""
from repro.models.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    ffn_kind="geglu",
    local_window=2048,
    block_pattern=("rec", "rec", "attn_local"),
    rglru=RGLRUConfig(d_rnn=2560, conv_width=4, block_width=2560),
    tie_embeddings=True,
    loss_chunk=512,
)
