"""HuBERT X-Large [arXiv:2106.07447]: encoder-only, 48L, d=1280, 16H,
d_ff=5120 (GELU MLP), vocab 504 (k-means target clusters).

The conv waveform frontend is a stub: ``input_specs`` provides precomputed
frame embeddings [B, T, d_model]; training is masked-frame prediction.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    causal=False,
    embed_inputs=False,           # frontend stub supplies embeddings
    ffn_kind="gelu",
    block_pattern=("attn_dense",),
)
