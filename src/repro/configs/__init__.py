"""Assigned-architecture registry: ``get(name)`` -> ModelConfig.

Each architecture also declares which shape cells apply (encoder-only archs
have no decode; quadratic-attention archs skip long_500k — see DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig, SHAPES, ShapeCell

ARCHS = [
    "deepseek_v3_671b",
    "llama4_scout_17b_a16e",
    "hubert_xlarge",
    "chameleon_34b",
    "recurrentgemma_2b",
    "stablelm_12b",
    "gemma2_9b",
    "mistral_nemo_12b",
    "qwen3_1_7b",
    "xlstm_125m",
]

ALIASES = {a.replace("_", "-"): a for a in ARCHS}
ALIASES["qwen3-1.7b"] = "qwen3_1_7b"
ALIASES["llama4-scout-17b-a16e"] = "llama4_scout_17b_a16e"


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(
        f"repro.configs.{ALIASES.get(name, name)}")
    return mod.CONFIG


def shape_cells(cfg: ModelConfig) -> List[ShapeCell]:
    """The applicable (arch x shape) cells for this architecture."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if cfg.family != "encoder":
        cells.append(SHAPES["decode_32k"])
        if cfg.family in ("hybrid", "ssm", "xlstm"):
            cells.append(SHAPES["long_500k"])
    return cells


def all_cells() -> List[Tuple[str, str]]:
    out = []
    for a in ARCHS:
        cfg = get(a)
        for cell in shape_cells(cfg):
            out.append((a, cell.name))
    return out
