"""Query-expressed workloads: plans, synthetic data, oracles, delta makers.

Four workloads demonstrating that "add a workload" is now a query
expression rather than a bespoke engine path:

  * ``wordcount_query``      — ``scan -> map -> group_by(sum)``; lowers to a
    plain ``JobSpec`` whose emitted Edges are bit-for-bit identical to
    ``apps/wordcount.py`` (asserted in ``tests/test_dql_query.py``);
  * ``join_query``           — incremental equi-join of two keyed sources
    (per-user spend ⋈ visits);
  * ``windowed_query``       — sliding/tumbling window aggregation over
    timestamped events (single stage: the window is key-space expansion);
  * ``cooccurrence_query``   — adjacent-token co-occurrence counts over
    token matrices, the embedding-stats feed the dormant ``models/`` stack
    wants (vocab x vocab count table).

Every workload ships a data generator, a NumPy oracle, and a '-old'/'+new'
delta maker (the convention of ``benchmarks/common.graph_update_delta``:
'-' rows carry the previous values so tombstones route correctly).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.incremental import DeltaKV, make_delta
from repro.core.kvstore import KV, make_kv
from repro.dql.algebra import Q, scan


# ---------------------------------------------------------------------------
# wordcount as a query (parity target: apps/wordcount.py)
# ---------------------------------------------------------------------------

def wordcount_query(vocab: int) -> Q:
    """``scan(docs) -> map(ones) -> group_by(w, sum)``; lowers to a JobSpec
    emitting exactly the Edges of ``apps.wordcount.make_spec(vocab)``."""
    return (scan("docs")
            .map(lambda v: {"w": v["w"],
                            "c": jnp.ones(jnp.asarray(v["w"]).shape,
                                          jnp.float32)})
            .group_by("w", num_keys=vocab, value="c", agg="sum",
                      name="wordcount"))


# ---------------------------------------------------------------------------
# incremental equi-join: per-user spend ⋈ visits
# ---------------------------------------------------------------------------

def join_query(num_users: int) -> Q:
    return scan("spend").join(scan("visits"), num_keys=num_users,
                              name="user_join")


def join_data(num_users: int, seed: int = 0) -> Dict[str, KV]:
    rng = np.random.default_rng(seed)
    uid = np.arange(num_users, dtype=np.int32)
    spend = make_kv(uid,
                    {"amt": rng.uniform(1, 100, num_users)
                     .astype(np.float32)},
                    rng.random(num_users) < 0.9)
    visits = make_kv(uid,
                     {"n": rng.integers(1, 50, num_users)
                      .astype(np.float32)},
                     rng.random(num_users) < 0.85)
    return {"spend": spend, "visits": visits}


def join_oracle(datas: Dict[str, KV]):
    """Dense (values, valid) of spend ⋈ visits."""
    sp, vi = datas["spend"], datas["visits"]
    valid = np.asarray(sp.valid) & np.asarray(vi.valid)
    vals = {"amt": np.where(valid, np.asarray(sp.values["amt"]), 0),
            "n": np.where(valid, np.asarray(vi.values["n"]), 0)}
    return vals, valid


def join_delta(datas: Dict[str, KV], frac: float,
               seed: int = 1) -> Dict[str, DeltaKV]:
    """Mutate a fraction of each side: '-' old row, '+' new value."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, col in (("spend", "amt"), ("visits", "n")):
        kv = datas[name]
        n = kv.capacity
        k = max(1, int(n * frac))
        rows = rng.choice(n, size=k, replace=False).astype(np.int32)
        old = np.asarray(kv.values[col])[rows]
        new = rng.uniform(1, 100, k).astype(np.float32)
        dk = np.repeat(rows, 2)
        sign = np.tile(np.array([-1, 1], np.int8), k)
        buf = np.empty(2 * k, np.float32)
        buf[0::2] = old
        buf[1::2] = new
        # '-' rows of never-valid users are harmless (the engine finds no
        # preserved edge to cancel) but skew oracles; keep them anyway and
        # let apply_delta_host make the row live with the '+' value
        out[name] = make_delta(dk, {col: buf}, sign)
    return out


# ---------------------------------------------------------------------------
# windowed aggregation over timestamped events
# ---------------------------------------------------------------------------

def windowed_query(num_keys: int, *, size: int, slide: Optional[int] = None,
                   num_windows: int) -> Q:
    """Sum of ``v`` per (window, key); output space num_windows*num_keys."""
    return (scan("events")
            .window(size, slide, time="t", num_windows=num_windows)
            .group_by("k", num_keys=num_keys, value="v", agg="sum",
                      name="windowed"))


def events_data(n_events: int, num_keys: int, *, t_max: int,
                seed: int = 0) -> KV:
    rng = np.random.default_rng(seed)
    return make_kv(np.arange(n_events, dtype=np.int32),
                   {"t": rng.integers(0, t_max, n_events).astype(np.int32),
                    "k": rng.integers(0, num_keys, n_events)
                    .astype(np.int32),
                    "v": rng.uniform(0, 10, n_events).astype(np.float32)})


def windowed_oracle(kv: KV, num_keys: int, *, size: int, slide: int,
                    num_windows: int) -> np.ndarray:
    """[num_windows*num_keys] sums; row w*num_keys+k is window w, key k."""
    out = np.zeros(num_windows * num_keys, np.float64)
    t = np.asarray(kv.values["t"])
    k = np.asarray(kv.values["k"])
    v = np.asarray(kv.values["v"])
    valid = np.asarray(kv.valid)
    for i in range(kv.capacity):
        if not valid[i]:
            continue
        w = int(t[i]) // slide
        while w >= 0 and w * slide + size > t[i]:
            if w < num_windows:
                out[w * num_keys + int(k[i])] += v[i]
            w -= 1
    return out


def events_delta(kv: KV, frac: float, *, t_max: int,
                 seed: int = 1) -> DeltaKV:
    """Re-time and re-value a fraction of events ('-' old, '+' new)."""
    rng = np.random.default_rng(seed)
    n = kv.capacity
    m = max(1, int(n * frac))
    rows = rng.choice(n, size=m, replace=False).astype(np.int32)
    dk = np.repeat(rows, 2)
    sign = np.tile(np.array([-1, 1], np.int8), m)

    def pair(old, new):
        buf = np.empty(2 * m, old.dtype)
        buf[0::2] = old
        buf[1::2] = new
        return buf

    t = np.asarray(kv.values["t"])[rows]
    k = np.asarray(kv.values["k"])[rows]
    v = np.asarray(kv.values["v"])[rows]
    return make_delta(dk, {
        "t": pair(t, rng.integers(0, t_max, m).astype(np.int32)),
        "k": pair(k, k),                      # key is stable; time/value move
        "v": pair(v, rng.uniform(0, 10, m).astype(np.float32)),
    }, sign)


# ---------------------------------------------------------------------------
# co-occurrence counts (adjacent-token bigrams, vocab x vocab)
# ---------------------------------------------------------------------------

def cooccurrence_query(vocab: int) -> Q:
    """Count adjacent-token pairs over [N, L] token matrices; group key is
    the flattened pair id ``a*vocab + b`` (negative tokens mask the slot —
    the padded-fanout idiom)."""
    def pairs(v):
        w = jnp.asarray(v["w"])
        a, b = w[:, :-1], w[:, 1:]
        return {"pk": jnp.where((a >= 0) & (b >= 0),
                                a * jnp.int32(vocab) + b, -1)}
    return (scan("docs")
            .map(pairs)
            .group_by("pk", num_keys=vocab * vocab, name="cooccur"))


def cooccurrence_oracle(docs: KV, vocab: int) -> np.ndarray:
    """[vocab*vocab] bigram counts."""
    out = np.zeros(vocab * vocab, np.float64)
    w = np.asarray(docs.values["w"])
    valid = np.asarray(docs.valid)
    for i in range(docs.capacity):
        if not valid[i]:
            continue
        for a, b in zip(w[i, :-1], w[i, 1:]):
            if a >= 0 and b >= 0:
                out[int(a) * vocab + int(b)] += 1.0
    return out
