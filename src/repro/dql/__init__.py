"""repro.dql: a composable delta algebra compiled to the kernel layer.

Incremental queries as a workload family: build a plan with
:func:`scan` and the fluent operators (``map``/``filter``/``project``/
``window``/``group_by``/``join``), ``compile()`` it into a
:class:`Query` — just another :class:`repro.api.Session` kind — and
refresh it with signed deltas::

    from repro import dql
    q = (dql.scan("docs")
            .map(lambda v: {"w": v["w"], "c": ones_like(v["w"])})
            .group_by("w", num_keys=vocab, value="c")
            .compile(RunConfig(backend="xla")))
    q.run(docs_kv)
    q.update(delta)        # preserved-state, |Δ|-proportional refresh

See :mod:`repro.dql.algebra` for the operator/delta-rule table,
:mod:`repro.dql.lower` for the planner, :mod:`repro.dql.driver` for the
incremental runtime, :mod:`repro.dql.derived` for the coalescer
re-derivation, and :mod:`repro.dql.workloads` for ready-made plans.
"""
from repro.dql.algebra import AGG_KINDS, Q, explain, scan
from repro.dql.lower import QuerySpec, lower
from repro.dql.query import Query, evaluate

__all__ = ["AGG_KINDS", "Q", "Query", "QuerySpec", "evaluate", "explain",
           "lower", "scan"]
