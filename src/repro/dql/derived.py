"""The stream coalescer re-derived as a two-branch plan over the algebra.

``stream/coalesce.py`` keeps, per record id, the first row iff it is a
'-' and the last row iff it is a '+'.  That first-'-'/last-'+' rule is a
pair of grouped monoid reductions plus an equi-join — i.e. expressible in
:mod:`repro.dql` with no bespoke kernel code:

  * a **min**-aggregated ``group_by(rid)`` over four arrival-index lanes::

        a_first      = arr                       -> min = first arrival
        first_neg    = arr  if sign<0 else BIG   -> min = first '-' arrival
        a_last_neg   = -arr                      -> min = -(last arrival)
        last_pos_neg = -arr if sign>0 else BIG   -> min = -(last '+' arrival)

    The first row of record r is a '-' iff ``min(first_neg) ==
    min(a_first)`` (the earliest '-' *is* the earliest row); symmetrically
    the last row is a '+' iff ``min(last_pos_neg) == min(a_last_neg)``.

  * a **sum**-aggregated ``group_by(rid)`` of the signs — the net row
    balance (+1 insert / -1 delete / 0 update), which is exactly the
    ``n_inserts``/``n_deletes`` telemetry.

  * an equi-``join`` of the two branches on rid, giving one relation row
    per touched record carrying both the keep flags and the net balance.

:func:`coalesce_rows_dql` evaluates that plan (storelessly, via
:func:`repro.dql.query.evaluate` -> ``ops.group_reduce``) and decodes a
:class:`~repro.stream.coalesce.CoalesceResult` that is *bit-for-bit* what
``coalesce_rows`` produces on the same batch (asserted in
``tests/test_dql_coalesce.py``).  One honest divergence: the algebra's
group_by is dense, so this version needs a record-id space bound
(``num_records``); the production kernel sorts arbitrary int32 ids.  The
production path stays — this module exists to prove subsumption.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.kvstore import make_kv
from repro.dql.algebra import Q, scan
from repro.dql.query import evaluate
from repro.stream.coalesce import CoalesceResult, make_delta

_BIG = np.int32(2 ** 30)       # > any in-batch arrival index


def coalesce_plan(num_records: int) -> Q:
    """The first-'-'/last-'+' rule as a plan: two group_bys joined on rid."""
    rows = scan("rows")
    ends = (rows
            .map(lambda v: {
                "rid": v["rid"],
                "a_first": v["arr"],
                "first_neg": jnp.where(v["sign"] < 0, v["arr"], _BIG),
                "a_last_neg": -v["arr"],
                "last_pos_neg": jnp.where(v["sign"] > 0, -v["arr"], _BIG),
            })
            .group_by("rid", num_keys=num_records, agg="min",
                      value={n: n for n in ("a_first", "first_neg",
                                            "a_last_neg", "last_pos_neg")},
                      name="ends"))
    nets = rows.group_by(
        "rid", num_keys=num_records, agg="sum",
        value={"net": lambda v: v["sign"].astype(jnp.int32)},
        name="nets")
    return ends.join(nets, name="coalesce")


def coalesce_rows_dql(record_ids: np.ndarray, values: Dict[str, np.ndarray],
                      sign: np.ndarray, *,
                      num_records: Optional[int] = None,
                      backend: Optional[str] = None) -> CoalesceResult:
    """Drop-in for :func:`repro.stream.coalesce.coalesce_rows`, evaluated
    through the delta algebra (dense rid space of size ``num_records``)."""
    record_ids = np.asarray(record_ids, np.int32)
    sign = np.asarray(sign, np.int8)
    n = int(record_ids.shape[0])
    if n == 0:
        return CoalesceResult(None, 0, 0, 0, 0, 0)
    if num_records is None:
        num_records = int(record_ids.max()) + 1

    data = make_kv(np.arange(n, dtype=np.int32),
                   {"rid": record_ids,
                    "arr": np.arange(n, dtype=np.int32),
                    "sign": sign.astype(np.int32)})
    vals, valid = evaluate(coalesce_plan(num_records), {"rows": data},
                           backend=backend)

    live = np.nonzero(valid)[0]           # touched rids, ascending
    a_first = vals["a_first"][live]
    a_last = -vals["a_last_neg"][live]
    keep_f = vals["first_neg"][live] == a_first
    keep_l = vals["last_pos_neg"][live] == vals["a_last_neg"][live]
    net = vals["net"][live]

    n_records = int(live.size)
    n_inserts = int((net > 0).sum())
    n_deletes = int((net < 0).sum())

    # surviving rows in (rid, arrival) order — the production kernel's
    # perm[keep] order (within a record the kept first precedes the kept
    # last; keeping both implies two distinct rows)
    rid_rep = np.concatenate([live[keep_f], live[keep_l]])
    arr_rep = np.concatenate([a_first[keep_f], a_last[keep_l]])
    order = np.lexsort((arr_rep, rid_rep))
    sel = arr_rep[order].astype(np.int64)
    if sel.size == 0:
        return CoalesceResult(None, n, 0, n_records, n_inserts, n_deletes)
    delta = make_delta(record_ids[sel],
                       {nm: np.asarray(a)[sel] for nm, a in values.items()},
                       sign[sel])
    return CoalesceResult(delta, n, int(sel.size), n_records, n_inserts,
                          n_deletes)
