"""Planner: fuse stateless chains, lower stateful ops to engine stages.

A plan tree lowers to a DAG of *stages*, one per stateful operator
(``group_by`` / ``join``).  Each stage is exactly one engine job — a
``JobSpec`` whose Map function applies the fused stateless chain
(map/filter/project/window) and emits signed (K2, MK, V2) edges — so both
the initial evaluation and every incremental refresh ride the existing
kernel layer unchanged: ``run_onestep`` (sort_pairs + segment_reduce) for
the first run, ``incremental_onestep`` (shuffle_reduce against the stage's
own ``MRBGStore`` slice) for ``Query.update()``.

Single-pipeline plans (``scan -> chain -> group_by``) lower all the way to
a plain :class:`repro.core.engine.JobSpec`: such a query is
indistinguishable from a hand-written app (``apps/wordcount.py`` parity is
bit-for-bit because the emitted Edges are identical arrays).  Anything
with multiple stages, a join, or a trailing stateless chain lowers to a
:class:`QuerySpec` driven by :class:`repro.dql.driver._QueryDriver`.

Lowering choices:

  * **MK discipline** — stage inputs are keyed so the engine's
    ``make_mk(record_id, slot, fanout)`` stays globally unique and stable
    across epochs: group stages use the upstream key as record id
    (mk == key * fanout + slot); join stages use ``key*2 + side`` so each
    side of a key owns one Map instance and a '-'/'+' pair from either
    side tombstones exactly its own preserved edges.
  * **join as one keyed merge** — both sides' rows emit into the group of
    their join key with per-side presence lanes (``_pl``/``_pr``, summed);
    a key is in the join output iff both lanes are positive.  The three
    delta terms of Δ(R ⋈ S) collapse into the engine's affected-key
    re-reduce against preserved edges.
  * **window as key-space expansion** — a row fans out (static fanout
    ceil(size/slide)) to composite keys ``window * num_keys + key`` before
    the grouped reduce; num_windows bounds the dense output space.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax.numpy as jnp

from repro.core.engine import JobSpec, emit_multi, emit_single
from repro.core.kvstore import (
    Reducer, max_reducer, mean_reducer, min_reducer, sum_reducer,
)
from repro.dql.algebra import (
    Filter, GroupBy, Join, Map, Node, Project, Scan, Window, explain,
)

_REDUCERS = {"sum": sum_reducer, "min": min_reducer, "max": max_reducer,
             "mean": mean_reducer}

# ref to a stage input: ("source", name) | ("stage", index)
Ref = Tuple[str, Any]


# ---------------------------------------------------------------------------
# Lowered specs
# ---------------------------------------------------------------------------

@dataclass
class InputPlan:
    """One upstream feed of a stage."""

    ref: Ref
    side: Optional[int] = None        # 0/1 for join sides, None for group


@dataclass
class StagePlan:
    """One stateful stage: exactly one engine job over a dense key space."""

    name: str
    kind: str                         # "group" | "join"
    num_keys: int
    reducer: Reducer
    map_fn: Callable                  # fused chain + emit; stable object
    inputs: Tuple[InputPlan, ...]
    having: Optional[Callable] = None  # values-dict -> bool [K] relation mask
    out_cols: Optional[Tuple[str, ...]] = None   # None: resolved at runtime


@dataclass
class QuerySpec:
    """A lowered multi-stage delta query; ``repro.api.Session`` accepts it
    exactly like a ``JobSpec``/``IterSpec`` (driver kind ``"query"``)."""

    name: str
    stages: Tuple[StagePlan, ...]
    sources: Tuple[str, ...]
    out_stage: int
    sink: Tuple[tuple, ...] = ()      # stateless chain applied to the output

    def __repr__(self) -> str:
        return (f"QuerySpec({self.name!r}, {len(self.stages)} stages, "
                f"sources={list(self.sources)})")


# ---------------------------------------------------------------------------
# Fused stateless chains
# ---------------------------------------------------------------------------

def apply_chain(chain, values, valid):
    """Run a fused stateless chain on (values pytree, valid mask).

    Pure jnp when traced inside a Map function; also accepts numpy arrays
    (the sink chain runs host-side on the dense relation).
    """
    for kind, arg in chain:
        if kind == "map":
            values = dict(arg(values))
        elif kind == "filter":
            valid = valid & jnp.asarray(arg(values), jnp.bool_)
        elif kind == "project":
            values = {n: values[n] for n in arg}
        else:                          # pragma: no cover
            raise ValueError(f"unknown chain op {kind!r}")
    return values, valid


def _key_of(key, values):
    keys = jnp.asarray(values[key] if isinstance(key, str) else key(values))
    if keys.dtype != jnp.int32:
        keys = keys.astype(jnp.int32)
    return keys


def _value_of(spec, values, keys):
    """Materialize one value column, broadcast to the emission key shape."""
    if isinstance(spec, str):
        v = jnp.asarray(values[spec])
    elif callable(spec):
        v = jnp.asarray(spec(values))
    else:                              # numeric constant (bare count)
        return jnp.full(keys.shape, spec, jnp.float32)
    if keys.ndim == 2 and (v.ndim < 2 or v.shape[:2] != keys.shape):
        # per-row value fanned out across the key slots
        v = jnp.broadcast_to(v[:, None], keys.shape[:2] + v.shape[1:])
    return v


# ---------------------------------------------------------------------------
# Map-function builders (one closure per stage; object identity is what
# keys the jit caches, so each is built exactly once at lowering time)
# ---------------------------------------------------------------------------

def _build_group_map(chain, window: Optional[Window], gb: GroupBy):
    value_specs = dict(gb.value)
    key_spec = gb.key
    if window is not None:
        n_win = max(1, math.ceil(window.size / window.slide))

    def map_fn(kv, sign):
        vals, valid = apply_chain(chain, kv.values, kv.valid)
        keys = _key_of(key_spec, vals)
        if window is not None:
            if keys.ndim != 1:
                raise ValueError("windowed group_by needs a per-row key")
            t = jnp.asarray(vals[window.time]).astype(jnp.int32)
            wins = (t // window.slide)[:, None] - \
                jnp.arange(n_win, dtype=jnp.int32)[None, :]
            in_win = ((wins >= 0) & (wins < window.num_windows) &
                      (t[:, None] < wins * window.slide + window.size))
            keys = wins * jnp.int32(gb.num_keys) + keys[:, None]
            v2 = {n: _value_of(s, vals, keys)
                  for n, s in value_specs.items()}
            slot_valid = valid[:, None] & in_win & (keys >= 0)
            return emit_multi(keys, v2, kv.keys, slot_valid,
                              record_sign=sign)
        v2 = {n: _value_of(s, vals, keys) for n, s in value_specs.items()}
        if keys.ndim == 1:
            return emit_single(keys, v2, kv.keys, valid & (keys >= 0),
                               record_sign=sign)
        return emit_multi(keys, v2, kv.keys,
                          valid[:, None] & (keys >= 0), record_sign=sign)

    return map_fn


def _build_join_map(lchain, rchain, jn: Join):
    lpfx, rpfx = jn.lprefix, jn.rprefix

    def _mask(a, m):
        return jnp.where(m.reshape((-1,) + (1,) * (a.ndim - 1)),
                         jnp.asarray(a), 0)

    def map_fn(kv, sign):
        vals = kv.values
        is_l = jnp.asarray(vals["_side"]) == 0
        lv, lvalid = apply_chain(lchain, vals["_l"], kv.valid)
        rv, rvalid = apply_chain(rchain, vals["_r"], kv.valid)
        overlap = {lpfx + n for n in lv} & {rpfx + n for n in rv}
        if overlap:
            raise ValueError(
                f"join output columns collide: {sorted(overlap)}; "
                f"disambiguate with lprefix=/rprefix=")
        valid = kv.valid & jnp.where(is_l, lvalid, rvalid)
        out = {lpfx + n: _mask(a, is_l) for n, a in lv.items()}
        out.update({rpfx + n: _mask(a, ~is_l) for n, a in rv.items()})
        # per-side presence lanes: a key is in the join iff both sum > 0
        out["_pl"] = jnp.where(is_l, 1, 0).astype(jnp.int32)
        out["_pr"] = jnp.where(is_l, 0, 1).astype(jnp.int32)
        return emit_single(kv.keys // 2, out, kv.keys, valid,
                           record_sign=sign)

    return map_fn


def _join_having(values) -> Any:
    return (values["_pl"] > 0) & (values["_pr"] > 0)


# ---------------------------------------------------------------------------
# The lowering walk
# ---------------------------------------------------------------------------

def lower(root: Node) -> Union[JobSpec, QuerySpec]:
    """Lower a plan tree to a ``JobSpec`` (single pipeline) or ``QuerySpec``."""
    stages: List[StagePlan] = []
    seen: Dict[int, Ref] = {}         # stateful node id -> stage ref (DAG)
    sources: List[str] = []

    def visit(node: Node) -> Tuple[Ref, list]:
        if isinstance(node, Scan):
            if node.source not in sources:
                sources.append(node.source)
            return ("source", node.source), []
        if isinstance(node, Map):
            ref, chain = visit(node.parent)
            return ref, chain + [("map", node.fn)]
        if isinstance(node, Filter):
            ref, chain = visit(node.parent)
            return ref, chain + [("filter", node.pred)]
        if isinstance(node, Project):
            ref, chain = visit(node.parent)
            return ref, chain + [("project", node.cols)]
        if isinstance(node, Window):
            ref, chain = visit(node.parent)
            return ref, chain + [("window", node)]
        if id(node) in seen:          # shared subplan: one stage, many readers
            return seen[id(node)], []
        if isinstance(node, GroupBy):
            ref, chain = visit(node.parent)
            chain, window = _pop_window(chain, node.name)
            plan = StagePlan(
                name=node.name, kind="group", num_keys=_total_keys(node, window),
                reducer=_REDUCERS[node.agg](),
                map_fn=_build_group_map(tuple(chain), window, node),
                inputs=(InputPlan(ref),),
                out_cols=tuple(node.value.keys()))
            stages.append(plan)
            out = ("stage", len(stages) - 1)
            seen[id(node)] = out
            return out, []
        if isinstance(node, Join):
            lref, lchain = visit(node.left)
            rref, rchain = visit(node.right)
            for ch, side in ((lchain, "left"), (rchain, "right")):
                if any(k == "window" for k, _ in ch):
                    raise ValueError(f"window on the {side} side of a join "
                                     f"must be followed by a group_by")
            plan = StagePlan(
                name=node.name, kind="join", num_keys=node.num_keys,
                reducer=sum_reducer(),
                map_fn=_build_join_map(tuple(lchain), tuple(rchain), node),
                inputs=(InputPlan(lref, 0), InputPlan(rref, 1)),
                having=_join_having)
            stages.append(plan)
            out = ("stage", len(stages) - 1)
            seen[id(node)] = out
            return out, []
        raise TypeError(f"unknown plan node {type(node).__name__}")

    ref, sink = visit(root)
    if ref[0] == "source":
        raise ValueError(
            f"a query needs at least one group_by or join; got only "
            f"stateless operators over scan({ref[1]!r})")
    if any(k == "window" for k, _ in sink):
        raise ValueError("a trailing window must be followed by a group_by")

    out_idx = ref[1]
    name = stages[out_idx].name

    # single source->chain->group_by pipeline with nothing after it lowers
    # to a plain JobSpec: the query is just another engine app
    if (len(stages) == 1 and not sink and stages[0].kind == "group"
            and stages[0].inputs[0].ref[0] == "source"
            and stages[0].having is None):
        st = stages[0]
        return JobSpec(st.map_fn, st.reducer, st.num_keys, st.name)

    return QuerySpec(name=name, stages=tuple(stages),
                     sources=tuple(sources), out_stage=out_idx,
                     sink=tuple(sink))


def sources_of(node: Node) -> Tuple[str, ...]:
    """Scan names of a plan, in first-reference order."""
    out: List[str] = []

    def walk(n: Node) -> None:
        if isinstance(n, Scan):
            if n.source not in out:
                out.append(n.source)
        elif isinstance(n, Join):
            walk(n.left)
            walk(n.right)
        elif isinstance(n, (Map, Filter, Project, Window, GroupBy)):
            walk(n.parent)

    walk(node)
    return tuple(out)


def _pop_window(chain: list, name: str) -> Tuple[list, Optional[Window]]:
    """A window annotation must sit at the tail of the chain feeding the
    group_by that consumes it."""
    window = None
    if chain and chain[-1][0] == "window":
        window = chain[-1][1]
        chain = chain[:-1]
    if any(k == "window" for k, _ in chain):
        raise ValueError(f"window feeding {name!r} must be the last "
                         f"stateless operator before the group_by")
    return chain, window


def _total_keys(gb: GroupBy, window: Optional[Window]) -> int:
    if window is None:
        return gb.num_keys
    return gb.num_keys * window.num_windows
