"""Query runtime: per-stage incremental state on the existing engine.

A lowered :class:`~repro.dql.lower.QuerySpec` runs as a DAG of engine
jobs.  Each stage owns

  * a ``JobSpec`` (built once — the map_fn / reducer objects are the jit
    cache keys, so refreshes never retrace in steady state),
  * its own :class:`~repro.core.mrbg_store.MRBGStore` slice preserving the
    stage's fine-grain MRBGraph edges, and
  * a :class:`RecordingView` — a ``ResultView`` that remembers which keys
    each ``incremental_onestep`` patch touched and what they held before.

Change propagation *is* the delta algebra: after a stage refreshes, the
recorded (key, old value, old valid) triples become the downstream signed
rows — '-' rows carrying the previous relation values (so computed keys
and filters in the consumer's fused chain route the tombstone correctly)
followed by '+' rows with the new values.  A stage whose inputs produced
no rows this batch is skipped outright.

Host <-> device encoding mirrors ``Session.update()``'s bucketed ladder
(`next_bucket`, ``RunConfig.delta_bucket_min``): every synthesized feed is
padded up a geometric capacity ladder so steady-state refreshes reuse
compiled executables (zero steady retraces, witnessed in
``tests/test_dql_query.py`` via ``jitcache.generation()``).

:func:`evaluate` is the storeless one-shot path: the same fused map
functions feed :func:`repro.kernels.ops.group_reduce` directly — used by
``dql.derived`` (the re-derived coalescer) where preserving state across
batches would be pure overhead.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import JobSpec, run_onestep
from repro.core.incremental import (
    DeltaKV, ResultView, _v2_dict, incremental_onestep, make_delta,
    pad_delta,
)
from repro.core.kvstore import (
    KV, edges_to_host, finalize_reduce, make_kv, next_bucket,
)
from repro.core.mrbg_store import IOStats, MRBGStore
from repro.dql.lower import QuerySpec, StagePlan, apply_chain
from repro.kernels import ops

Schema = Dict[str, Tuple[tuple, str]]      # col -> (row shape, dtype str)


# ---------------------------------------------------------------------------
# RecordingView: ResultView that captures pre-patch state for propagation
# ---------------------------------------------------------------------------

class RecordingView(ResultView):
    """Dense stage output that records what each patch overwrote."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._changes: list = []

    def patch(self, keys, values, counts) -> None:
        keys = np.asarray(keys)
        k = keys[keys < self.num_keys]
        old_vals = {n: a[k].copy() for n, a in self.values.items()}
        old_valid = self.valid[k].copy()
        super().patch(keys, values, counts)
        self._changes.append((k, old_vals, old_valid))

    def take_changes(self):
        """(keys, old values, old valid) since the last take, or None."""
        if not self._changes:
            return None
        ch, self._changes = self._changes, []
        keys = np.concatenate([c[0] for c in ch])
        vals = {n: np.concatenate([c[1][n] for c in ch]) for n in ch[0][1]}
        valid = np.concatenate([c[2] for c in ch])
        return keys, vals, valid


# ---------------------------------------------------------------------------
# Feed encoders (host side; shared by the driver and evaluate())
# ---------------------------------------------------------------------------

def _schema_of(values) -> Schema:
    return {n: (tuple(np.asarray(a).shape[1:]), str(np.asarray(a).dtype))
            for n, a in values.items()}


def _zeros_cols(schema: Schema, cap: int) -> Dict[str, np.ndarray]:
    return {n: np.zeros((cap,) + shape, dtype=np.dtype(dt))
            for n, (shape, dt) in schema.items()}


def _rows_of_delta(delta: DeltaKV):
    """Valid rows of a user DeltaKV as host (keys, values, sign)."""
    rows = np.nonzero(np.asarray(delta.valid))[0]
    keys = np.asarray(delta.keys)[rows].astype(np.int32)
    vals = {n: np.asarray(a)[rows] for n, a in delta.values.items()}
    sign = np.asarray(delta.sign)[rows]
    return keys, vals, sign


def _encode_group_rows(rows, bucket_min: int) -> DeltaKV:
    """Signed relation rows -> a bucket-padded DeltaKV for a group stage.

    The relation key doubles as the record id so the preserved edge of a
    key is tombstoned by exactly that key's '-' row (mk == key)."""
    keys, vals, sign = rows
    n = len(keys)
    cap = next_bucket(max(n, 1), bucket_min)
    k = np.zeros(cap, np.int32)
    k[:n] = keys
    valid = np.zeros(cap, np.bool_)
    valid[:n] = True
    sg = np.ones(cap, np.int8)
    sg[:n] = sign
    buf = {}
    for c, a in vals.items():
        a = np.asarray(a)
        buf[c] = np.zeros((cap,) + a.shape[1:], a.dtype)
        buf[c][:n] = a
    return make_delta(k, buf, sg, keys=k, valid=valid)


def _fill_join_rows(sides, schemas: List[Schema], cap: int):
    """Lay out per-side row blocks in the union-schema join encoding:
    key' = key*2 + side, off-side columns zero-filled from the captured
    schema so the pytree structure is identical whichever side feeds."""
    keys = np.zeros(cap, np.int32)
    side_lane = np.zeros(cap, np.int32)
    valid = np.zeros(cap, np.bool_)
    sign = np.ones(cap, np.int8)
    lcols = _zeros_cols(schemas[0], cap)
    rcols = _zeros_cols(schemas[1], cap)
    pos = 0
    for s, (k, vals, sg, vmask) in sides:
        m = len(k)
        sl = slice(pos, pos + m)
        keys[sl] = np.where(vmask, k, 0) * 2 + s
        side_lane[sl] = s
        valid[sl] = vmask
        sign[sl] = sg
        tgt = lcols if s == 0 else rcols
        for c, a in vals.items():
            if c not in tgt:
                raise KeyError(
                    f"join side {s} fed unknown column {c!r}; the side's "
                    f"schema (captured at Query.run) has {sorted(tgt)}")
            tgt[c][sl] = np.asarray(a)
        pos += m
    values = {"_l": lcols, "_r": rcols, "_side": side_lane}
    return keys, values, valid, sign


def _encode_join_kv(sides, schemas) -> KV:
    """Initial (full) input of a join stage: both sides' full row sets."""
    total = sum(len(s[1][0]) for s in sides)
    keys, values, valid, _ = _fill_join_rows(sides, schemas, max(total, 1))
    return make_kv(keys, values, valid)


def _encode_join_feed(feeds, schemas, bucket_min: int) -> DeltaKV:
    """Signed per-side feeds -> one bucket-padded DeltaKV."""
    sides = []
    for s, (k, vals, sg) in feeds:
        sides.append((s, (k, vals, sg, np.ones(len(k), np.bool_))))
    total = sum(len(f[1][0]) for f in feeds)
    cap = next_bucket(max(total, 1), bucket_min)
    keys, values, valid, sign = _fill_join_rows(sides, schemas, cap)
    return make_delta(keys, values, sign, keys=keys, valid=valid)


# ---------------------------------------------------------------------------
# Per-stage runtime
# ---------------------------------------------------------------------------

class _StageRT:
    """One stage's live state: JobSpec + MRBGStore slice + RecordingView."""

    def __init__(self, plan: StagePlan, cfg):
        self.plan = plan
        self.cfg = cfg
        # built once: the (map_fn, reducer) objects key the jit caches
        self.spec = JobSpec(plan.map_fn, plan.reducer, plan.num_keys,
                            plan.name)
        self.store = self._fresh_store()
        self.view: Optional[RecordingView] = None
        self.schemas: List[Optional[Schema]] = [None] * len(plan.inputs)

    def _fresh_store(self) -> MRBGStore:
        return MRBGStore(self.plan.num_keys, self.cfg.value_bytes,
                         policy=self.cfg.store_policy, **self.cfg.store_kw())

    def run_initial(self, kv: KV) -> None:
        self.store = self._fresh_store()
        res = run_onestep(self.spec, kv, preserve=True,
                          backend=self.cfg.backend)
        host = edges_to_host(res.edges)
        self.store.append(host["k2"], host["mk"], _v2_dict(host["v2"]))
        self.view = RecordingView.from_job(self.plan.num_keys, res.results,
                                           res.counts)

    def update(self, enc: DeltaKV) -> dict:
        self.store.reset_stats()
        return incremental_onestep(self.spec, enc, self.store, self.view,
                                   backend=self.cfg.backend)

    # -- the stage's *relation* (view masked by having) --------------------
    def visible(self) -> List[str]:
        return [n for n in self.view.values if not n.startswith("_")]

    def rel_valid(self) -> np.ndarray:
        v = self.view.valid
        if self.plan.having is not None:
            v = v & np.asarray(self.plan.having(self.view.values))
        return v

    def take_rows(self):
        """Signed downstream rows from the patches of the last update.

        For every touched key whose relation row was live before, emit a
        '-' row with the old values; for every key live after, a '+' row
        with the new values.  Consumers see a plain signed-relation delta.
        """
        ch = self.view.take_changes() if self.view is not None else None
        if ch is None:
            return None
        keys, old_vals, old_valid = ch
        old_rv = old_valid
        if self.plan.having is not None:
            old_rv = old_rv & np.asarray(self.plan.having(old_vals))
        new_vals = {n: self.view.values[n][keys]
                    for n in self.view.values}
        new_rv = self.view.valid[keys]
        if self.plan.having is not None:
            new_rv = new_rv & np.asarray(self.plan.having(new_vals))
        out_keys = np.concatenate([keys[old_rv], keys[new_rv]])
        if out_keys.size == 0:
            return None
        vis = self.visible()
        out_vals = {n: np.concatenate([old_vals[n][old_rv],
                                       new_vals[n][new_rv]]) for n in vis}
        sign = np.concatenate([
            np.full(int(old_rv.sum()), -1, np.int8),
            np.ones(int(new_rv.sum()), np.int8)])
        return out_keys.astype(np.int32), out_vals, sign


# ---------------------------------------------------------------------------
# The Session driver (kind = "query")
# ---------------------------------------------------------------------------

class _QueryDriver:
    """Drives a QuerySpec through the uniform Session protocol."""

    kind = "query"

    def __init__(self, spec: QuerySpec, cfg):
        self.spec = spec
        self.cfg = cfg
        self.stages = [_StageRT(p, cfg) for p in spec.stages]
        self.mode = "query"
        self._affected = -1

    def backend(self) -> str:
        return ops.resolve_backend(self.cfg.backend)

    @property
    def stores(self) -> List[MRBGStore]:
        return [st.store for st in self.stages]

    @property
    def view(self):
        return self.stages[self.spec.out_stage].view

    # -- full evaluation ---------------------------------------------------
    def run(self, data) -> None:
        datas = self._norm_sources(data, KV, "run")
        for st in self.stages:
            kv = self._full_input(st, datas)
            st.run_initial(kv)
            if st.view is not None:
                st.view.take_changes()       # initial run is not a delta
        self._affected = -1
        self.mode = "query"

    def _full_input(self, st: _StageRT, datas) -> KV:
        plan = st.plan
        if plan.kind == "group":
            (ip,) = plan.inputs
            if ip.ref[0] == "source":
                kv = datas[ip.ref[1]]
                st.schemas[0] = _schema_of(kv.values)
                return kv
            parent = self.stages[ip.ref[1]]
            st.schemas[0] = _schema_of(
                {n: parent.view.values[n] for n in parent.visible()})
            return self._rel_kv(parent)
        sides = []
        for i, ip in enumerate(plan.inputs):
            if ip.ref[0] == "source":
                kv = datas[ip.ref[1]]
                vals = {n: np.asarray(a) for n, a in kv.values.items()}
                st.schemas[i] = _schema_of(vals)
                sides.append((ip.side, (np.asarray(kv.keys), vals,
                                        np.ones(kv.capacity, np.int8),
                                        np.asarray(kv.valid))))
            else:
                parent = self.stages[ip.ref[1]]
                vals = {n: parent.view.values[n] for n in parent.visible()}
                st.schemas[i] = _schema_of(vals)
                valid = parent.rel_valid()
                sides.append((ip.side, (
                    np.arange(parent.plan.num_keys, dtype=np.int32), vals,
                    np.ones(parent.plan.num_keys, np.int8), valid)))
        return _encode_join_kv(sides, st.schemas)

    @staticmethod
    def _rel_kv(parent: _StageRT) -> KV:
        vals = {n: parent.view.values[n] for n in parent.visible()}
        return make_kv(np.arange(parent.plan.num_keys, dtype=np.int32),
                       vals, parent.rel_valid())

    # -- incremental refresh -------------------------------------------------
    def update(self, delta) -> None:
        datas = self._norm_sources(delta, DeltaKV, "update")
        affected = 0
        stage_rows: Dict[int, Any] = {}
        for idx, st in enumerate(self.stages):
            enc = self._delta_input(st, datas, stage_rows)
            if enc is None:
                stage_rows[idx] = None
                continue
            stats = st.update(enc)
            affected += int(stats.get("affected", 0))
            stage_rows[idx] = st.take_rows()
        self._affected = affected
        self.mode = "query-incremental"

    def _delta_input(self, st: _StageRT, datas, stage_rows):
        plan = st.plan
        if plan.kind == "group":
            (ip,) = plan.inputs
            if ip.ref[0] == "source":
                d = datas.get(ip.ref[1])
                return None if d is None else self._pad(d)
            rows = stage_rows.get(ip.ref[1])
            return None if rows is None else _encode_group_rows(
                rows, self.cfg.delta_bucket_min)
        feeds = []
        for i, ip in enumerate(plan.inputs):
            if ip.ref[0] == "source":
                d = datas.get(ip.ref[1])
                if d is not None:
                    feeds.append((ip.side, _rows_of_delta(d)))
            else:
                rows = stage_rows.get(ip.ref[1])
                if rows is not None:
                    feeds.append((ip.side, rows))
        if not feeds:
            return None
        return _encode_join_feed(feeds, st.schemas,
                                 self.cfg.delta_bucket_min)

    def _pad(self, delta: DeltaKV) -> DeltaKV:
        cap = next_bucket(delta.capacity, self.cfg.delta_bucket_min)
        return delta if cap == delta.capacity else pad_delta(delta, cap)

    def _norm_sources(self, data, leaf_cls, what: str) -> dict:
        srcs = self.spec.sources
        if isinstance(data, leaf_cls):
            if len(srcs) != 1:
                raise ValueError(
                    f"{what}() on a {len(srcs)}-source query needs a dict "
                    f"{{source: {leaf_cls.__name__}}}; sources: {list(srcs)}")
            return {srcs[0]: data}
        if not isinstance(data, dict):
            raise TypeError(
                f"{what}() takes a {leaf_cls.__name__} or a dict keyed by "
                f"source name, got {type(data).__name__}")
        unknown = set(data) - set(srcs)
        if unknown:
            raise ValueError(f"unknown sources {sorted(unknown)}; "
                             f"this query reads {list(srcs)}")
        if what == "run" and set(data) != set(srcs):
            raise ValueError(f"run() needs every source; missing "
                             f"{sorted(set(srcs) - set(data))}")
        return dict(data)

    # -- output / reporting --------------------------------------------------
    def relation(self):
        """(values, valid) of the output relation after the sink chain."""
        st = self.stages[self.spec.out_stage]
        vals = {n: np.array(st.view.values[n]) for n in st.visible()}
        valid = st.rel_valid().copy()
        if self.spec.sink:
            vals, valid = apply_chain(self.spec.sink, vals, valid)
            vals = {n: np.asarray(a) for n, a in vals.items()}
            valid = np.asarray(valid)
        return vals, valid

    def result(self) -> Dict[str, np.ndarray]:
        vals, valid = self.relation()
        return {n: np.where(valid.reshape((-1,) + (1,) * (a.ndim - 1)),
                            a, 0) for n, a in vals.items()}

    def fill(self, rep) -> None:
        st = self.stages[self.spec.out_stage]
        rep.counts = None if st.view is None else st.view.counts
        rep.affected_keys = self._affected
        io = IOStats()
        for s in self.stages:
            io.add(s.store.stats)
        rep.io = io
        rep.store_bytes = sum(s.store.file_bytes() for s in self.stages)
        rep.live_bytes = sum(s.store.live_bytes() for s in self.stages)
        rep.store_batches = sum(s.store.n_batches for s in self.stages)


# ---------------------------------------------------------------------------
# Eager one-shot evaluation (no preserved state) via ops.group_reduce
# ---------------------------------------------------------------------------

def _eval_static(plan: StagePlan, backend: Optional[str]):
    return (plan.map_fn, plan.reducer, plan.num_keys,
            ops.resolve_backend(backend))


@functools.partial(jax.jit, static_argnums=0)
def _eval_stage(static, kv: KV):
    map_fn, reducer, num_keys, bk = static
    sign = jnp.ones(kv.capacity, jnp.int8)
    edges = map_fn(kv, sign)
    acc, counts = ops.group_reduce(reducer, edges.k2, edges.v2,
                                   edges.valid & (edges.sign > 0),
                                   num_keys, backend=bk)
    keys = jnp.arange(num_keys, dtype=jnp.int32)
    return finalize_reduce(reducer, keys, acc, counts), counts


def evaluate(spec: Union[JobSpec, QuerySpec], data, *,
             backend: Optional[str] = None):
    """Evaluate a lowered spec once, storelessly.

    Returns ``(values, valid)`` of the output relation.  The same fused
    map functions the incremental driver uses feed
    :func:`repro.kernels.ops.group_reduce` directly — no MRBG store, no
    view, no preserved edges; right when the caller will never refresh
    (e.g. the derived per-batch coalescer in :mod:`repro.dql.derived`).
    """
    if isinstance(spec, JobSpec):
        if isinstance(data, dict):        # single-pipeline plan, named scan
            if len(data) != 1:
                raise ValueError("a JobSpec-lowered plan reads one source; "
                                 f"got {sorted(data)}")
            (data,) = data.values()
        spec = QuerySpec(name=spec.name,
                         stages=(StagePlan(
                             name=spec.name, kind="group",
                             num_keys=spec.num_keys, reducer=spec.reducer,
                             map_fn=spec.map_fn,
                             inputs=_sole_source_inputs(),
                         ),),
                         sources=("input",), out_stage=0)
    datas = {}
    if isinstance(data, KV):
        if len(spec.sources) != 1:
            raise ValueError("multi-source query: pass {source: KV}")
        datas = {spec.sources[0]: data}
    else:
        datas = dict(data)
    rels: Dict[int, Tuple[dict, np.ndarray]] = {}
    for idx, plan in enumerate(spec.stages):
        kv = _eval_input(plan, datas, rels)
        vals, counts = _eval_stage(_eval_static(plan, backend), kv)
        vals = {n: np.asarray(a) for n, a in vals.items()}
        counts = np.asarray(counts)
        valid = counts > 0
        if plan.having is not None:
            valid = valid & np.asarray(plan.having(vals))
        rels[idx] = (vals, valid)
    vals, valid = rels[spec.out_stage]
    vals = {n: a for n, a in vals.items() if not n.startswith("_")}
    if spec.sink:
        vals, valid = apply_chain(spec.sink, vals, valid)
        vals = {n: np.asarray(a) for n, a in vals.items()}
        valid = np.asarray(valid)
    return vals, valid


def _sole_source_inputs():
    from repro.dql.lower import InputPlan
    return (InputPlan(("source", "input")),)


def _eval_input(plan: StagePlan, datas, rels) -> KV:
    def rel_rows(idx):
        vals, valid = rels[idx]
        vis = {n: a for n, a in vals.items() if not n.startswith("_")}
        K = valid.shape[0]
        return np.arange(K, dtype=np.int32), vis, valid

    if plan.kind == "group":
        (ip,) = plan.inputs
        if ip.ref[0] == "source":
            return datas[ip.ref[1]]
        keys, vis, valid = rel_rows(ip.ref[1])
        return make_kv(keys, vis, valid)
    sides, schemas = [], []
    for ip in plan.inputs:
        if ip.ref[0] == "source":
            kv = datas[ip.ref[1]]
            vals = {n: np.asarray(a) for n, a in kv.values.items()}
            keys, valid = np.asarray(kv.keys), np.asarray(kv.valid)
        else:
            keys, vals, valid = rel_rows(ip.ref[1])
        schemas.append(_schema_of(vals))
        sides.append((ip.side, (keys, vals,
                                np.ones(len(keys), np.int8), valid)))
    return _encode_join_kv(sides, schemas)
