"""Logical delta-query algebra: operators that carry their own delta rule.

Every operator of the algebra is a homomorphism over *signed relations* —
bags of rows tagged +1 (insert) / -1 (delete), exactly the
:class:`repro.core.incremental.DeltaKV` encoding the engine already
refreshes against.  The delta rule of each operator says how a change in
its input becomes a change in its output (Fegaras' incremental stream
algebra; Elghandour et al.'s delta-query derivation):

  ============  ========================================================
  operator      delta rule
  ============  ========================================================
  scan          Δ(R) = ΔR                       (the stream itself)
  map f         Δ(f(R)) = f(ΔR)                 (applied to both signs)
  filter σ      Δ(σ(R)) = σ(ΔR)                 ('-' rows re-test the
                                                 *old* value: a tombstone
                                                 is emitted iff the old
                                                 row had passed)
  project π     Δ(π(R)) = π(ΔR)
  group_by ⊕    Δ-rows re-reduce only affected groups: the signed
                segment-reduce homomorphism the engine's fine-grain
                refresh (§3.3) implements — tombstones cancel preserved
                MRBGraph edges, survivors re-reduce per group
  join ⋈        Δ(R ⋈ S) = ΔR ⋈ S  ∪  R ⋈ ΔS  ∪  ΔR ⋈ ΔS.  Lowered to
                a keyed merge: both sides' rows land in one group per
                join key with per-side presence counts, so patching one
                side re-evaluates the join output exactly for the
                affected keys — the three delta terms collapse into one
                affected-key re-reduce against preserved state
  window        key-space expansion *before* group_by: a row at time t
                fans out to every window containing t, so its delta
                rule is map's (each window bucket is just another group)
  ============  ========================================================

The builder is fluent and immutable::

    from repro import dql
    q = (dql.scan("docs")
            .map(lambda v: {"w": v["w"], "c": jnp.ones_like(v["w"], jnp.float32)})
            .group_by(key="w", value="c", agg="sum", num_keys=vocab))
    compiled = q.compile(RunConfig(backend="xla"))
    compiled.run(data)                     # full evaluation
    compiled.update(delta)                 # |Δ|-proportional refresh

Stateless operators (map / filter / project / window) never materialize:
the planner (:mod:`repro.dql.lower`) fuses each maximal stateless chain
into the Map function of the next stateful stage, so one kernel sequence
serves the whole chain.  Conventions:

  * column names starting with ``_`` are reserved for the planner
    (presence lanes, the join side lane);
  * '-' delta rows carry the record's *previous* values (the same
    convention ``apply_delta_host`` / the synthetic sources follow) so
    computed keys and filters route tombstones to the groups the old
    value contributed to;
  * group keys are int32; negative keys mask the emission (the idiom
    ``apps/wordcount.py`` uses for padded fanout).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Mapping, Optional, Tuple, Union

AGG_KINDS = ("sum", "min", "max", "mean")

# a value spec: an existing column, a computed column, or a constant
ValueSpec = Union[str, Callable, int, float]


# ---------------------------------------------------------------------------
# Plan nodes (immutable; the builder below wraps them)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Node:
    pass


@dataclass(frozen=True)
class Scan(Node):
    """A named delta-stream input (one ``KV`` + its ``DeltaKV`` stream)."""

    source: str = "input"


@dataclass(frozen=True)
class Map(Node):
    """Row-wise transform: ``fn(values) -> values`` (vectorized, pure jnp)."""

    parent: Node = None
    fn: Callable = None


@dataclass(frozen=True)
class Filter(Node):
    """Row predicate: ``pred(values) -> bool [N]``."""

    parent: Node = None
    pred: Callable = None


@dataclass(frozen=True)
class Project(Node):
    """Keep only the named columns."""

    parent: Node = None
    cols: Tuple[str, ...] = ()


@dataclass(frozen=True)
class Window(Node):
    """Tumbling/sliding window annotation, consumed by the next group_by.

    A row whose ``time`` column is t belongs to every window w with
    ``w*slide <= t < w*slide + size`` (tumbling when slide == size).  The
    next ``group_by`` emits into composite groups ``w * num_keys + key``.
    """

    parent: Node = None
    size: int = 0
    slide: int = 0
    time: str = "t"
    num_windows: int = 0


@dataclass(frozen=True)
class GroupBy(Node):
    """Signed grouped aggregation over a dense int key space."""

    parent: Node = None
    key: Union[str, Callable] = None
    value: Any = None            # normalized to {name: ValueSpec} by builder
    agg: str = "sum"
    num_keys: int = 0
    name: str = "group_by"


@dataclass(frozen=True)
class Join(Node):
    """Equi-join of two keyed relations on their (dense int) key.

    Each side holds at most one live row per key (true of group_by outputs
    and of scans keyed by record id); the output carries both sides'
    columns, optionally prefixed, for keys live on *both* sides.
    """

    left: Node = None
    right: Node = None
    num_keys: int = 0
    lprefix: str = ""
    rprefix: str = ""
    name: str = "join"


# ---------------------------------------------------------------------------
# Fluent builder
# ---------------------------------------------------------------------------

class Q:
    """Immutable handle around a plan node; every method returns a new Q."""

    def __init__(self, node: Node):
        self.node = node

    # -- stateless operators (fused by the planner) ------------------------
    def map(self, fn: Callable) -> "Q":
        return Q(Map(self.node, fn))

    def filter(self, pred: Callable) -> "Q":
        return Q(Filter(self.node, pred))

    def project(self, *cols: str) -> "Q":
        return Q(Project(self.node, tuple(cols)))

    def window(self, size: int, slide: Optional[int] = None, *,
               time: str = "t", num_windows: int) -> "Q":
        slide = size if slide is None else slide
        if size <= 0 or slide <= 0:
            raise ValueError("window size and slide must be positive")
        return Q(Window(self.node, int(size), int(slide), time,
                        int(num_windows)))

    # -- stateful operators ------------------------------------------------
    def group_by(self, key: Union[str, Callable], *, num_keys: int,
                 value: Any = None, agg: str = "sum",
                 name: str = "group_by") -> "Q":
        if agg not in AGG_KINDS:
            raise ValueError(f"agg must be one of {AGG_KINDS}, got {agg!r}")
        return Q(GroupBy(self.node, key, _norm_value(value), agg,
                         int(num_keys), name))

    def join(self, other: "Q", *, num_keys: Optional[int] = None,
             lprefix: str = "", rprefix: str = "",
             name: str = "join") -> "Q":
        ln = _keyspace_of(self.node)
        rn = _keyspace_of(other.node)
        nk = num_keys
        for side in (ln, rn):
            if side is not None:
                nk = side if nk is None else nk
                if side != nk:
                    raise ValueError(
                        f"join sides disagree on key space: {ln} vs {rn}")
        if nk is None:
            raise ValueError("join of two scans needs num_keys=")
        return Q(Join(self.node, other.node, int(nk), lprefix, rprefix,
                      name))

    # -- compilation -------------------------------------------------------
    def compile(self, config=None):
        """Lower the plan and bind it to a :class:`repro.api.Session`."""
        from repro.dql.query import Query
        return Query(self, config)

    def spec(self):
        """The lowered spec: a plain ``JobSpec`` when the plan is a single
        source->chain->group_by pipeline, a ``QuerySpec`` otherwise."""
        from repro.dql.lower import lower
        return lower(self.node)

    def __repr__(self) -> str:
        return f"Q({explain(self.node)})"


def scan(source: str = "input") -> Q:
    """Root of every plan: the named delta-stream input."""
    return Q(Scan(source))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _norm_value(value: Any) -> Dict[str, ValueSpec]:
    """Normalize the group_by ``value=`` argument to {name: spec}."""
    if value is None:
        return {"n": 1.0}                 # bare count
    if isinstance(value, str):
        return {value: value}
    if isinstance(value, Mapping):
        return dict(value)
    raise TypeError("value= must be None, a column name, or a "
                    "{name: column|callable|constant} mapping")


def _keyspace_of(node: Node) -> Optional[int]:
    """Output key space of a keyed node; None for scans (caller supplies)."""
    if isinstance(node, GroupBy):
        return node.num_keys
    if isinstance(node, Join):
        return node.num_keys
    if isinstance(node, (Map, Filter, Project, Window)):
        return _keyspace_of(node.parent)
    return None


def explain(node: Node) -> str:
    """One-line plan rendering (leaf -> root)."""
    if isinstance(node, Scan):
        return f"scan({node.source})"
    if isinstance(node, Map):
        return f"{explain(node.parent)} -> map"
    if isinstance(node, Filter):
        return f"{explain(node.parent)} -> filter"
    if isinstance(node, Project):
        return f"{explain(node.parent)} -> project{list(node.cols)}"
    if isinstance(node, Window):
        kind = "tumbling" if node.size == node.slide else "sliding"
        return (f"{explain(node.parent)} -> window[{kind} "
                f"{node.size}/{node.slide}]")
    if isinstance(node, GroupBy):
        return (f"{explain(node.parent)} -> group_by[{node.agg}, "
                f"K={node.num_keys}]")
    if isinstance(node, Join):
        return (f"({explain(node.left)}) ⋈ ({explain(node.right)}) "
                f"[K={node.num_keys}]")
    return type(node).__name__
