"""Query: a compiled delta plan bound to a ``repro.api.Session``.

``Q.compile(config)`` returns one of these.  It is a thin, stateful
convenience over the uniform session surface — a compiled query *is* just
another session kind (driver kind ``"query"``; single-pipeline plans lower
all the way to a plain ``JobSpec`` and run the engine's accumulator/MRBG
one-step paths untouched), so ``RunReport``, checkpoint/restore, the
streaming scheduler's cost model, and the serving tier all work on it
with no query-specific code.

On top of the session it keeps host *input mirrors* (one per source,
indexed by record id — the same role ``StreamSession``'s mirror plays) so
``rerun()`` — the Fig. 8 alternative once |Δ| outgrows the incremental
crossover — needs no caller-side bookkeeping::

    q = (dql.scan("edges").group_by(key="dst", value="w", num_keys=K)
            .compile(RunConfig(backend="xla")))
    q.run(edges_kv)
    q.update(delta)          # |Δ|-proportional, preserved-state refresh
    q.rerun()                # full recompute on the mutated mirrors
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Union

import numpy as np

from repro.api.config import RunConfig, StreamConfig
from repro.api.session import Session
from repro.core.engine import JobSpec
from repro.core.incremental import DeltaKV, apply_delta_host
from repro.core.kvstore import KV, make_kv, next_bucket
from repro.dql.driver import evaluate as _evaluate_spec
from repro.dql.lower import QuerySpec, lower


class Query:
    """A lowered plan + its Session + per-source input mirrors."""

    def __init__(self, q, config: Optional[RunConfig] = None):
        from repro.dql.algebra import Q
        self.plan = q.node if isinstance(q, Q) else q
        self.qspec: Union[JobSpec, QuerySpec] = lower(self.plan)
        self.config = config or RunConfig()
        self.session = Session(self.qspec, self.config)
        self._mirrors: Optional[Dict[str, list]] = None

    @property
    def sources(self) -> tuple:
        if isinstance(self.qspec, QuerySpec):
            return self.qspec.sources
        from repro.dql.lower import sources_of
        return sources_of(self.plan)

    @property
    def name(self) -> str:
        return self.qspec.name

    # -- lifecycle (mirrors Session.run/update/rerun) ----------------------
    def run(self, data):
        """Initial full evaluation.  ``data``: a KV, or {source: KV}."""
        datas = self._as_source_dict(data, KV)
        self._mirrors = {
            name: [np.array(kv.keys),
                   {n: np.array(a) for n, a in kv.values.items()},
                   np.array(kv.valid)]
            for name, kv in datas.items()}
        return self.session.run(self._session_arg(datas))

    def update(self, delta):
        """Incremental refresh.  ``delta``: a DeltaKV, or {source: DeltaKV}
        for multi-source plans (absent sources are unchanged)."""
        deltas = self._as_source_dict(delta, DeltaKV, partial=True)
        rep = self.session.update(self._session_arg(deltas))
        for name, d in deltas.items():        # after: no mirror roll-back
            self._apply_mirror(name, d)
        return rep

    def rerun(self):
        """Full recompute on the mutated input mirrors (scheduler's
        alternative past the update-vs-rerun crossover)."""
        if self._mirrors is None:
            raise RuntimeError("rerun() needs the input mirrors captured by "
                               "run(); a restored Query must run() or "
                               "update() only")
        datas = {name: make_kv(m[0], m[1], m[2])
                 for name, m in self._mirrors.items()}
        return self.session.rerun(self._session_arg(datas))

    # -- outputs -----------------------------------------------------------
    @property
    def result(self) -> Dict[str, np.ndarray]:
        return self.session.result

    def relation(self):
        """(values, valid) of the output relation (invalid rows unmasked)."""
        drv = self.session._driver
        rel = getattr(drv, "relation", None)
        if rel is not None:
            return rel()
        view = self.session.view
        return view.as_dict(), view.valid.copy()

    def report(self, include_result: bool = True):
        return self.session.report(include_result)

    def explain(self) -> str:
        from repro.dql.algebra import explain
        return explain(self.plan)

    # -- fault tolerance ---------------------------------------------------
    def checkpoint(self, path: Optional[str] = None):
        return self.session.checkpoint(path)

    @classmethod
    def restore(cls, q, path: str,
                config: Optional[RunConfig] = None) -> "Query":
        obj = cls.__new__(cls)
        from repro.dql.algebra import Q
        obj.plan = q.node if isinstance(q, Q) else q
        obj.qspec = lower(obj.plan)
        obj.config = config or RunConfig()
        obj.session = Session.restore(obj.qspec, path, config)
        obj._mirrors = None
        return obj

    # -- streaming adapter -------------------------------------------------
    def stream(self, data, source=None, *,
               stream: Optional[StreamConfig] = None, name: str = "query"):
        """Bind this query's spec to a :class:`repro.stream.StreamSession`.

        Single-source plans only (the stream layer feeds one delta
        stream); the StreamSession owns its own session + mirror, so use
        either the returned object *or* this Query, not both.
        """
        from repro.stream.session import StreamSession
        if len(self.sources) != 1:
            raise ValueError(
                f"stream() supports single-source queries; this plan reads "
                f"{list(self.sources)} — drive multi-source updates via "
                f"Query.update({{source: delta}})")
        if isinstance(data, dict):
            data = data[self.sources[0]]
        return StreamSession(self.qspec, data, source=source,
                             config=self.config, stream=stream, name=name)

    # -- internals ---------------------------------------------------------
    def _as_source_dict(self, data, leaf_cls, partial: bool = False) -> dict:
        srcs = self.sources
        if isinstance(data, leaf_cls):
            if len(srcs) != 1:
                raise ValueError(
                    f"this query reads {list(srcs)}; pass a dict "
                    f"{{source: {leaf_cls.__name__}}}")
            return {srcs[0]: data}
        if not isinstance(data, dict):
            raise TypeError(f"expected {leaf_cls.__name__} or dict, got "
                            f"{type(data).__name__}")
        unknown = set(data) - set(srcs)
        if unknown:
            raise ValueError(f"unknown sources {sorted(unknown)}; this "
                             f"query reads {list(srcs)}")
        if not partial and set(data) != set(srcs):
            raise ValueError(f"missing sources "
                             f"{sorted(set(srcs) - set(data))}")
        return dict(data)

    def _session_arg(self, datas: dict):
        # single-source plans speak bare KV/DeltaKV to the session (the
        # JobSpec lowering requires it; for QuerySpec it lets
        # Session.update's bucketed-ladder padding kick in)
        if len(self.sources) == 1:
            return datas[self.sources[0]]
        return datas

    def _apply_mirror(self, name: str, delta: DeltaKV) -> None:
        if self._mirrors is None or name not in self._mirrors:
            return
        m = self._mirrors[name]
        rid = np.asarray(delta.record_ids)
        dvalid = np.asarray(delta.valid)
        if dvalid.any():
            need = int(rid[dvalid].max()) + 1
            if need > m[0].shape[0]:
                self._grow_mirror(m, next_bucket(need, m[0].shape[0]))
        keys, values, valid = m
        apply_delta_host(keys, values, valid, delta)

    @staticmethod
    def _grow_mirror(m, capacity: int) -> None:
        pad = capacity - m[0].shape[0]
        m[0] = np.concatenate(
            [m[0], np.zeros((pad,) + m[0].shape[1:], m[0].dtype)])
        m[1] = {n: np.concatenate([a, np.zeros((pad,) + a.shape[1:],
                                               a.dtype)])
                for n, a in m[1].items()}
        m[2] = np.concatenate([m[2], np.zeros(pad, bool)])


def evaluate(q, data, *, backend: Optional[str] = None):
    """One-shot, storeless evaluation of a plan (or compiled spec).

    Returns ``(values, valid)`` of the output relation.  Use this when the
    result is consumed once and never refreshed — it skips the MRBG store
    and view entirely and feeds the fused map functions straight into
    ``kernels.ops.group_reduce``.
    """
    from repro.dql.algebra import Q
    if isinstance(q, Q):
        q = lower(q.node)
    return _evaluate_spec(q, data, backend=backend)
