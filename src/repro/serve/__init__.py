"""repro.serve — SLO-aware multi-tenant serving tier.

The serving entry point for fleets of incremental tenants: per-tenant
SLO classes with deadline-slack scheduling, admission control that sheds
best-effort work under overload, batched cross-tenant refresh (many
small tenants, one kernel launch), and cold-store spill to disk under a
shared memory budget.  Replaces ``repro.stream.MultiSessionServer``
(kept as a deprecated shim for one release).
"""
from repro.serve.admission import AdmissionController
from repro.serve.sched import (BEST_EFFORT, LATENCY, THROUGHPUT, SLOClass,
                               deadline_slack, order_by_priority)
from repro.serve.spill import SpillManager
from repro.serve.tier import ServeTier, TenantHandle

__all__ = [
    "AdmissionController",
    "BEST_EFFORT",
    "LATENCY",
    "THROUGHPUT",
    "SLOClass",
    "SpillManager",
    "ServeTier",
    "TenantHandle",
    "deadline_slack",
    "order_by_priority",
]
