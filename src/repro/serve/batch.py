"""Batched cross-tenant refresh: many tenants' deltas, one kernel launch.

A fleet of small tenants makes the per-tenant refresh path dispatch-bound:
every micro-batch pays its own delta-Map launch, shuffle sort, and
segment reduce even when the delta holds a handful of rows.  This module
stacks compatible tenants' prepared deltas into one ``[T, cap]`` batch
and drives the union through a *single* pass of the existing engine:

1. one jitted, vmapped delta-Map over the tenant lane;
2. a **tenant-id lane** on K2 — each tenant's keys are offset by
   ``tenant * num_keys``, so the per-tenant key spaces become disjoint
   ranges of one global key space and one shuffle sort / segment reduce
   serves everyone;
3. one bucketed :func:`~repro.core.incremental._combine_edges` +
   :func:`~repro.core.incremental._merge_reduce` launch (the same
   ``ops.shuffle_reduce`` path — fused on the pallas backend — and the
   same power-of-two bucket ladder, so executables are shared with the
   solo path's cache discipline);
4. a host-side split of the merged chunks and reduced values back to each
   tenant's MRBG store and result view.

Steady-state cost becomes launches-per-*batch* instead of
launches-per-*tenant*.  Per-tenant outputs are bit-for-bit identical to a
solo refresh: the key ranges are disjoint, the shuffle sort is stable,
and within every (k2, mk) segment the row order (preserved rows before
delta rows, emission order within each) matches what the tenant's own
refresh would have fed the reducer.
"""
from __future__ import annotations

import functools
import time
from contextlib import ExitStack
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.incremental import (DeltaKV, _combine_edges, _merge_reduce,
                                    _v2_dict, pad_delta)
from repro.core.kvstore import KV, Edges, edges_to_host, next_bucket, sort_edges
from repro.kernels import jitcache, ops

MAX_GLOBAL_KEY = 2**31 - 1


def batch_signature(ss, prep) -> Optional[tuple]:
    """Group key for tenants whose prepared refreshes can share a launch;
    ``None`` when the tenant must refresh solo.

    Only ``onestep-mrbg`` drivers with an ``update`` decision batch — the
    iterative, accumulator, and distributed paths (and rerun/auto-off
    decisions) keep the per-tenant path.  Two tenants share a signature
    when they run the same Map *function object*, the same reducer, key
    count, and resolved backend, and emit identical delta value schemas —
    exactly the conditions under which one trace serves both.
    """
    drv = ss.session._driver
    if getattr(drv, "kind", None) != "onestep-mrbg":
        return None
    if prep.decision is None or prep.decision.action != "update":
        return None
    spec = ss.session.spec
    delta = prep.res.delta
    leaves = tuple(sorted(
        (name, str(np.asarray(a).dtype), tuple(np.asarray(a).shape[1:]))
        for name, a in _v2_dict(delta.values).items()))
    return (id(spec.map_fn), spec.reducer, spec.num_keys,
            ops.resolve_backend(ss.session.config.backend), leaves)


@functools.partial(jax.jit, static_argnums=(0,))
def _batched_delta_map(spec_static, delta: DeltaKV) -> Edges:
    """vmapped delta Map over ``[T, cap]`` stacked tenants, tenant-id K2
    offset, then ONE shuffle sort over the flattened union."""
    jitcache.count_trace("serve._batched_delta_map")
    map_fn, num_keys, backend = spec_static

    def one_tenant(keys, values, valid, sign):
        return map_fn(KV(keys, values, valid), sign)

    edges = jax.vmap(one_tenant)(delta.keys, delta.values,
                                 delta.valid, delta.sign)
    t_idx = jnp.arange(edges.k2.shape[0], dtype=jnp.int32)[:, None]
    gk2 = jnp.where(edges.valid, edges.k2 + t_idx * num_keys, 0)
    flat = Edges(gk2.reshape(-1), edges.mk.reshape(-1),
                 jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]),
                              edges.v2),
                 edges.valid.reshape(-1), edges.sign.reshape(-1))
    return sort_edges(flat, backend=backend)


def _stack_tenants(deltas: List[DeltaKV], cap: int, t_pad: int) -> DeltaKV:
    """Stack per-tenant deltas (row-padded to ``cap``) into ``[t_pad, cap]``
    lanes; padding tenants are all-invalid rows."""
    padded = [pad_delta(d, cap) for d in deltas]

    def lane(get):
        arrs = [np.asarray(get(d)) for d in padded]
        out = np.zeros((t_pad, cap) + arrs[0].shape[1:], arrs[0].dtype)
        for t, a in enumerate(arrs):
            out[t] = a
        return jnp.asarray(out)

    return DeltaKV(lane(lambda d: d.keys),
                   lane(lambda d: d.record_ids),
                   {n: lane(lambda d, n=n: d.values[n])
                    for n in padded[0].values},
                   lane(lambda d: d.valid),
                   lane(lambda d: d.sign))


def execute_group(items: List[Tuple[object, object]],
                  delta_bucket_min: int = 64) -> None:
    """Run one batched refresh for ``items`` — ``(handle, prep)`` pairs
    sharing a :func:`batch_signature` — and commit every participant.

    On any failure every participant's mirror is rolled back and the
    exception re-raised; no tenant is left half-refreshed.  Each tenant's
    scheduler observes its *share* of the batch wall-clock, so the EWMA
    cost model learns the amortized batched cost.
    """
    t0 = time.perf_counter()
    gen0 = jitcache.generation()
    with ExitStack() as stack:
        for h, _ in items:
            stack.enter_context(h.ss._lock)
        try:
            _run(items, delta_bucket_min)
        except BaseException:
            for h, prep in items:
                h.ss.rollback_batch(prep)
            raise
        wall = time.perf_counter() - t0
        retraced = jitcache.generation() != gen0
        share = wall / len(items)
        for h, prep in items:
            h.ss.session.absorb_refresh(share)
            h.ss.commit_batch(prep, "update", share, retraced)


def _run(items, delta_bucket_min: int) -> None:
    session0 = items[0][0].ss.session
    spec = session0.spec
    num_keys = spec.num_keys
    backend = ops.resolve_backend(session0.config.backend)
    reducer = spec.reducer

    t_pad = next_bucket(len(items), 1)
    if t_pad * num_keys > MAX_GLOBAL_KEY:
        raise ValueError(
            f"tenant-id lane overflow: {t_pad} tenants x {num_keys} keys "
            f"exceeds int32; lower ServeTier(max_batch_tenants=...)")
    cap = next_bucket(max(p.res.delta.capacity for _, p in items),
                      delta_bucket_min)
    stacked = _stack_tenants([p.res.delta for _, p in items], cap, t_pad)

    # 1-2) one vmapped delta Map + one shuffle sort for the whole group
    edges = _batched_delta_map((spec.map_fn, num_keys, backend), stacked)
    dh = edges_to_host(edges, sorted_valid_first=True)
    affected_g = np.unique(dh["k2"])        # global (tenant-offset) keys
    for h, _ in items:
        for store in h.ss.session.stores:
            store.reset_stats()
    if affected_g.size == 0:
        for h, _ in items:
            h.ss.session._driver._affected = 0
        return

    # 3) per-tenant store queries, re-offset into the global key space;
    # concatenated tenant-major so preserved rows precede delta rows and
    # the stable shuffle sort keeps solo-identical segment order
    owner = affected_g // num_keys
    dv2 = _v2_dict(dh["v2"])
    pk_parts, pmk_parts = [], []
    pv_parts = {n: [] for n in dv2}
    for t, (h, _) in enumerate(items):
        mask = owner == t
        local = (affected_g[mask] - t * num_keys).astype(affected_g.dtype)
        pk2, pmk, pv2, _plen = h.ss.session.store.query(local)
        if pv2 is None or pk2.shape[0] == 0:
            continue
        pk_parts.append(pk2.astype(np.int64) + t * num_keys)
        pmk_parts.append(pmk)
        for n, a in _v2_dict(pv2).items():
            pv_parts[n].append(a)
    if pk_parts:
        pk2_all = np.concatenate(pk_parts).astype(np.int32)
        pmk_all = np.concatenate(pmk_parts)
        pv2_all = {n: np.concatenate(parts) for n, parts in pv_parts.items()}
    else:
        pk2_all = np.zeros(0, np.int32)
        pmk_all = np.zeros(0, np.int32)
        pv2_all = {n: np.zeros((0,) + a.shape[1:], a.dtype)
                   for n, a in dv2.items()}

    # 4-5) ONE bucketed merge + segment reduce over the union
    key_cap = next_bucket(affected_g.size, 64)
    combined = _combine_edges(pk2_all, pmk_all, pv2_all,
                              dh["k2"], dh["mk"], dv2,
                              np.asarray(dh["sign"], np.int8))
    keys_pad = np.full(key_cap, np.int32(MAX_GLOBAL_KEY), np.int32)
    keys_pad[:affected_g.size] = affected_g.astype(np.int32)
    merged, values, counts = _merge_reduce(reducer, key_cap, backend,
                                           combined, jnp.asarray(keys_pad))

    # 6) split the merged chunks / reduced values back per tenant
    mh = edges_to_host(merged)
    m_owner = mh["k2"] // num_keys
    m_local = (mh["k2"] % num_keys).astype(mh["k2"].dtype)
    mv2 = _v2_dict(mh["v2"])
    counts_h = np.asarray(counts)[:affected_g.size]
    vals_h = {n: np.asarray(a)[:affected_g.size]
              for n, a in _v2_dict(values).items()}
    for t, (h, _) in enumerate(items):
        drv = h.ss.session._driver
        sel = m_owner == t
        drv.store.append(m_local[sel], mh["mk"][sel],
                         {n: a[sel] for n, a in mv2.items()})
        amask = owner == t
        local = (affected_g[amask] - t * num_keys).astype(affected_g.dtype)
        c_t = counts_h[amask]
        drv.store.mark_deleted(local[c_t == 0])
        drv.view.patch(local, {n: a[amask] for n, a in vals_h.items()}, c_t)
        drv._affected = int(amask.sum())
        drv._counts = drv.view.counts
        drv.mode = "incremental"
