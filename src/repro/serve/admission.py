"""Admission control: shed best-effort submits when the tier is saturated.

The controller estimates the tier-wide *backlog* — how many seconds of
refresh work are already queued across all tenants, priced by each
tenant's own :class:`~repro.stream.scheduler.RefreshScheduler` EWMA cost
model — and rejects new best-effort rows once that estimate exceeds a
budget.  Latency- and throughput-class tenants are always admitted; they
rely on backpressure (the bounded ingest queue) instead of shedding.

Queued rows are priced exactly: the tier counts rows at ``submit()``
time (``TenantHandle.queued_rows``) and credits them back as refreshes
consume them, so work sitting in the ingest queue — whose per-record row
counts are otherwise opaque without draining it — weighs its true size.
For sessions fed around the tier the estimate falls back to
``_pending_rows`` plus one row per queued record.  One deliberate
admitting-side approximation remains: a tenant with no clean ``update``
cost sample yet is priced at zero, because the seeded rerun estimate
includes cold-compile time and would shed the whole fleet at startup.
"""
from __future__ import annotations

from typing import Iterable


class AdmissionController:
    """Sheds best-effort work once estimated queued work exceeds
    ``max_backlog_seconds``."""

    def __init__(self, max_backlog_seconds: float = 0.25):
        if max_backlog_seconds <= 0:
            raise ValueError("max_backlog_seconds must be > 0")
        self.max_backlog_seconds = float(max_backlog_seconds)
        self.shed_submits = 0
        self.shed_rows = 0

    def backlog_seconds(self, handles: Iterable) -> float:
        """Predicted seconds of refresh work already buffered tier-wide."""
        total = 0.0
        for h in handles:
            ss = h.ss
            rows = max(int(getattr(h, "queued_rows", 0)),
                       ss._pending_rows + ss._inbox.qsize())
            if rows <= 0:
                continue
            est_u, est_rerun = ss.scheduler.estimates(rows)
            if est_u is None:
                continue                      # no clean sample yet: admit
            # only the cost-comparing policies are free to take the
            # cheaper rerun path; under the paper policy the crossover is
            # a ratio rule, so queued rows cost the incremental path
            if est_rerun is not None and ss.scheduler.config.policy != "paper":
                est_u = min(est_u, est_rerun)
            total += est_u
        return total

    def admit(self, handle, n_rows: int, backlog_s: float) -> bool:
        """Admission decision for one submit; counts the shed on refusal."""
        if not handle.slo.sheddable:
            return True
        if backlog_s <= self.max_backlog_seconds:
            return True
        self.shed_submits += 1
        self.shed_rows += int(n_rows)
        return False

    def snapshot(self) -> dict:
        return {"max_backlog_seconds": self.max_backlog_seconds,
                "shed_submits": self.shed_submits,
                "shed_rows": self.shed_rows}
