"""SLO classes and deadline-slack priority ordering for the serving tier.

Each tenant is admitted under one of three service classes:

- ``latency``     — interactive tenants with a p95 refresh-latency target;
                    scheduled first, never shed.
- ``throughput``  — bulk tenants that care about sustained updates/sec;
                    scheduled after latency tenants, never shed.
- ``best-effort`` — background tenants; scheduled last and shed by
                    admission control when the tier is overloaded.

Within a class, due tenants are ordered by *deadline slack*: the time left
until the oldest pending row blows its deadline, minus the refresh cost
the tenant's own :class:`~repro.stream.scheduler.RefreshScheduler` EWMA
model predicts for the pending rows.  Most-negative slack first — the
tenant closest to (or deepest into) a breach refreshes next.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional

LATENCY = "latency"
THROUGHPUT = "throughput"
BEST_EFFORT = "best-effort"
KINDS = (LATENCY, THROUGHPUT, BEST_EFFORT)
_RANK = {LATENCY: 0, THROUGHPUT: 1, BEST_EFFORT: 2}


@dataclass(frozen=True)
class SLOClass:
    """A tenant's service-level objective.

    ``deadline_ms`` bounds how long a submitted row may wait before its
    refresh completes (drives scheduling order); ``target_p95_ms`` is the
    latency class's advertised p95 (drives breach accounting in
    ``stats()``).
    """

    kind: str = BEST_EFFORT
    deadline_ms: float = 200.0
    target_p95_ms: Optional[float] = None

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown SLO class {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.deadline_ms <= 0:
            raise ValueError("deadline_ms must be > 0")
        if self.target_p95_ms is not None and self.target_p95_ms <= 0:
            raise ValueError("target_p95_ms must be > 0 (or None)")

    @property
    def rank(self) -> int:
        return _RANK[self.kind]

    @property
    def sheddable(self) -> bool:
        return self.kind == BEST_EFFORT

    @classmethod
    def latency(cls, target_p95_ms: float = 50.0,
                deadline_ms: Optional[float] = None) -> "SLOClass":
        return cls(LATENCY, deadline_ms or target_p95_ms, target_p95_ms)

    @classmethod
    def throughput(cls, deadline_ms: float = 1000.0) -> "SLOClass":
        return cls(THROUGHPUT, deadline_ms)

    @classmethod
    def best_effort(cls, deadline_ms: float = 5000.0) -> "SLOClass":
        return cls(BEST_EFFORT, deadline_ms)


def deadline_slack(handle, now: Optional[float] = None) -> float:
    """Seconds of headroom before ``handle``'s oldest pending row misses
    its deadline, net of the predicted refresh cost.  Negative = already
    (predicted to be) in breach."""
    if now is None:
        now = time.perf_counter()
    ss = handle.ss
    pending = ss._pending
    waited = (now - pending[0][1]) if pending else 0.0
    rows = max(ss._pending_rows, 1)
    est_u, est_rerun = ss.scheduler.estimates(rows)
    est = est_u if est_u is not None else (est_rerun or 0.0)
    return handle.slo.deadline_ms / 1e3 - waited - est


def order_by_priority(handles, now: Optional[float] = None) -> List:
    """Scheduling order for one sweep: class rank first (latency <
    throughput < best-effort), then most-negative deadline slack."""
    if now is None:
        now = time.perf_counter()
    return sorted(handles,
                  key=lambda h: (h.slo.rank, deadline_slack(h, now)))
