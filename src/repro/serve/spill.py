"""Cold-store spill: serialize idle tenants' MRBG stores to disk.

A tenant that hasn't seen traffic recently still pins its preserved
MRBG-Store in host memory.  Under budget pressure the tier spills such
tenants: each store's blobs go to one ``.npz`` per store (the same
serialization the checkpoint format uses — :func:`store_blobs` /
:func:`store_meta` / :func:`load_store_state`), the in-memory store is
cleared in place, and the next delta for that tenant transparently
reloads it first.  Because the npz round-trip preserves every chunk byte
and the index arrays exactly, a spilled-then-reloaded tenant's next
refresh is bit-for-bit identical to one that never spilled.
"""
from __future__ import annotations

from pathlib import Path
from typing import List

import numpy as np

from repro.core.mrbg_store import load_store_state, store_blobs, store_meta


class SpillManager:
    """Spills and reloads tenants' MRBG stores under a spill directory."""

    def __init__(self, spill_dir):
        self.dir = Path(spill_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.spills = 0
        self.reloads = 0
        self.bytes_spilled = 0

    def _paths(self, handle) -> List[Path]:
        return [self.dir / f"{handle.name}.mrbg_{i:03d}.npz"
                for i in range(len(handle.ss.session.stores))]

    def spill(self, handle) -> int:
        """Serialize every store of ``handle``'s session and release the
        in-memory copies.  Returns the bytes freed.  Caller must ensure
        the tenant is idle (no batch in flight)."""
        ss = handle.ss
        with ss._lock:
            if handle.spilled:
                return 0
            freed = ss.session.store_bytes()
            metas = []
            for store, path in zip(ss.session.stores, self._paths(handle)):
                np.savez(path, **store_blobs(store))
                metas.append(store_meta(store))
                store.clear()
            handle.spill_meta = metas
            handle.spilled = True
        self.spills += 1
        self.bytes_spilled += freed
        return freed

    def reload(self, handle) -> None:
        """Restore ``handle``'s stores from disk (no-op when resident)."""
        ss = handle.ss
        with ss._lock:
            if not handle.spilled:
                return
            for store, meta, path in zip(ss.session.stores,
                                         handle.spill_meta,
                                         self._paths(handle)):
                with np.load(path) as npz:
                    load_store_state(store, npz, meta)
                path.unlink()
            handle.spill_meta = None
            handle.spilled = False
        self.reloads += 1

    def discard(self, handle) -> None:
        """Drop ``handle``'s spill files (tenant removed while spilled)."""
        for path in self._paths(handle):
            path.unlink(missing_ok=True)

    def snapshot(self) -> dict:
        return {"spills": self.spills, "reloads": self.reloads,
                "bytes_spilled": self.bytes_spilled}
