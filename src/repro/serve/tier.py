"""ServeTier: SLO-aware multi-tenant serving over stream sessions.

The serving entry point (successor of ``MultiSessionServer``, which now
shims onto this class).  One scheduler thread drives every tenant's
micro-batches, but unlike the old round-robin sweep it:

- orders due tenants by SLO class and deadline slack
  (:mod:`repro.serve.sched`);
- sheds best-effort submits under overload
  (:mod:`repro.serve.admission`);
- stacks compatible small tenants' refreshes into one batched kernel
  launch (:mod:`repro.serve.batch`) instead of launching per tenant;
- enforces the shared store budget obsolete-bytes-first, then spills
  cold tenants' MRBG stores to disk (:mod:`repro.serve.spill`), reloading
  them transparently on their next delta.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.kernels import jitcache
from repro.serve.admission import AdmissionController
from repro.serve.batch import MAX_GLOBAL_KEY, batch_signature, execute_group
from repro.serve.sched import SLOClass, order_by_priority
from repro.serve.spill import SpillManager
from repro.stream.session import StreamSession


@dataclass
class TenantHandle:
    """Tier-side bookkeeping for one tenant."""

    name: str
    ss: StreamSession
    slo: SLOClass
    group: Optional[str] = None
    last_active: float = field(default_factory=time.perf_counter)
    spilled: bool = False
    spill_meta: Optional[list] = None
    shed_submits: int = 0
    shed_rows: int = 0
    breaches: int = 0
    observed: int = 0
    spill_count: int = 0
    reclaimed_bytes: int = 0
    # rows admitted through tier.submit() and not yet refreshed; unlike
    # ss._inbox.qsize() (records, row counts opaque) this prices queued
    # work exactly, which is what admission's backlog estimate needs
    queued_rows: int = 0
    # breach-window latency reservoir (seconds); bounded, reset by callers
    # that want a measurement window rather than lifetime percentiles
    latency_samples: List[float] = field(default_factory=list)

    def reset_window(self) -> None:
        """Zero the SLO accounting window (breaches, sheds, latencies)."""
        self.shed_submits = self.shed_rows = 0
        self.breaches = self.observed = 0
        self.latency_samples.clear()

    def snapshot(self) -> Dict[str, object]:
        lat = sorted(self.latency_samples)
        p95 = (lat[min(len(lat) - 1,
                       int(round(0.95 * (len(lat) - 1))))] * 1e3
               if lat else None)
        return {
            "slo": self.slo.kind,
            "deadline_ms": self.slo.deadline_ms,
            "target_p95_ms": self.slo.target_p95_ms,
            "shed_submits": self.shed_submits,
            "shed_rows": self.shed_rows,
            "breaches": self.breaches,
            "observed": self.observed,
            "breach_rate": self.breaches / max(self.observed, 1),
            "latency_p95_ms": p95,
            "queued_rows": self.queued_rows,
            "spilled": self.spilled,
            "spill_count": self.spill_count,
            "reclaimed_bytes": self.reclaimed_bytes,
        }


class ServeTier:
    """Schedule many tenant :class:`StreamSession`\\ s over one engine."""

    def __init__(self, store_budget_bytes: Optional[int] = None,
                 poll_interval: float = 0.002,
                 batch_refresh: bool = True,
                 max_batch_tenants: int = 128,
                 spill_dir=None,
                 admission: Optional[AdmissionController] = None):
        self.store_budget_bytes = store_budget_bytes
        self.poll_interval = poll_interval
        self.batch_refresh = batch_refresh
        self.max_batch_tenants = max(int(max_batch_tenants), 1)
        self.handles: Dict[str, TenantHandle] = {}
        self.admission = admission or AdmissionController()
        self.spill = SpillManager(spill_dir) if spill_dir is not None else None
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._over_budget = False
        self._sweeps = 0
        self._batched_launches = 0
        self._batched_refreshes = 0
        self._error: Optional[BaseException] = None

    # -- tenancy -----------------------------------------------------------
    @property
    def tenants(self) -> Dict[str, StreamSession]:
        """Name -> session view (read-only; kept for server compat)."""
        return {n: h.ss for n, h in self.handles.items()}

    def add(self, tenant: StreamSession, slo: Optional[SLOClass] = None,
            group: Optional[str] = None) -> StreamSession:
        """Admit a tenant; the tier owns its scheduling from now on (the
        tenant must not run its own worker thread).

        Admission runs the tenant's initial job — and, with
        ``StreamConfig(prewarm=True)``, compiles its delta bucket ladder —
        before it enters the sweep, so a new tenant never pays
        cold-compile latency out of the shared scheduler thread.  ``slo``
        defaults to best-effort; ``group`` partitions batched refresh
        (tenants only batch within their group).
        """
        if tenant.name in self.handles:
            raise ValueError(f"tenant {tenant.name!r} already registered")
        if tenant._thread is not None:
            raise ValueError(f"tenant {tenant.name!r} already runs its own "
                             f"worker; construct it unstarted")
        tenant.start(background=False)     # initial run, no thread
        tenant._managed = True             # this thread is its consumer now
        self.handles[tenant.name] = TenantHandle(
            tenant.name, tenant, slo or SLOClass.best_effort(), group)
        return tenant

    def remove(self, name: str) -> StreamSession:
        """Deregister a tenant and hand its session back (resident again
        if it was spilled; buffered rows stay queued for the caller to
        drain in sync mode)."""
        handle = self.handles.pop(name)
        if handle.spilled and self.spill is not None:
            self.spill.reload(handle)
        handle.ss._managed = False
        return handle.ss

    def __getitem__(self, name: str) -> StreamSession:
        return self.handles[name].ss

    def handle(self, name: str) -> TenantHandle:
        return self.handles[name]

    # -- ingestion ---------------------------------------------------------
    def submit(self, name: str, record_ids, values, sign, *, epoch: int = 0,
               timeout: Optional[float] = None) -> bool:
        """Submit one delta record through admission control.

        Returns ``False`` when the record was shed (best-effort tenant,
        tier overloaded) — the caller may retry later.  Latency and
        throughput classes are always admitted (backpressure applies).
        """
        handle = self.handles[name]
        n_rows = len(record_ids)
        if handle.slo.sheddable:
            backlog = self.admission.backlog_seconds(self.handles.values())
            if not self.admission.admit(handle, n_rows, backlog):
                handle.shed_submits += 1
                handle.shed_rows += n_rows
                return False
        handle.ss.submit(record_ids, values, sign, epoch=epoch,
                         timeout=timeout)
        handle.queued_rows += n_rows
        handle.last_active = time.perf_counter()
        return True

    # -- scheduling --------------------------------------------------------
    def start(self) -> "ServeTier":
        if self._thread is None:
            self._stop_evt.clear()
            self._thread = threading.Thread(target=self._loop,
                                            name="serve-tier", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    def __enter__(self) -> "ServeTier":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                if not self.sweep():
                    time.sleep(self.poll_interval)
            except BaseException as e:       # noqa: BLE001 — surfaced via
                self._error = e              # _check_error on drain
                return

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError("serving tier scheduler thread died; the "
                               "failing micro-batch was dropped"
                               ) from self._error

    def _serve_urgent(self) -> bool:
        """Refresh every due latency/throughput tenant immediately (solo).

        Called between best-effort work units as a preemption point: a
        latency-class row that arrives while the sweep is grinding
        through the best-effort herd waits for at most one launch, not
        the whole herd.
        """
        served = False
        for h in list(self.handles.values()):
            if h.slo.sheddable:
                continue
            h.ss._ingest()
            # _busy means an earlier prepared batch of this tenant is
            # still awaiting execution in the outer sweep; preparing a
            # second one here would refresh them out of order
            if h.ss._busy or not h.ss._should_fire():
                continue
            with h.ss._lock:
                if h.spilled and self.spill is not None:
                    self.spill.reload(h)
                prep = h.ss.prepare_batch()
            if prep is None:
                continue
            with h.ss._lock:
                h.ss.execute_prepared(prep)
            self._after_refresh(h, prep)
            served = True
        return served

    def sweep(self) -> bool:
        """One scheduling pass: ingest everywhere, prepare every due
        tenant in SLO order, refresh batched groups with one launch each
        and the rest solo (non-sheddable tenants preempt between work
        units), then enforce the store budget.  Returns True if any
        tenant made progress."""
        progressed = False
        handles = list(self.handles.values())
        for h in handles:
            h.ss._ingest()
        due = order_by_priority([h for h in handles if h.ss._should_fire()])

        prepared: List[tuple] = []
        for h in due:
            ss = h.ss
            with ss._lock:
                if h.spilled and self.spill is not None:
                    self.spill.reload(h)     # cold tenant woke up
                prep = ss.prepare_batch()
            if prep is not None:
                prepared.append((h, prep))

        groups: Dict[tuple, List[tuple]] = {}
        solos: List[tuple] = []
        for h, prep in prepared:
            sig = batch_signature(h.ss, prep) if self.batch_refresh else None
            if sig is not None:
                groups.setdefault(sig + (h.group,), []).append((h, prep))
            else:
                solos.append((h, prep))

        chunks: List[List[tuple]] = []
        for sig, items in groups.items():
            num_keys = items[0][0].ss.session.spec.num_keys
            limit = max(1, min(self.max_batch_tenants,
                               MAX_GLOBAL_KEY // max(num_keys, 1)))
            while items:
                chunk, items = items[:limit], items[limit:]
                if len(chunk) == 1:
                    solos.append(chunk[0])
                else:
                    chunks.append(chunk)

        # non-sheddable solos run before any best-effort work grinds;
        # after that, every launch is a preemption point
        solos.sort(key=lambda hp: hp[0].slo.rank)
        while solos and not solos[0][0].slo.sheddable:
            h, prep = solos.pop(0)
            with h.ss._lock:
                h.ss.execute_prepared(prep)
            self._after_refresh(h, prep)
            progressed = True

        for chunk in chunks:
            execute_group(chunk,
                          chunk[0][0].ss.session.config.delta_bucket_min)
            self._batched_launches += 1
            self._batched_refreshes += len(chunk)
            for h, prep in chunk:
                self._after_refresh(h, prep)
            progressed = True
            progressed |= self._serve_urgent()

        for h, prep in solos:
            with h.ss._lock:
                h.ss.execute_prepared(prep)
            self._after_refresh(h, prep)
            progressed = True
            progressed |= self._serve_urgent()

        self._enforce_budget()
        self._sweeps += 1
        return progressed

    def _after_refresh(self, handle: TenantHandle, prep) -> None:
        now = time.perf_counter()
        handle.last_active = now
        handle.queued_rows = max(0, handle.queued_rows - prep.n_in)
        if handle.slo.target_p95_ms is not None:
            latency = now - prep.first_arrival
            handle.observed += 1
            handle.latency_samples.append(latency)
            if len(handle.latency_samples) > 4096:
                del handle.latency_samples[:2048]
            if latency * 1e3 > handle.slo.target_p95_ms:
                handle.breaches += 1

    # -- shared store budget ----------------------------------------------
    def total_store_bytes(self) -> int:
        return sum(h.ss.store_bytes() for h in self.handles.values())

    def _enforce_budget(self) -> None:
        if self.store_budget_bytes is None:
            return
        total = self.total_store_bytes()
        if total <= self.store_budget_bytes:
            self._over_budget = False
            return
        # 1) compact: most obsolete bytes first (ties: least recently
        # active first), crediting each tenant's reclaim in stats()
        order = sorted(self.handles.values(),
                       key=lambda h: (-h.ss.session.store_obsolete_bytes(),
                                      h.last_active))
        for h in order:
            if total <= self.store_budget_bytes:
                break
            reclaimed = h.ss.compact_store()
            if reclaimed:
                h.reclaimed_bytes += reclaimed
                total -= reclaimed
        # 2) still over: spill cold tenants' stores to disk, least
        # important first (best-effort before latency), LRU within class
        if self.spill is not None:
            for h in sorted(self.handles.values(),
                            key=lambda h: (-h.slo.rank, h.last_active)):
                if total <= self.store_budget_bytes:
                    break
                if h.spilled or not h.ss.idle:
                    continue
                freed = self.spill.spill(h)
                if freed:
                    h.spill_count += 1
                    total -= freed
        self._over_budget = total > self.store_budget_bytes

    # -- synchronization / outputs ----------------------------------------
    def drain(self, timeout: float = 60.0) -> None:
        """Flush and process everything buffered in every tenant."""
        deadline = time.perf_counter() + timeout
        for h in self.handles.values():
            h.ss._flush = True
        try:
            while True:
                self._check_error()
                if self._thread is None:
                    self.sweep()
                if all(h.ss.idle for h in self.handles.values()):
                    return
                if time.perf_counter() > deadline:
                    lag = {n: h.ss._pending_rows + h.ss._inbox.qsize()
                           for n, h in self.handles.items() if not h.ss.idle}
                    raise TimeoutError(f"tier drain exceeded {timeout}s; "
                                       f"lagging tenants: {lag}")
                if self._thread is not None:
                    time.sleep(self.poll_interval)
        finally:
            for h in self.handles.values():
                h.ss._flush = False

    def stats(self) -> Dict[str, object]:
        tenants = {n: h.ss.metrics.snapshot()
                   for n, h in self.handles.items()}
        out = {
            "tenants": tenants,
            "classes": {n: h.snapshot() for n, h in self.handles.items()},
            "total_store_bytes": self.total_store_bytes(),
            "store_budget_bytes": self.store_budget_bytes,
            "over_budget": self._over_budget,
            "sweeps": self._sweeps,
            "batched_launches": self._batched_launches,
            "batched_refreshes": self._batched_refreshes,
            "reclaimed_bytes": {n: h.reclaimed_bytes
                                for n, h in self.handles.items()},
            "admission": self.admission.snapshot(),
            # process-wide latency-tail telemetry (shared jit caches)
            "retrace_batches": sum(t["retrace_batches"]
                                   for t in tenants.values()),
            "rows_rejected": sum(t["rows_rejected"]
                                 for t in tenants.values()),
            # tier-wide coalescer savings (rows the engine never saw)
            "rows_cancelled": sum(t["rows_cancelled"]
                                  for t in tenants.values()),
            "net_inserts": sum(t["net_inserts"] for t in tenants.values()),
            "net_deletes": sum(t["net_deletes"] for t in tenants.values()),
            "jit": jitcache.snapshot(),
        }
        if self.spill is not None:
            out["spill"] = self.spill.snapshot()
        return out
