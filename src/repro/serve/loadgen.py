"""Synthetic multi-tenant load generation for the serving tier.

Builds fleets of small wordcount tenants (the dispatch-bound regime the
batched cross-tenant refresh targets) and drives them with closed-loop
rounds (throughput cells) or open-loop paced offered load (overload
cells).  Shared by ``benchmarks/serve_load.py`` and the serve tests.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import numpy as np

from repro.api import RunConfig, StreamConfig
from repro.apps import wordcount as wc
from repro.serve.sched import SLOClass
from repro.serve.tier import ServeTier
from repro.stream.session import StreamSession


def make_fleet(tier: ServeTier, n_tenants: int, *, vocab: int = 64,
               n_docs: int = 8, doc_len: int = 4, seed: int = 0,
               backend: Optional[str] = None, value_bytes: int = 4,
               cache_dir: Optional[str] = None,
               slo_of: Optional[Callable[[int], SLOClass]] = None,
               group_of: Optional[Callable[[int], Optional[str]]] = None,
               crossover: float = 100.0) -> Dict[str, np.ndarray]:
    """Admit ``n_tenants`` small wordcount tenants; returns the per-tenant
    corpus mirrors the caller mutates alongside its submits.  The high
    default ``crossover`` pins every refresh on the incremental ``update``
    path, which is what the batched launch rides."""
    rng = np.random.default_rng(seed)
    mirrors: Dict[str, np.ndarray] = {}
    for i in range(n_tenants):
        docs = rng.integers(0, vocab, (n_docs, doc_len)).astype(np.int32)
        name = f"t{i:04d}"
        spec, data = wc.make_job(docs, vocab)
        tier.add(StreamSession(
            spec, data, name=name,
            config=RunConfig(backend=backend, onestep_path="mrbg",
                             value_bytes=value_bytes,
                             compilation_cache_dir=cache_dir),
            stream=StreamConfig(max_batch_delay=0.0, crossover=crossover,
                                prewarm=False)),
            slo=slo_of(i) if slo_of is not None else None,
            group=group_of(i) if group_of is not None else None)
        mirrors[name] = docs.copy()
    return mirrors


def submit_update(tier: ServeTier, mirrors: Dict[str, np.ndarray],
                  name: str, rng, vocab: int,
                  rows_per_update: int = 1) -> bool:
    """One document-rewrite record ('-' old row, '+' new row, for
    ``rows_per_update`` distinct documents) for ``name``.  Returns False
    when admission shed it (the mirror is left untouched, mirroring what
    a real producer would retry later).  Wider records shift cost from
    the submit path to the refresh engine — how overload cells saturate
    the tier without the submission loop being the bottleneck."""
    docs = mirrors[name]
    k = min(rows_per_update, len(docs))
    rows = rng.choice(len(docs), size=k, replace=False)
    new = rng.integers(0, vocab, (k,) + docs.shape[1:]).astype(np.int32)
    rids = np.repeat(rows.astype(np.int32), 2)
    buf = np.empty((2 * k,) + docs.shape[1:], np.int32)
    buf[0::2] = docs[rows]
    buf[1::2] = new
    admitted = tier.submit(name, rids, {"w": buf},
                           np.tile(np.array([-1, 1], np.int8), k))
    if admitted:
        docs[rows] = new
    return admitted


def run_rounds(tier: ServeTier, mirrors: Dict[str, np.ndarray],
               rounds: int, *, vocab: int = 64, seed: int = 1,
               rows_per_update: int = 1,
               timeout: float = 600.0) -> Dict[str, float]:
    """Closed-loop throughput cell: one update per tenant per round, drain
    between rounds.  Returns wall-clock and sustained updates/sec."""
    rng = np.random.default_rng(seed)
    names = list(mirrors)
    admitted = 0
    t0 = time.perf_counter()
    for _ in range(rounds):
        for name in names:
            admitted += submit_update(tier, mirrors, name, rng, vocab,
                                      rows_per_update)
        tier.drain(timeout=timeout)
    wall = time.perf_counter() - t0
    return {"wall_s": wall, "updates": admitted,
            "updates_per_sec": admitted / wall if wall > 0 else 0.0}


def open_loop_rate(tier: ServeTier, mirrors: Dict[str, np.ndarray],
                   updates: int, *, vocab: int = 64, seed: int = 3,
                   rows_per_update: int = 1,
                   timeout: float = 600.0) -> float:
    """Measured service capacity in updates/sec: submit ``updates``
    round-robin as fast as they are accepted (no per-round drain barrier),
    then drain.  Run with the tier's scheduler thread on, so the rate
    includes real submit/refresh overlap — this is what an overload cell
    should be calibrated against, not the stricter closed-loop rate."""
    rng = np.random.default_rng(seed)
    names = list(mirrors)
    t0 = time.perf_counter()
    for i in range(updates):
        submit_update(tier, mirrors, names[i % len(names)], rng, vocab,
                      rows_per_update)
    tier.drain(timeout=timeout)
    return updates / (time.perf_counter() - t0)


def overload_run(tier: ServeTier, mirrors: Dict[str, np.ndarray], *,
                 latency_tenant: str, duration_s: float,
                 offered_per_sec: float, latency_interval_s: float = 0.05,
                 vocab: int = 64, seed: int = 2, rows_per_update: int = 1,
                 timeout: float = 600.0) -> Dict[str, float]:
    """Open-loop overload cell: offer ``offered_per_sec`` updates/sec
    round-robin across the best-effort tenants (no waiting for drains)
    plus a steady trickle to ``latency_tenant``; admission control is what
    keeps the tier standing.  Call with the tier's scheduler thread
    running."""
    rng = np.random.default_rng(seed)
    best_effort = [n for n in mirrors if n != latency_tenant]
    interval = 1.0 / offered_per_sec
    t0 = time.perf_counter()
    offered = admitted = lat_updates = 0
    next_latency = t0
    while True:
        now = time.perf_counter()
        if now - t0 >= duration_s:
            break
        if now >= next_latency:
            submit_update(tier, mirrors, latency_tenant, rng, vocab)
            lat_updates += 1
            next_latency = now + latency_interval_s
        target = t0 + offered * interval
        if now < target:
            time.sleep(min(target - now, 0.005))
            continue
        name = best_effort[offered % len(best_effort)]
        admitted += submit_update(tier, mirrors, name, rng, vocab,
                                  rows_per_update)
        offered += 1
    tier.drain(timeout=timeout)
    return {"offered": offered, "admitted": admitted,
            "shed": offered - admitted,
            "shed_fraction": (offered - admitted) / max(offered, 1),
            "latency_updates": lat_updates,
            "duration_s": time.perf_counter() - t0}
