"""End-to-end training driver with checkpoint/restart, failure injection,
and straggler watchdog.

CPU-scale usage (the examples call this with a ~100M config):

  python -m repro.launch.train --arch qwen3-1.7b --preset 100m \
      --steps 300 --ckpt-every 50 --out /tmp/run1
  # kill it anywhere; re-running the same command resumes from the last
  # checkpoint and reproduces the exact same loss trajectory (deterministic
  # data pipeline + saved optimizer state).

On a pod this same driver runs under the production mesh with the
per-arch sharding rules (``--mesh pod16x16``): the step function is the one
the dry-run compiles.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.data import LMDataConfig, lm_batch_at_step
from repro.launch.steps import make_train_step
from repro.models import lm
from repro.models.config import ModelConfig, smoke_config
from repro.optim import AdamWConfig, adamw_init


def preset_config(cfg: ModelConfig, preset: str) -> ModelConfig:
    if preset == "full":
        return cfg
    if preset == "smoke":
        return smoke_config(cfg)
    if preset == "100m":
        # ~100M-param member of the same family (103M for the dense ones)
        kw = dict(n_layers=max(4, min(cfg.n_layers, 12)), d_model=768,
                  n_heads=12, n_kv_heads=min(cfg.n_kv_heads, 4),
                  d_ff=2048, head_dim=64, vocab=32768, remat="none",
                  local_window=256)
        if cfg.moe is not None:
            import dataclasses
            kw["moe"] = dataclasses.replace(
                cfg.moe, num_experts=8, top_k=min(cfg.moe.top_k, 2),
                d_ff_expert=768, d_ff_shared=768 if cfg.moe.num_shared else 0,
                ep_axes=("model",))
        if cfg.mla is not None:
            from repro.models.config import MLAConfig
            kw["mla"] = MLAConfig(q_lora_rank=128, kv_lora_rank=64,
                                  qk_nope_head_dim=64, qk_rope_head_dim=32,
                                  v_head_dim=64)
        if cfg.rglru is not None:
            from repro.models.config import RGLRUConfig
            kw["rglru"] = RGLRUConfig(d_rnn=512, conv_width=4,
                                      block_width=512)
        return cfg.replace(**kw)
    raise ValueError(preset)


class StragglerWatchdog:
    """Flags steps slower than ``ratio`` x the EWMA step time.

    On a real pod the action is re-sharding/evicting the slow host; here we
    record and surface the events (exercised in tests via injected sleeps).
    """

    def __init__(self, ratio: float = 2.0, alpha: float = 0.2):
        self.ratio = ratio
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.events = []

    def observe(self, step: int, dt: float) -> bool:
        if self.ewma is None:
            self.ewma = dt
            return False
        slow = dt > self.ratio * self.ewma
        if slow:
            self.events.append((step, dt, self.ewma))
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


def train(cfg: ModelConfig, *, steps: int, global_batch: int, seq_len: int,
          out: str, ckpt_every: int = 50, fail_at: Optional[int] = None,
          lr: float = 3e-4, log_every: int = 10, seed: int = 0):
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps, warmup=min(100, steps // 10 + 1))
    data_cfg = LMDataConfig(vocab=cfg.vocab, seq_len=seq_len,
                            global_batch=global_batch, seed=seed,
                            mask_prob=0.3 if cfg.family == "encoder" else 0.0)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    mgr = CheckpointManager(out, keep=3, every=ckpt_every)
    watchdog = StragglerWatchdog()

    params = lm.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw_init(params, opt_cfg)
    start = 0
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        {"params": params, "opt": opt})
    s, tree, meta = mgr.resume(like)
    if s is not None:
        params, opt = tree["params"], tree["opt"]
        start = s
        print(f"[train] resumed from step {s}")

    losses = []
    for step in range(start, steps):
        if fail_at is not None and step == fail_at:
            raise RuntimeError(f"injected failure at step {step}")
        batch_np = lm_batch_at_step(data_cfg, step)
        if not cfg.embed_inputs:
            # frontend stub: hash-embed the tokens (stands in for conv/VQ)
            rng = np.random.default_rng(1234)
            table = rng.normal(0, 1, (256, cfg.d_model)).astype(np.float32)
            batch_np["inputs"] = table[batch_np["inputs"] % 256]
        batch = jax.tree.map(jnp.asarray, batch_np)
        t0 = time.perf_counter()
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        watchdog.observe(step, dt)
        losses.append(loss)
        if step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"({dt*1e3:.0f} ms)", flush=True)
        mgr.maybe_save(step + 1, {"params": params, "opt": opt},
                       {"loss": loss})
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"straggler events: {len(watchdog.events)}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--preset", default="100m",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--out", default="/tmp/repro_train")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    import repro.configs as C
    cfg = preset_config(C.get(args.arch), args.preset)
    train(cfg, steps=args.steps, global_batch=args.global_batch,
          seq_len=args.seq_len, out=args.out, ckpt_every=args.ckpt_every,
          fail_at=args.fail_at, lr=args.lr)


if __name__ == "__main__":
    main()
