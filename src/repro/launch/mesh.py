"""Production mesh construction (single-pod 16x16 and multi-pod 2x16x16).

Defined as functions so importing this module never touches jax device
state (jax locks the device count on first backend init).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

# TPU v5e hardware constants used across the roofline analysis
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (~uni-directional)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_single_pod_with_pod_axis() -> Mesh:
    """(1, 16, 16) so the same ("pod","data","model") specs work 1-pod."""
    return jax.make_mesh((1, 16, 16), ("pod", "data", "model"))


def make_host_mesh(n: int = 8, axes=("data", "model")) -> Mesh:
    """Small mesh over forced host devices for tests."""
    devs = np.array(jax.devices()[:n])
    if len(axes) == 2:
        return Mesh(devs.reshape(2, n // 2), axes)
    return Mesh(devs.reshape((1, 2, n // 2)), axes)
