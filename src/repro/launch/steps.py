"""Jittable train / serve steps + input specs for every (arch x shape) cell.

``input_specs`` follows the shannon/kernels pattern: weak-type-correct,
shardable ``ShapeDtypeStruct`` stand-ins; nothing is allocated until a real
driver feeds arrays.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.common import pspec, valid_pspec
from repro.models.config import ModelConfig, SHAPES, ShapeCell
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            functools.partial(lm.lm_loss, cfg))(params, batch)
        params, opt_state, info = adamw_update(grads, opt_state, params,
                                               opt_cfg)
        metrics = {"loss": loss, **info}
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        return lm.lm_loss(cfg, params, batch)
    return eval_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, caches, tokens):
        return lm.serve_step(cfg, params, caches, tokens)
    return serve_step


def make_prefill_step(cfg: ModelConfig):
    """Inference-prefill: forward pass producing last-token logits."""
    def prefill_step(params, batch):
        inputs = batch["inputs"]
        s = inputs.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                               inputs.shape[:2])
        hidden, _ = lm.forward(cfg, params, inputs, pos)
        return lm.logits_fn(cfg, params, hidden[:, -1:, :])
    return prefill_step


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    if mesh is None:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Optional[Mesh]):
    """Training / prefill batch ShapeDtypeStructs."""
    rules = cfg.sharding
    b, s = cell.global_batch, cell.seq_len
    bsp = valid_pspec(rules, ("batch", None), (b, s), mesh) \
        if mesh is not None else None
    if cfg.embed_inputs:
        inputs = _sds((b, s), jnp.int32, mesh, bsp)
    else:
        esp = valid_pspec(rules, ("batch", None, "d_model"),
                          (b, s, cfg.d_model), mesh) \
            if mesh is not None else None
        inputs = _sds((b, s, cfg.d_model), cfg.dtype("compute"), mesh, esp)
    return {
        "inputs": inputs,
        "targets": _sds((b, s), jnp.int32, mesh, bsp),
        "mask": _sds((b, s), jnp.bool_, mesh, bsp),
    }


def decode_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Optional[Mesh]):
    """(tokens, caches) ShapeDtypeStructs for one serve_step."""
    rules = cfg.sharding
    b = cell.global_batch
    tsp = valid_pspec(rules, ("batch", None), (b, 1), mesh) \
        if mesh is not None else None
    tokens = _sds((b, 1), jnp.int32, mesh, tsp)
    caches = lm.cache_specs(cfg, b, cell.seq_len, mesh)
    return tokens, caches


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Optional[Mesh],
                opt_cfg: Optional[AdamWConfig] = None):
    """Everything ``jit(step).lower(...)`` needs for this cell.

    Returns (step_fn, example_args) where example_args are
    ShapeDtypeStructs.
    """
    opt_cfg = opt_cfg or AdamWConfig()
    params = lm.param_specs(cfg, mesh)
    if cell.kind == "train":
        opt = opt_specs(cfg, mesh, opt_cfg)
        return make_train_step(cfg, opt_cfg), (params, opt,
                                               batch_specs(cfg, cell, mesh))
    if cell.kind == "prefill":
        return make_prefill_step(cfg), (params, batch_specs(cfg, cell, mesh))
    tokens, caches = decode_specs(cfg, cell, mesh)
    return make_serve_step(cfg), (params, caches, tokens)


def opt_specs(cfg: ModelConfig, mesh: Optional[Mesh],
              opt_cfg: AdamWConfig):
    params = lm.param_specs(cfg, mesh)
    def conv(p):
        if mesh is None:
            return jax.ShapeDtypeStruct(p.shape, opt_cfg.opt_dtype)
        return jax.ShapeDtypeStruct(p.shape, opt_cfg.opt_dtype,
                                    sharding=p.sharding)
    mv = jax.tree.map(conv, params)
    step = _sds((), jnp.int32, mesh, P())
    return {"m": mv, "v": mv, "step": step}
