import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the jitted train/prefill/serve step with ShapeDtypeStruct
     stand-ins (no allocation),
  3. compiles, prints memory_analysis() / cost_analysis(),
  4. parses the collective ops (all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute) out of the compiled HLO and sums their
     operand bytes,
  5. writes everything to artifacts/dryrun/<arch>__<shape>__<mesh>.json
     for the roofline analysis (launch/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_TUPLE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*\(([^)]*)\)")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str):
    """Sum output-shape bytes of every collective op, by kind."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        kind, dtype, dims = m.groups()
        out[kind] = out.get(kind, 0) + _shape_bytes(dtype, dims)
    for m in _TUPLE_RE.finditer(hlo_text):
        kind, inner = m.groups()
        for part in re.finditer(r"([a-z0-9]+)\[([0-9,]*)\]", inner):
            out[kind] = out.get(kind, 0) + _shape_bytes(*part.groups())
    return out


def _fix_rules_for_mesh(cfg, multi_pod: bool):
    from repro.models.config import ShardingRules
    if multi_pod:
        return cfg
    # single-pod mesh has no "pod" axis: drop it from batch sharding
    rules = cfg.sharding
    import dataclasses
    batch = tuple(a for a in rules.batch if a != "pod")
    return cfg.replace(sharding=dataclasses.replace(rules, batch=batch))


def _compile_once(cfg, cell, mesh):
    from repro.launch.steps import input_specs
    from repro.models import meshctx
    meshctx.set_mesh(mesh)
    t0 = time.time()
    with mesh:
        step, args = input_specs(cfg, cell, mesh)
        lowered = jax.jit(step).lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
    return {
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1)),
        "bytes": float(cost.get("bytes accessed", -1)),
        "coll": coll,
        "memory": {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
        },
    }


def _probe_cfg(cfg, k: int):
    """Unrolled k-cycle config for per-layer cost extrapolation.

    ``jax.jit``-compiled scans report the while-body cost ONCE, so the
    full-model compile under-counts flops by ~n_layers; two unrolled probes
    (k=1, 2) recover the per-cycle cost exactly.
    """
    n_layers = len(cfg.prefix_blocks) + len(cfg.block_pattern) * k
    return cfg.replace(n_layers=n_layers, scan_layers=False)


def dryrun_cell(arch: str, shape: str, multi_pod: bool,
                overrides=None, tag: str = "baseline",
                verbose: bool = True, probes: bool = True):
    import repro.configs as C
    from repro.launch.mesh import make_production_mesh
    from repro.models.config import SHAPES

    cfg = C.get(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    cfg = _fix_rules_for_mesh(cfg, multi_pod)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=multi_pod)
    meshname = "pod2x16x16" if multi_pod else "pod16x16"

    full = _compile_once(cfg, cell, mesh)

    rec = {
        "arch": arch, "shape": shape, "mesh": meshname, "tag": tag,
        "devices": int(mesh.devices.size),
        "cycles": cfg.cycles,
        "full": full,
    }

    if probes:
        p1 = _compile_once(_probe_cfg(cfg, 1), cell, mesh)
        p2 = _compile_once(_probe_cfg(cfg, 2), cell, mesh)
        per_cycle_fl = p2["flops"] - p1["flops"]
        per_cycle_by = p2["bytes"] - p1["bytes"]
        rem_frac = len(cfg.remainder_blocks) / len(cfg.block_pattern)
        scale = (cfg.cycles - 1) + rem_frac
        est = {
            "flops_per_device": p1["flops"] + per_cycle_fl * scale,
            "bytes_per_device": p1["bytes"] + per_cycle_by * scale,
            "collective_bytes_per_device": {},
        }
        kinds = set(p1["coll"]) | set(p2["coll"])
        for kk in kinds:
            c1, c2 = p1["coll"].get(kk, 0), p2["coll"].get(kk, 0)
            est["collective_bytes_per_device"][kk] = c1 + (c2 - c1) * scale
        rec["probe1"] = p1
        rec["probe2"] = p2
        rec["estimated"] = est

    if verbose:
        print(f"[{arch} x {shape} x {meshname} x {tag}] "
              f"lower {full['lower_s']:.0f}s compile {full['compile_s']:.0f}s")
        print("  memory_analysis:", full["memory"])
        if probes:
            print("  est flops/dev %.3e bytes/dev %.3e" %
                  (rec["estimated"]["flops_per_device"],
                   rec["estimated"]["bytes_per_device"]))
            print("  est collective bytes/dev:",
                  {k: f"{v:.3e}" for k, v in
                   rec["estimated"]["collective_bytes_per_device"].items()})
    ART.mkdir(parents=True, exist_ok=True)
    out = ART / f"{arch}__{shape}__{meshname}__{tag}.json"
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", nargs="*", default=[],
                    help="config overrides: key=value (int/float/str)")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for conv in (int, float):
            try:
                v = conv(v)
                break
            except ValueError:
                continue
        overrides[k] = v

    import repro.configs as C

    cells = []
    if args.all:
        cells = C.all_cells()
    else:
        cells = [(args.arch, args.shape)]

    meshname = "pod2x16x16" if args.multi_pod else "pod16x16"
    failures = []
    for arch, shape in cells:
        path = ART / f"{arch}__{shape}__{meshname}__{args.tag}.json"
        if args.skip_existing and path.exists():
            print(f"skip {arch} x {shape} (exists)")
            continue
        try:
            dryrun_cell(arch, shape, args.multi_pod, tag=args.tag,
                        overrides=overrides or None)
        except Exception as e:
            traceback.print_exc()
            failures.append((arch, shape, str(e)[:200]))
    if failures:
        print("FAILURES:")
        for f in failures:
            print(" ", f)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
