"""Render EXPERIMENTS.md tables from the dry-run artifacts.

Usage: python -m repro.launch.report   (rewrites the marked sections)
"""
from __future__ import annotations

import json
import re
from pathlib import Path

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS
from repro.launch.roofline import ART, analyze, load_all, markdown_table

ROOT = Path(__file__).resolve().parents[3]

HILL_CELLS = [
    ("deepseek_v3_671b", "train_4k",
     ["baseline", "a2a", "a2a_bw", "a2a_bw_dots"]),
    ("llama4_scout_17b_a16e", "prefill_32k",
     ["baseline", "a2a", "a2a_bw", "a2a_bw_blk4k"]),
    ("chameleon_34b", "train_4k",
     ["baseline", "blockwise", "bw_dots", "bw_dots_blk4k"]),
]


def perf_table() -> str:
    out = []
    for arch, shape, tags in HILL_CELLS:
        out.append(f"\n**{arch} × {shape}**\n")
        out.append("| variant | compute s | memory s | collective s "
                   "| t_step | RF | vs baseline |")
        out.append("|---|---|---|---|---|---|---|")
        base_step = None
        for tag in tags:
            f = ART / f"{arch}__{shape}__pod16x16__{tag}.json"
            if not f.exists():
                out.append(f"| {tag} | (not compiled) | | | | | |")
                continue
            rec = json.loads(f.read_text())
            a = analyze(rec)
            if base_step is None:
                base_step = a["t_step_s"]
            out.append(
                f"| {tag} | {a['t_compute_s']:.1f} | {a['t_memory_s']:.1f} "
                f"| {a['t_collective_s']:.1f} | **{a['t_step_s']:.1f}** "
                f"| {a['roofline_fraction']:.3f} "
                f"| {base_step / a['t_step_s']:.1f}× |")
    return "\n".join(out)


def multipod_summary() -> str:
    recs1 = {(r["arch"], r["shape"]): r["analysis"]
             for r in load_all("baseline", "pod16x16")}
    recs2 = load_all("baseline", "pod2x16x16")
    rows = ["| arch | shape | 1-pod t_step | 2-pod t_step | scaling eff |",
            "|---|---|---|---|---|"]
    for r in recs2:
        a2 = r["analysis"]
        a1 = recs1.get((r["arch"], r["shape"]))
        if a1 is None or "error" in a2 or "error" in a1:
            continue
        # same global work on 2x devices => ideal t_step ratio = 0.5
        eff = a1["t_step_s"] / (2 * a2["t_step_s"]) if a2["t_step_s"] else 0
        rows.append(f"| {r['arch']} | {r['shape']} | {a1['t_step_s']:.2f} "
                    f"| {a2['t_step_s']:.2f} | {min(eff, 9.99):.2f} |")
    return "\n".join(rows)


def main():
    md = (ROOT / "EXPERIMENTS.md").read_text()
    table = markdown_table(load_all("baseline", "pod16x16"))
    md = re.sub(r"<!-- ROOFLINE_TABLE -->[\s\S]*?(?=\nReading the baseline)",
                "<!-- ROOFLINE_TABLE -->\n" + table + "\n",
                md)
    md = re.sub(r"<!-- PERF_LOG -->[\s\S]*?(?=\nStopping criterion)",
                "<!-- PERF_LOG -->\n" + perf_table() + "\n",
                md)
    md = re.sub(r"<!-- MULTIPOD -->[\s\S]*?(?=\n## |$)",
                "<!-- MULTIPOD -->\n" + multipod_summary() + "\n",
                md, count=1)
    (ROOT / "EXPERIMENTS.md").write_text(md)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
