"""Roofline analysis over the dry-run artifacts (§Roofline deliverable).

For every (arch x shape x mesh x tag) JSON produced by launch/dryrun.py:

  compute term    = HLO_flops_per_device / 197 TFLOP/s        (bf16, v5e)
  memory term     = HLO_bytes_per_device / 819 GB/s
  collective term = collective_bytes_per_device / 50 GB/s     (ICI per chip)

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N_active·B (decode),
the useful-compute ratio MODEL_FLOPS/HLO_FLOPS, the dominant term, and the
roofline fraction

  RF = (MODEL_FLOPS / (devices · peak)) / max(terms)

i.e. "ideal useful-compute time over modeled execution time" — RF = 1 means
the step is pure, perfectly-overlapped useful matmul.

Usage: python -m repro.launch.roofline [--tag baseline] [--md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def model_params(cfg) -> int:
    """Total parameter count from the declarative plan."""
    import numpy as np
    from repro.models import lm as lmm
    from repro.models.common import ParamSpec
    import jax
    plan = lmm.plan_model(cfg)
    leaves = jax.tree.leaves(plan,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def active_params(cfg) -> int:
    """Active (per-token) parameters: subtract unrouted experts."""
    total = model_params(cfg)
    if cfg.moe is None:
        return total
    per_expert = cfg.d_model * 2 * cfg.moe.d_ff_expert + \
        cfg.moe.d_ff_expert * cfg.d_model
    n_moe_layers = sum(1 for k in (cfg.prefix_blocks +
                                   cfg.block_pattern * cfg.cycles +
                                   cfg.remainder_blocks)
                       if k == "attn_moe")
    return total - n_moe_layers * (cfg.moe.num_experts - cfg.moe.top_k) * \
        per_expert


def model_flops(arch: str, shape: str, devices: int) -> float:
    import repro.configs as C
    from repro.models.config import SHAPES
    cfg = C.get(arch)
    cell = SHAPES[shape]
    n_act = active_params(cfg)
    if cfg.embed_inputs:
        # embeddings don't do matmul work per token
        n_act -= cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 0)
    tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return 6.0 * n_act * tokens
    if cell.kind == "prefill":
        return 2.0 * n_act * tokens
    # decode: one token per sequence + attention over the KV cache
    import math
    attn = 0.0
    if cfg.family not in ("xlstm",):
        kv_read = 4.0 * cfg.n_layers * cfg.n_heads * cfg.head_dim * \
            min(cell.seq_len, 10**9)
        attn = kv_read * cell.global_batch
    return 2.0 * n_act * cell.global_batch + attn


def useful_decode_bytes(arch: str, shape: str) -> float:
    """Minimum HBM traffic for one decode step: read every live parameter
    once + read the KV/recurrent cache once (global bytes)."""
    import numpy as np
    import jax
    import repro.configs as C
    from repro.models import lm as lmm
    from repro.models.common import ParamSpec
    from repro.models.config import SHAPES
    cfg = C.get(arch)
    cell = SHAPES[shape]
    pbytes = 2.0 * active_params(cfg)          # bf16
    cplan = lmm.plan_caches(cfg, cell.global_batch, cell.seq_len)
    cplan["pos"] = ParamSpec((), (), "zeros")
    leaves = jax.tree.leaves(cplan,
                             is_leaf=lambda x: isinstance(x, ParamSpec))
    cbytes = 2.0 * sum(int(np.prod(s.shape)) for s in leaves)
    return pbytes + cbytes


def analyze(rec: dict) -> dict:
    est = rec.get("estimated") or {
        "flops_per_device": rec["full"]["flops"],
        "bytes_per_device": rec["full"]["bytes"],
        "collective_bytes_per_device": rec["full"]["coll"],
    }
    devices = rec["devices"]
    fl = est["flops_per_device"]
    by = est["bytes_per_device"]
    coll = sum(est["collective_bytes_per_device"].values())
    t_compute = fl / PEAK_FLOPS
    t_memory = by / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], devices)
    t_step = max(terms.values())
    from repro.models.config import SHAPES
    is_decode = SHAPES[rec["shape"]].kind == "decode"
    if is_decode:
        # decode is inherently memory-bound: the roofline resource is HBM.
        ub = useful_decode_bytes(rec["arch"], rec["shape"])
        t_ideal = (ub / devices) / HBM_BW
        useful = ub / max(by * devices, 1e-9)
    else:
        t_ideal = mf / (devices * PEAK_FLOPS)
        useful = mf / max(fl * devices, 1e-9)
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": t_ideal / max(t_step, 1e-30),
        "roofline_kind": "memory(HBM)" if is_decode else "compute(MXU)",
        "t_step_s": t_step,
        "temp_gib": (rec["full"]["memory"]["temp_size"] or 0) / 2**30,
        "args_gib": (rec["full"]["memory"]["argument_size"] or 0) / 2**30,
    }


def load_all(tag: str, mesh: str = "pod16x16"):
    out = []
    for f in sorted(ART.glob(f"*__{mesh}__{tag}.json")):
        rec = json.loads(f.read_text())
        if rec["arch"] == "qwen3-1.7b":   # alias duplicate of qwen3_1_7b
            continue
        try:
            rec["analysis"] = analyze(rec)
        except Exception as e:
            rec["analysis"] = {"error": str(e)}
        out.append(rec)
    return out


def markdown_table(recs) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | RF | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for r in recs:
        a = r["analysis"]
        if "error" in a:
            rows.append(f"| {r['arch']} | {r['shape']} | ERR {a['error']} "
                        "| | | | | | |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {a['t_compute_s']:.3f} "
            f"| {a['t_memory_s']:.3f} | {a['t_collective_s']:.3f} "
            f"| **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['roofline_fraction']:.3f} | {a['temp_gib']:.0f} |")
    return hdr + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default="pod16x16")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    recs = load_all(args.tag, args.mesh)
    if args.md:
        print(markdown_table(recs))
        return
    for r in recs:
        a = r["analysis"]
        if "error" in a:
            print(f"{r['arch']:26s} {r['shape']:12s} ERR {a['error']}")
            continue
        print(f"{r['arch']:26s} {r['shape']:12s} "
              f"C {a['t_compute_s']:8.3f}s M {a['t_memory_s']:8.3f}s "
              f"X {a['t_collective_s']:8.3f}s -> {a['dominant']:10s} "
              f"useful {a['useful_ratio']:5.2f} RF {a['roofline_fraction']:.3f}")


if __name__ == "__main__":
    main()
