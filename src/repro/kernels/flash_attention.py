"""Flash attention (fwd) Pallas kernel: GQA + causal + local window + softcap.

Tiling: grid = (batch, q_heads, q_blocks); the KV sequence is walked inside
the kernel with ``jax.lax.fori_loop`` over VMEM-resident KV blocks, carrying
the streaming-softmax state (m, l, acc) in registers/VMEM — the standard
IO-aware schedule: HBM traffic is O(S·d) per head instead of O(S²).

Block sizes default to (q=128, kv=128) — MXU-aligned (128x128 systolic
array) and comfortably inside the ~16 MB/core VMEM for head_dim <= 256:
q_blk·hd + 2·kv_blk·hd + q_blk·kv_blk floats ≈ 0.3 MB at fp32.

ref.py oracle: ``mha_ref`` (dense masked softmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import mha_ref  # noqa: F401  (back-compat)

NEG = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_blk: int, causal: bool,
            window: int, softcap: float, q_blk: int, seq_k: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    qi = pl.program_id(2)

    q = q_ref[0, 0]                        # [q_blk, hd]
    hd = q.shape[-1]
    q_pos = qi * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, 1), 0)

    nkv = seq_k // kv_blk

    def body(kv_i, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (slice(0, 1), slice(0, 1),
                            pl.dslice(kv_i * kv_blk, kv_blk),
                            slice(None)))[0, 0]
        v = pl.load(v_ref, (slice(0, 1), slice(0, 1),
                            pl.dslice(kv_i * kv_blk, kv_blk),
                            slice(None)))[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
        s = s / (hd ** 0.5)
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        k_pos = kv_i * kv_blk + jax.lax.broadcasted_iota(
            jnp.int32, (1, kv_blk), 1)
        mask = jnp.ones((q_blk, kv_blk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if window > 0:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask, s, NEG)
        mb = jnp.maximum(m, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - mb)
        corr = jnp.exp(m - mb)
        l2 = l * corr + p.sum(axis=1, keepdims=True)
        acc2 = acc * corr + jnp.dot(p.astype(v.dtype), v,
                                    preferred_element_type=jnp.float32)
        return mb, l2, acc2

    m0 = jnp.full((q_blk, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((q_blk, 1), jnp.float32)
    a0 = jnp.zeros((q_blk, hd), jnp.float32)
    if causal:
        # only KV blocks at or before this q block contribute
        hi = jnp.minimum((qi + 1) * q_blk + kv_blk - 1, seq_k) // kv_blk
    else:
        hi = nkv
    m, l, acc = jax.lax.fori_loop(0, hi, body, (m0, l0, a0))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "q_blk", "kv_blk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_blk: int = 128,
                    kv_blk: int = 128, interpret: bool = True) -> jax.Array:
    """q [B, H, Sq, hd]; k/v [B, KH, Sk, hd] (GQA: H % KH == 0)."""
    b, h, sq, hd = q.shape
    kh, sk = k.shape[1], k.shape[2]
    rep = h // kh
    q_blk = min(q_blk, sq)
    kv_blk = min(kv_blk, sk)
    assert sq % q_blk == 0 and sk % kv_blk == 0

    out = pl.pallas_call(
        functools.partial(_kernel, kv_blk=kv_blk, causal=causal,
                          window=window, softcap=softcap, q_blk=q_blk,
                          seq_k=sk),
        grid=(b, h, sq // q_blk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, hd), lambda b_, h_, i: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, sk, hd),
                         lambda b_, h_, i: (b_, h_ // rep, 0, 0)),
            pl.BlockSpec((1, 1, sk, hd),
                         lambda b_, h_, i: (b_, h_ // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, hd),
                               lambda b_, h_, i: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
    return out
