"""Backend dispatch for the engine's two hot paths: shuffle-sort and Reduce.

Every engine layer (one-step, incremental, iterative, incremental-iterative,
distributed) funnels its shuffle and Reduce work through the two entry
points here:

  * :func:`sort_pairs`      — lexicographic stable sort of (k2, mk) with a
    permutation output; arbitrary pytree payloads are gathered once.
  * :func:`segment_reduce`  — segment reduction for all four ``Reducer``
    monoids (sum / min / max / mean) over pytree values, with an explicit
    validity mask and per-segment counts.

Backends:

  * ``"xla"``    — jax.lax.sort / jax.ops.segment_* (the portable fallback).
  * ``"pallas"`` — the Pallas TPU kernels (bitonic network, one-hot MXU
    matmul); interpret mode on CPU, native lowering on TPU.
  * ``"auto"``   — pallas on TPU, xla elsewhere.

Selection precedence: per-call ``backend=`` argument > :func:`set_backend`
(or the :class:`use_backend` context manager) > the ``REPRO_BACKEND``
environment variable > ``"auto"``.  Callers that jit must resolve the
backend *outside* the traced function (``resolve_backend``) and pass it as
a static argument so that flipping the backend retraces instead of hitting
a stale cache — the engine layers all follow this pattern.

Both backends implement the identical contract — same masking semantics,
same tie-breaking (total order by (k2, mk, row index)) — so they agree
bit-for-bit on integer data and to reordering-of-additions on floats;
``tests/test_backend_parity.py`` holds them to it.
"""
from __future__ import annotations

import math
import os
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

BACKENDS = ("xla", "pallas", "auto")
_ENV_VAR = "REPRO_BACKEND"
_configured: Optional[str] = None


def set_backend(name: Optional[str]) -> None:
    """Set the process-wide backend (``None`` reverts to env/auto)."""
    global _configured
    if name is not None and name not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {name!r}")
    _configured = name


def get_backend() -> str:
    """The currently configured (possibly still ``'auto'``) backend."""
    if _configured is not None:
        return _configured
    env = os.environ.get(_ENV_VAR)
    if env:
        if env not in BACKENDS:
            raise ValueError(
                f"{_ENV_VAR} must be one of {BACKENDS}, got {env!r}")
        return env
    return "auto"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve the per-call override / config / env chain to xla|pallas."""
    b = backend if backend is not None else get_backend()
    if b not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {b!r}")
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "xla"
    return b


class use_backend:
    """Context manager: ``with use_backend('pallas'): ...``"""

    def __init__(self, name: Optional[str]):
        self.name = name
        self.prev: Optional[str] = None

    def __enter__(self):
        global _configured
        self.prev = _configured
        set_backend(self.name)
        return self

    def __exit__(self, *exc):
        global _configured
        _configured = self.prev
        return False


def _interpret() -> bool:
    """Pallas interpret mode everywhere but real TPU.

    Delegates to :func:`repro.kernels.sort_u32.default_interpret`, which
    honors the ``REPRO_PALLAS_INTERPRET`` override.
    """
    from repro.kernels.sort_u32 import default_interpret
    return default_interpret()


# ---------------------------------------------------------------------------
# sort_pairs: the shuffle sort
# ---------------------------------------------------------------------------

class SortedPairs(NamedTuple):
    k2: jax.Array        # [N] sorted primary keys
    mk: jax.Array        # [N] co-sorted secondary keys
    payload: Any         # pytree of [N, ...] gathered through perm
    perm: jax.Array      # [N] int32, k2_sorted == k2[perm]


def sort_pairs(k2: jax.Array, mk: Optional[jax.Array] = None,
               payload: Any = None, *, num_keys: int = 2,
               backend: Optional[str] = None) -> SortedPairs:
    """Stable lexicographic sort by (k2[, mk]); ties keep input order.

    Validity is the caller's concern: mask invalid rows' k2 to INVALID_KEY
    beforehand and they sort to the tail.  ``payload`` may be any pytree of
    [N, ...] arrays; every leaf is gathered once through the permutation.
    """
    bk = resolve_backend(backend)
    n = k2.shape[0]
    if mk is None:
        mk = jnp.zeros(n, jnp.int32)
        num_keys = 1
    if bk == "pallas":
        from repro.kernels.sort_u32 import sort_lex_pallas
        lo = mk if num_keys >= 2 else jnp.zeros(n, jnp.int32)
        k2s, los, perm = sort_lex_pallas(k2, lo, interpret=_interpret())
        mks = los if num_keys >= 2 else jnp.take(mk, perm, axis=0)
    else:
        iota = jnp.arange(n, dtype=jnp.int32)
        if num_keys <= 1:
            k2s, perm = jax.lax.sort((k2, iota), num_keys=1, is_stable=True)
        else:
            k2s, _, perm = jax.lax.sort((k2, mk, iota), num_keys=2,
                                        is_stable=True)
        mks = jnp.take(mk, perm, axis=0)
    gathered = jax.tree.map(lambda a: jnp.take(a, perm, axis=0), payload)
    return SortedPairs(k2s, mks, gathered, perm)


# ---------------------------------------------------------------------------
# segment_reduce: the Reduce stage
# ---------------------------------------------------------------------------

def _kind_of(reducer) -> str:
    kind = getattr(reducer, "kind", reducer)
    if kind not in ("sum", "min", "max", "mean"):
        raise ValueError(f"unknown reducer kind {kind!r}")
    return kind


def _identity_scalar(kind: str, dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        info = jnp.finfo(dtype)
    else:
        info = jnp.iinfo(dtype)
    return info.max if kind == "min" else info.min


def _mask_leaf(kind: str, leaf: jax.Array, valid: jax.Array) -> jax.Array:
    mask = valid.reshape((-1,) + (1,) * (leaf.ndim - 1))
    if kind in ("min", "max"):
        return jnp.where(mask, leaf, _identity_scalar(kind, leaf.dtype))
    return jnp.where(mask, leaf, 0).astype(leaf.dtype)


def segment_reduce(reducer, segment_ids: jax.Array, values: Any,
                   valid: jax.Array, num_segments: int,
                   indices_are_sorted: bool = False,
                   backend: Optional[str] = None):
    """Reduce ``values`` into ``num_segments`` groups.

    ``reducer`` is a ``repro.core.kvstore.Reducer`` or a bare kind string.
    Returns (accumulated values pytree [K, ...], counts [K] int32); mean
    returns the *sum* (``finalize_reduce`` divides by the counts).  Invalid
    rows are routed to a scratch segment (index ``num_segments``) so they
    never pollute real groups.
    """
    bk = resolve_backend(backend)
    kind = _kind_of(reducer)
    seg = jnp.where(valid, segment_ids, num_segments).astype(jnp.int32)

    if bk == "pallas":
        return _segment_reduce_pallas(kind, seg, values, valid, num_segments)
    return _segment_reduce_xla(kind, seg, values, valid, num_segments,
                               indices_are_sorted)


def _segment_reduce_xla(kind, seg, values, valid, num_segments,
                        indices_are_sorted):
    op = {"sum": jax.ops.segment_sum, "mean": jax.ops.segment_sum,
          "min": jax.ops.segment_min, "max": jax.ops.segment_max}[kind]

    def _one(leaf):
        leaf = _mask_leaf(kind, leaf, valid)
        out = op(leaf, seg, num_segments=num_segments + 1,
                 indices_are_sorted=indices_are_sorted)
        return out[:num_segments]

    acc = jax.tree.map(_one, values)
    counts = jax.ops.segment_sum(valid.astype(jnp.int32), seg,
                                 num_segments=num_segments + 1,
                                 indices_are_sorted=indices_are_sorted)
    return acc, counts[:num_segments]


def _segment_reduce_pallas(kind, seg, values, valid, num_segments):
    from repro.kernels.segment_reduce import (
        segment_minmax_mxu, segment_sum_counts_mxu, segment_sum_mxu,
    )
    interp = _interpret()
    leaves, treedef = jax.tree.flatten(values)
    counts = None
    outs = []
    for leaf in leaves:
        masked = _mask_leaf(kind, leaf, valid)
        width = math.prod(masked.shape[1:])          # -1 breaks on 0 rows
        flat = masked.reshape(masked.shape[0], width)
        if kind in ("sum", "mean"):
            out_dtype = (jnp.int32 if jnp.issubdtype(leaf.dtype, jnp.integer)
                         else jnp.float32)
            if counts is None:
                # the counts ride the first sum leaf's launch for free
                # (one-hot column sums; invalid rows sit in the scratch
                # segment, so segments < num_segments count valid rows only)
                out, cnt = segment_sum_counts_mxu(
                    seg, flat, num_segments + 1, out_dtype=out_dtype,
                    interpret=interp)
                counts = cnt[:num_segments]
            else:
                out = segment_sum_mxu(seg, flat, num_segments + 1,
                                      out_dtype=out_dtype, interpret=interp)
            out = out.astype(leaf.dtype)
        else:
            out = segment_minmax_mxu(kind, seg, flat, num_segments + 1,
                                     interpret=interp)
        out = out[:num_segments]
        outs.append(out.reshape((num_segments,) + leaf.shape[1:]))

    acc = jax.tree.unflatten(treedef, outs)
    if counts is None:
        counts = segment_sum_mxu(seg, valid.astype(jnp.int32)[:, None],
                                 num_segments + 1, out_dtype=jnp.int32,
                                 interpret=interp)[:num_segments, 0]
    return acc, counts


# ---------------------------------------------------------------------------
# shuffle_reduce: the fused shuffle+merge+Reduce hot path
# ---------------------------------------------------------------------------

class ShuffleReduced(NamedTuple):
    """Sorted+merged rows plus the per-affected-key reduction."""

    k2: jax.Array        # [N] sorted primary keys (invalid rows at tail)
    mk: jax.Array        # [N] co-sorted secondary keys
    values: Any          # pytree of [N, ...] gathered through perm
    live: jax.Array      # [N] bool: last writer per (k2, mk), not a tombstone
    perm: jax.Array      # [N] int32 sort permutation
    acc: Any             # pytree of [key_cap, ...] accumulated live values
    counts: jax.Array    # [key_cap] int32 live rows per affected key


_INT32_MAX = 2**31 - 1
_FUSED_MAX_D = 512       # value width cap for the fused kernel's VMEM tile
_FUSED_MAX_KEYS = 4096   # affected-key cap (single one-hot block per tile)


def _can_fuse(kind: str, leaves, n: int, key_cap: int) -> bool:
    return (kind in ("sum", "mean") and len(leaves) == 1
            and leaves[0].ndim <= 2 and n > 0
            and 0 < key_cap <= _FUSED_MAX_KEYS
            and (leaves[0].size // max(n, 1)) <= _FUSED_MAX_D)


def shuffle_reduce(reducer, k2: jax.Array, mk: jax.Array, values: Any,
                   valid: jax.Array, sign: jax.Array,
                   affected_keys: jax.Array, *,
                   backend: Optional[str] = None,
                   fused: Optional[bool] = None) -> ShuffleReduced:
    """Shuffle-sort, last-writer-wins merge, and reduce in one call.

    The engine's whole merge hot path: rows are sorted stably by (k2, mk)
    (invalid rows masked to the tail), the last row of each (k2, mk) run
    survives if its sign is positive (tombstones delete), and the live
    rows' values are reduced into the slots of ``affected_keys`` (sorted
    ascending, unique, padded with int32 max; ``counts`` counts live rows
    per slot, mean division stays with ``finalize_reduce``).

    ``fused=None`` picks the fused Pallas kernel automatically when the
    backend is pallas and the monoid supports it (sum/mean, single
    modest-width value leaf); ``False`` forces the composed path;
    ``True`` requires fusion and raises where unsupported.  Both paths
    implement the identical contract — the composed path on xla is the
    bitwise reference.
    """
    bk = resolve_backend(backend)
    kind = _kind_of(reducer)
    n = k2.shape[0]
    key_cap = affected_keys.shape[0]
    leaves, treedef = jax.tree.flatten(values)
    fusable = bk == "pallas" and _can_fuse(kind, leaves, n, key_cap)
    if fused and not fusable:
        raise ValueError(
            "fused shuffle_reduce requires the pallas backend, a sum/mean "
            "reducer, and a single value leaf of width <= "
            f"{_FUSED_MAX_D} with 0 < key_cap <= {_FUSED_MAX_KEYS}")
    if fusable and fused is not False:
        return _shuffle_reduce_fused(kind, k2, mk, leaves[0], treedef,
                                     valid, sign, affected_keys)
    return _shuffle_reduce_composed(reducer, kind, bk, k2, mk, values,
                                    valid, sign, affected_keys)


def _shuffle_reduce_composed(reducer, kind, bk, k2, mk, values, valid, sign,
                             affected_keys) -> ShuffleReduced:
    n = k2.shape[0]
    key_cap = affected_keys.shape[0]
    k2m = jnp.where(valid, k2, jnp.int32(_INT32_MAX))
    res = sort_pairs(k2m, mk, (values, valid, sign), num_keys=2, backend=bk)
    vals_s, valid_s, sign_s = res.payload

    # last-writer-wins per (k2, mk); tombstones delete
    nk2 = jnp.roll(res.k2, -1)
    nmk = jnp.roll(res.mk, -1)
    is_last = jnp.logical_or(
        jnp.arange(n) == n - 1,
        jnp.logical_or(nk2 != res.k2, nmk != res.mk))
    live = valid_s & is_last & (sign_s > 0)

    # route each live row to its affected-key slot
    local = jnp.searchsorted(affected_keys, res.k2).astype(jnp.int32)
    in_set = jnp.take(affected_keys,
                      jnp.clip(local, 0, key_cap - 1)) == res.k2
    acc, counts = segment_reduce(reducer, local, vals_s, live & in_set,
                                 key_cap, backend=bk)
    return ShuffleReduced(res.k2, res.mk, vals_s, live, res.perm, acc,
                          counts)


def _shuffle_reduce_fused(kind, k2, mk, leaf, treedef, valid, sign,
                          affected_keys) -> ShuffleReduced:
    from repro.kernels.fused import fused_shuffle_reduce
    key_cap = affected_keys.shape[0]
    out_dtype = (jnp.int32 if jnp.issubdtype(leaf.dtype, jnp.integer)
                 else jnp.float32)
    k2m = jnp.where(valid, k2, jnp.int32(_INT32_MAX))
    flat = leaf.reshape(leaf.shape[0], -1)
    k2s, mks, vals_s, live, perm, acc, counts = fused_shuffle_reduce(
        k2m, mk, flat, valid, sign, affected_keys, out_dtype=out_dtype,
        interpret=_interpret())
    vals_s = vals_s.reshape(leaf.shape)
    acc = acc.astype(leaf.dtype).reshape((key_cap,) + leaf.shape[1:])
    return ShuffleReduced(k2s, mks, jax.tree.unflatten(treedef, [vals_s]),
                          live, perm, jax.tree.unflatten(treedef, [acc]),
                          counts)


# ---------------------------------------------------------------------------
# group_reduce: the dql lowering shim
# ---------------------------------------------------------------------------

def group_reduce(reducer, keys: jax.Array, values: Any, valid: jax.Array,
                 num_groups: int, backend: Optional[str] = None):
    """Grouped reduce over a dense group-id space (``repro.dql`` lowering).

    Same contract as :func:`segment_reduce` — returns
    ``(accumulated pytree [num_groups, ...], counts [num_groups] int32)`` —
    but accepts the delta algebra's emission convention directly: negative
    or out-of-range keys mask the row (the idiom fused group_by chains use
    for padded fanout slots), composing with ``valid``.
    """
    keys = jnp.asarray(keys, jnp.int32)
    live = jnp.asarray(valid, jnp.bool_) & (keys >= 0) & (keys < num_groups)
    return segment_reduce(reducer, keys, values, live, num_groups,
                          backend=backend)
