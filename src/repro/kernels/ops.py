"""Backend dispatch for the engine's two hot paths: shuffle-sort and Reduce.

Every engine layer (one-step, incremental, iterative, incremental-iterative,
distributed) funnels its shuffle and Reduce work through the two entry
points here:

  * :func:`sort_pairs`      — lexicographic stable sort of (k2, mk) with a
    permutation output; arbitrary pytree payloads are gathered once.
  * :func:`segment_reduce`  — segment reduction for all four ``Reducer``
    monoids (sum / min / max / mean) over pytree values, with an explicit
    validity mask and per-segment counts.

Backends:

  * ``"xla"``    — jax.lax.sort / jax.ops.segment_* (the portable fallback).
  * ``"pallas"`` — the Pallas TPU kernels (bitonic network, one-hot MXU
    matmul); interpret mode on CPU, native lowering on TPU.
  * ``"auto"``   — pallas on TPU, xla elsewhere.

Selection precedence: per-call ``backend=`` argument > :func:`set_backend`
(or the :class:`use_backend` context manager) > the ``REPRO_BACKEND``
environment variable > ``"auto"``.  Callers that jit must resolve the
backend *outside* the traced function (``resolve_backend``) and pass it as
a static argument so that flipping the backend retraces instead of hitting
a stale cache — the engine layers all follow this pattern.

Both backends implement the identical contract — same masking semantics,
same tie-breaking (total order by (k2, mk, row index)) — so they agree
bit-for-bit on integer data and to reordering-of-additions on floats;
``tests/test_backend_parity.py`` holds them to it.
"""
from __future__ import annotations

import os
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

BACKENDS = ("xla", "pallas", "auto")
_ENV_VAR = "REPRO_BACKEND"
_configured: Optional[str] = None


def set_backend(name: Optional[str]) -> None:
    """Set the process-wide backend (``None`` reverts to env/auto)."""
    global _configured
    if name is not None and name not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {name!r}")
    _configured = name


def get_backend() -> str:
    """The currently configured (possibly still ``'auto'``) backend."""
    if _configured is not None:
        return _configured
    env = os.environ.get(_ENV_VAR)
    if env:
        if env not in BACKENDS:
            raise ValueError(
                f"{_ENV_VAR} must be one of {BACKENDS}, got {env!r}")
        return env
    return "auto"


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve the per-call override / config / env chain to xla|pallas."""
    b = backend if backend is not None else get_backend()
    if b not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {b!r}")
    if b == "auto":
        b = "pallas" if jax.default_backend() == "tpu" else "xla"
    return b


class use_backend:
    """Context manager: ``with use_backend('pallas'): ...``"""

    def __init__(self, name: Optional[str]):
        self.name = name
        self.prev: Optional[str] = None

    def __enter__(self):
        global _configured
        self.prev = _configured
        set_backend(self.name)
        return self

    def __exit__(self, *exc):
        global _configured
        _configured = self.prev
        return False


def _interpret() -> bool:
    """Pallas interpret mode everywhere but real TPU."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# sort_pairs: the shuffle sort
# ---------------------------------------------------------------------------

class SortedPairs(NamedTuple):
    k2: jax.Array        # [N] sorted primary keys
    mk: jax.Array        # [N] co-sorted secondary keys
    payload: Any         # pytree of [N, ...] gathered through perm
    perm: jax.Array      # [N] int32, k2_sorted == k2[perm]


def sort_pairs(k2: jax.Array, mk: Optional[jax.Array] = None,
               payload: Any = None, *, num_keys: int = 2,
               backend: Optional[str] = None) -> SortedPairs:
    """Stable lexicographic sort by (k2[, mk]); ties keep input order.

    Validity is the caller's concern: mask invalid rows' k2 to INVALID_KEY
    beforehand and they sort to the tail.  ``payload`` may be any pytree of
    [N, ...] arrays; every leaf is gathered once through the permutation.
    """
    bk = resolve_backend(backend)
    n = k2.shape[0]
    if mk is None:
        mk = jnp.zeros(n, jnp.int32)
        num_keys = 1
    if bk == "pallas":
        from repro.kernels.sort_u32 import sort_lex_pallas
        lo = mk if num_keys >= 2 else jnp.zeros(n, jnp.int32)
        k2s, los, perm = sort_lex_pallas(k2, lo, interpret=_interpret())
        mks = los if num_keys >= 2 else jnp.take(mk, perm, axis=0)
    else:
        iota = jnp.arange(n, dtype=jnp.int32)
        if num_keys <= 1:
            k2s, perm = jax.lax.sort((k2, iota), num_keys=1, is_stable=True)
        else:
            k2s, _, perm = jax.lax.sort((k2, mk, iota), num_keys=2,
                                        is_stable=True)
        mks = jnp.take(mk, perm, axis=0)
    gathered = jax.tree.map(lambda a: jnp.take(a, perm, axis=0), payload)
    return SortedPairs(k2s, mks, gathered, perm)


# ---------------------------------------------------------------------------
# segment_reduce: the Reduce stage
# ---------------------------------------------------------------------------

def _kind_of(reducer) -> str:
    kind = getattr(reducer, "kind", reducer)
    if kind not in ("sum", "min", "max", "mean"):
        raise ValueError(f"unknown reducer kind {kind!r}")
    return kind


def _identity_scalar(kind: str, dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        info = jnp.finfo(dtype)
    else:
        info = jnp.iinfo(dtype)
    return info.max if kind == "min" else info.min


def _mask_leaf(kind: str, leaf: jax.Array, valid: jax.Array) -> jax.Array:
    mask = valid.reshape((-1,) + (1,) * (leaf.ndim - 1))
    if kind in ("min", "max"):
        return jnp.where(mask, leaf, _identity_scalar(kind, leaf.dtype))
    return jnp.where(mask, leaf, 0).astype(leaf.dtype)


def segment_reduce(reducer, segment_ids: jax.Array, values: Any,
                   valid: jax.Array, num_segments: int,
                   indices_are_sorted: bool = False,
                   backend: Optional[str] = None):
    """Reduce ``values`` into ``num_segments`` groups.

    ``reducer`` is a ``repro.core.kvstore.Reducer`` or a bare kind string.
    Returns (accumulated values pytree [K, ...], counts [K] int32); mean
    returns the *sum* (``finalize_reduce`` divides by the counts).  Invalid
    rows are routed to a scratch segment (index ``num_segments``) so they
    never pollute real groups.
    """
    bk = resolve_backend(backend)
    kind = _kind_of(reducer)
    seg = jnp.where(valid, segment_ids, num_segments).astype(jnp.int32)

    if bk == "pallas":
        return _segment_reduce_pallas(kind, seg, values, valid, num_segments)
    return _segment_reduce_xla(kind, seg, values, valid, num_segments,
                               indices_are_sorted)


def _segment_reduce_xla(kind, seg, values, valid, num_segments,
                        indices_are_sorted):
    op = {"sum": jax.ops.segment_sum, "mean": jax.ops.segment_sum,
          "min": jax.ops.segment_min, "max": jax.ops.segment_max}[kind]

    def _one(leaf):
        leaf = _mask_leaf(kind, leaf, valid)
        out = op(leaf, seg, num_segments=num_segments + 1,
                 indices_are_sorted=indices_are_sorted)
        return out[:num_segments]

    acc = jax.tree.map(_one, values)
    counts = jax.ops.segment_sum(valid.astype(jnp.int32), seg,
                                 num_segments=num_segments + 1,
                                 indices_are_sorted=indices_are_sorted)
    return acc, counts[:num_segments]


def _segment_reduce_pallas(kind, seg, values, valid, num_segments):
    from repro.kernels.segment_reduce import (
        segment_minmax_mxu, segment_sum_mxu,
    )
    interp = _interpret()

    def _one(leaf):
        leaf = _mask_leaf(kind, leaf, valid)
        flat = leaf.reshape(leaf.shape[0], -1)       # >2-D leaves flatten
        if kind in ("sum", "mean"):
            out_dtype = (jnp.int32 if jnp.issubdtype(leaf.dtype, jnp.integer)
                         else jnp.float32)
            out = segment_sum_mxu(seg, flat, num_segments + 1,
                                  out_dtype=out_dtype, interpret=interp)
            out = out.astype(leaf.dtype)
        else:
            out = segment_minmax_mxu(kind, seg, flat, num_segments + 1,
                                     interpret=interp)
        out = out[:num_segments]
        return out.reshape((num_segments,) + leaf.shape[1:])

    acc = jax.tree.map(_one, values)
    counts = segment_sum_mxu(seg, valid.astype(jnp.int32)[:, None],
                             num_segments + 1, out_dtype=jnp.int32,
                             interpret=interp)[:num_segments, 0]
    return acc, counts
