"""Segment reduction on the MXU (the Reduce stage on TPU).

Hadoop's Reduce iterates a key's value list with scalar code; a TPU wants
matrix units.  For a tile of R rows with segment ids ``seg[R]`` and values
``vals[R, D]``, the per-tile contribution to the output block [K, D] is

    onehot(seg)[R, K]^T @ vals[R, D]     (one 128x128-aligned MXU matmul)

The grid walks (row tiles x output blocks); each output block stays
resident in VMEM across the row-tile loop (BlockSpec index_map pins it),
accumulating partial sums — the classic stationary-output tiling.

Three kernel families cover all four ``Reducer`` monoids:

  * ``segment_sum_mxu``        — sum and mean (mean = sum + count, the
    division happens in ``kvstore.finalize_reduce``); integer values
    accumulate in int32, floats in float32.
  * ``segment_sum_counts_mxu`` — the same matmul with the per-segment row
    counts as a second output of the *same* launch (counts are the one-hot
    column sums, already resident), so the dispatcher's (acc, counts)
    contract costs one kernel instead of two.
  * ``segment_minmax_mxu``     — min/max via a *sublane* reduction: rows
    stream through in chunks of ``SUBLANES`` (the VPU's 8-row register
    height), each chunk masked against the one-hot block and folded into a
    stationary [kblk, D] accumulator.  Peak intermediate is
    [SUBLANES, kblk, D] — the old masked-select kernel materialized the
    full [rows, kblk, D] cube, which is why its tile knobs were clamped to
    a quarter of the sum kernel's; they now share the same defaults.
  * ``segment_reduce_mxu``     — the original float32 sum entry point,
    kept as the benchmark/back-compat surface.

Degenerate inputs (no rows, no segments) return empty/identity results
instead of tripping the tiling math.  ``interpret`` defaults to platform
auto-detection (``REPRO_PALLAS_INTERPRET`` overrides).  ``repro.kernels.
ref`` holds the pure-jnp oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import segment_minmax_ref, segment_reduce_ref  # noqa: F401
from repro.kernels.sort_u32 import default_interpret

DEFAULT_ROWS = 1024     # rows per tile
DEFAULT_KBLK = 256      # output segments per block: small blocks make the
                        # sorted-input block-skip (see _block_live) bite
MINMAX_ROWS = DEFAULT_ROWS   # sublane kernel: no cubic intermediate to cap
MINMAX_KBLK = DEFAULT_KBLK
SUBLANES = 8            # VPU register height: min/max chunk size


def _block_live(seg, base: int, kblk: int):
    """True iff any row of this tile lands in output block [base, base+kblk).

    The shuffle feeds the reducer *sorted* segment ids, so most
    (row tile x output block) grid pairs are empty; gating the matmul on
    this cheap VPU range test turns the grid from dense O(n/R * K/kblk)
    matmuls into the ~O(n/R + K/kblk) non-empty band.  Unsorted ids stay
    correct — the test is exact, just less often false.
    """
    return jnp.any((seg >= base) & (seg < base + kblk))


def _sum_kernel(seg_ref, val_ref, out_ref, *, kblk: int, rows: int):
    i = pl.program_id(0)      # row tile

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]                        # [rows]
    base = pl.program_id(1) * kblk

    @pl.when(_block_live(seg, base, kblk))
    def _work():
        vals = val_ref[...]                   # [rows, D]
        local = seg - base
        onehot = (local[:, None] ==
                  jax.lax.broadcasted_iota(jnp.int32, (rows, kblk), 1))
        onehot = onehot.astype(vals.dtype)
        out_ref[...] += jnp.dot(onehot.T, vals,
                                preferred_element_type=out_ref.dtype)


def _sum_counts_kernel(seg_ref, val_ref, out_ref, cnt_ref, *, kblk: int,
                       rows: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    seg = seg_ref[...]
    base = pl.program_id(1) * kblk

    @pl.when(_block_live(seg, base, kblk))
    def _work():
        vals = val_ref[...]
        local = seg - base
        onehot = (local[:, None] ==
                  jax.lax.broadcasted_iota(jnp.int32, (rows, kblk), 1))
        cnt_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)[:, None]
        onehot = onehot.astype(vals.dtype)
        out_ref[...] += jnp.dot(onehot.T, vals,
                                preferred_element_type=out_ref.dtype)


def _minmax_kernel(seg_ref, val_ref, out_ref, *, kblk: int, rows: int,
                   is_min: bool, ident):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    base = pl.program_id(1) * kblk
    d = val_ref.shape[1]
    dtype = val_ref.dtype
    fold = jnp.minimum if is_min else jnp.maximum
    kiota = jax.lax.broadcasted_iota(jnp.int32, (SUBLANES, kblk), 1)
    idval = jnp.asarray(ident, dtype)

    @pl.when(_block_live(seg_ref[...], base, kblk))
    def _work():
        def chunk(c, acc):
            r0 = c * SUBLANES
            seg8 = seg_ref[pl.ds(r0, SUBLANES)] - base    # [8]
            vals8 = val_ref[pl.ds(r0, SUBLANES), :]       # [8, D]
            onehot = seg8[:, None] == kiota               # [8, kblk]
            masked = jnp.where(onehot[:, :, None], vals8[:, None, :], idval)
            red = masked.min(axis=0) if is_min else masked.max(axis=0)
            return fold(acc, red)

        acc0 = jnp.full((kblk, d), ident, dtype)
        acc = jax.lax.fori_loop(0, rows // SUBLANES, chunk, acc0)
        out_ref[...] = fold(out_ref[...], acc)


def _round_up(n: int, mult: int) -> int:
    return ((n + mult - 1) // mult) * mult


def _pad_rows(seg, vals, rows, num_segments, *, fill=0, multiple=1):
    """Clamp the row tile to the (padded) input and pad rows to a multiple.

    Callers guarantee ``n > 0``; padding rows carry segment id
    ``num_segments`` (the scratch segment) and ``fill`` values.
    """
    n, d = vals.shape
    rows = max(multiple, _round_up(min(rows, n), multiple))
    if n % rows != 0:
        pad = rows - n % rows
        seg = jnp.concatenate([seg, jnp.full(pad, num_segments, seg.dtype)])
        vals = jnp.concatenate([vals, jnp.full((pad, d), fill, vals.dtype)])
    return seg, vals, rows


def _kblocks(num_segments, kblk):
    kblk = min(kblk, max(num_segments, 1))
    kpad = (kblk - num_segments % kblk) % kblk
    return kblk, num_segments + kpad


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "out_dtype", "rows",
                                    "kblk", "interpret"))
def segment_sum_mxu(seg: jax.Array, vals: jax.Array, num_segments: int, *,
                    out_dtype=jnp.float32, rows: int = DEFAULT_ROWS,
                    kblk: int = DEFAULT_KBLK,
                    interpret: bool | None = None) -> jax.Array:
    """seg [N] int32 (invalid rows: any id >= num_segments), vals [N, D].

    Returns [num_segments, D] sums in ``out_dtype``.  Padding rows outside
    [0, num_segments) may land in the kblk overhang; the slice drops them.
    """
    if interpret is None:
        interpret = default_interpret()
    n, d = vals.shape
    if num_segments <= 0:
        return jnp.zeros((max(num_segments, 0), d), out_dtype)
    if n == 0:
        return jnp.zeros((num_segments, d), out_dtype)
    seg, vals, rows = _pad_rows(seg, vals, rows, num_segments)
    n, d = vals.shape
    kblk, kfull = _kblocks(num_segments, kblk)
    if jnp.issubdtype(vals.dtype, jnp.integer):
        vals = vals.astype(out_dtype)
    out = pl.pallas_call(
        functools.partial(_sum_kernel, kblk=kblk, rows=rows),
        grid=(n // rows, kfull // kblk),
        in_specs=[
            pl.BlockSpec((rows,), lambda i, j: (i,)),
            pl.BlockSpec((rows, d), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((kblk, d), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((kfull, d), out_dtype),
        interpret=interpret,
    )(seg.astype(jnp.int32), vals)
    return out[:num_segments]


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "out_dtype", "rows",
                                    "kblk", "interpret"))
def segment_sum_counts_mxu(seg: jax.Array, vals: jax.Array,
                           num_segments: int, *, out_dtype=jnp.float32,
                           rows: int = DEFAULT_ROWS,
                           kblk: int = DEFAULT_KBLK,
                           interpret: bool | None = None):
    """One launch for the dispatcher's (sums [K, D], counts [K]) contract.

    ``counts`` are the one-hot column sums — exactly what
    ``jax.ops.segment_sum(ones)`` would produce, without re-reading the
    segment ids from HBM in a second kernel.
    """
    if interpret is None:
        interpret = default_interpret()
    n, d = vals.shape
    if num_segments <= 0:
        k = max(num_segments, 0)
        return (jnp.zeros((k, d), out_dtype), jnp.zeros(k, jnp.int32))
    if n == 0:
        return (jnp.zeros((num_segments, d), out_dtype),
                jnp.zeros(num_segments, jnp.int32))
    seg, vals, rows = _pad_rows(seg, vals, rows, num_segments)
    n, d = vals.shape
    kblk, kfull = _kblocks(num_segments, kblk)
    if jnp.issubdtype(vals.dtype, jnp.integer):
        vals = vals.astype(out_dtype)
    out, cnt = pl.pallas_call(
        functools.partial(_sum_counts_kernel, kblk=kblk, rows=rows),
        grid=(n // rows, kfull // kblk),
        in_specs=[
            pl.BlockSpec((rows,), lambda i, j: (i,)),
            pl.BlockSpec((rows, d), lambda i, j: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((kblk, d), lambda i, j: (j, 0)),
                   pl.BlockSpec((kblk, 1), lambda i, j: (j, 0))],
        out_shape=[jax.ShapeDtypeStruct((kfull, d), out_dtype),
                   jax.ShapeDtypeStruct((kfull, 1), jnp.int32)],
        interpret=interpret,
    )(seg.astype(jnp.int32), vals)
    return out[:num_segments], cnt[:num_segments, 0]


@functools.partial(jax.jit,
                   static_argnames=("kind", "num_segments", "rows", "kblk",
                                    "interpret"))
def segment_minmax_mxu(kind: str, seg: jax.Array, vals: jax.Array,
                       num_segments: int, *, rows: int = MINMAX_ROWS,
                       kblk: int = MINMAX_KBLK,
                       interpret: bool | None = None) -> jax.Array:
    """Segment min/max; empty segments hold the reduction identity."""
    assert kind in ("min", "max"), kind
    if interpret is None:
        interpret = default_interpret()
    if jnp.issubdtype(vals.dtype, jnp.floating):
        # XLA's segment_min/max identity for empty float segments is ±inf
        ident = float("inf") if kind == "min" else float("-inf")
    else:
        info = jnp.iinfo(vals.dtype)
        ident = info.max if kind == "min" else info.min
    n, d = vals.shape
    if num_segments <= 0:
        return jnp.full((max(num_segments, 0), d), ident, vals.dtype)
    if n == 0:
        return jnp.full((num_segments, d), ident, vals.dtype)
    # pad rows with the identity (not zero) so padding never wins, and to a
    # sublane multiple so the chunked scan tiles evenly
    seg, vals, rows = _pad_rows(seg, vals, rows, num_segments, fill=ident,
                                multiple=SUBLANES)
    n, d = vals.shape
    kblk, kfull = _kblocks(num_segments, kblk)
    out = pl.pallas_call(
        functools.partial(_minmax_kernel, kblk=kblk, rows=rows,
                          is_min=(kind == "min"), ident=ident),
        grid=(n // rows, kfull // kblk),
        in_specs=[
            pl.BlockSpec((rows,), lambda i, j: (i,)),
            pl.BlockSpec((rows, d), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((kblk, d), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((kfull, d), vals.dtype),
        interpret=interpret,
    )(seg.astype(jnp.int32), vals)
    return out[:num_segments]


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "rows", "kblk",
                                    "interpret"))
def segment_reduce_mxu(seg: jax.Array, vals: jax.Array, num_segments: int,
                       *, rows: int = DEFAULT_ROWS, kblk: int = DEFAULT_KBLK,
                       interpret: bool | None = None) -> jax.Array:
    """Original float32-sum entry point (benchmarks, back-compat)."""
    return segment_sum_mxu(seg, vals.astype(jnp.float32), num_segments,
                           out_dtype=jnp.float32, rows=rows, kblk=kblk,
                           interpret=interpret)
