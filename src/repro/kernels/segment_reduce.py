"""Segment reduction as an MXU one-hot matmul (the Reduce stage on TPU).

Hadoop's Reduce iterates a key's value list with scalar code; a TPU wants
matrix units.  For a tile of R rows with segment ids ``seg[R]`` and values
``vals[R, D]``, the per-tile contribution to the output block [K, D] is

    onehot(seg)[R, K]^T @ vals[R, D]     (one 128x128-aligned MXU matmul)

The grid walks (row tiles x output blocks); each output block stays
resident in VMEM across the row-tile loop (BlockSpec index_map pins it),
accumulating partial sums — the classic stationary-output tiling.

ref.py oracle: ``segment_reduce_ref`` (jax.ops.segment_sum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_ROWS = 512      # rows per tile
DEFAULT_KBLK = 512      # output segments per block


def _kernel(seg_ref, val_ref, out_ref, *, kblk: int, rows: int):
    i = pl.program_id(0)      # row tile
    j = pl.program_id(1)      # output block

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]                        # [rows]
    vals = val_ref[...]                       # [rows, D]
    base = j * kblk
    local = seg - base
    onehot = (local[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (rows, kblk), 1))
    onehot = onehot.astype(vals.dtype)
    out_ref[...] += jnp.dot(onehot.T, vals,
                            preferred_element_type=out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "rows", "kblk",
                                    "interpret"))
def segment_reduce_mxu(seg: jax.Array, vals: jax.Array, num_segments: int,
                       *, rows: int = DEFAULT_ROWS, kblk: int = DEFAULT_KBLK,
                       interpret: bool = True) -> jax.Array:
    """seg [N] int32 (invalid rows: any id >= num_segments), vals [N, D].

    Returns [num_segments, D] sums in float32.
    """
    n, d = vals.shape
    rows = min(rows, n)
    if n % rows != 0:
        pad = rows - n % rows
        seg = jnp.concatenate([seg, jnp.full(pad, num_segments, seg.dtype)])
        vals = jnp.concatenate([vals, jnp.zeros((pad, d), vals.dtype)])
        n = seg.shape[0]
    kblk = min(kblk, max(num_segments, 1))
    kpad = (kblk - num_segments % kblk) % kblk
    kfull = num_segments + kpad

    out = pl.pallas_call(
        functools.partial(_kernel, kblk=kblk, rows=rows),
        grid=(n // rows, kfull // kblk),
        in_specs=[
            pl.BlockSpec((rows,), lambda i, j: (i,)),
            pl.BlockSpec((rows, d), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((kblk, d), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((kfull, d), jnp.float32),
        interpret=interpret,
    )(seg.astype(jnp.int32), vals)
    return out[:num_segments]


def segment_reduce_ref(seg: jax.Array, vals: jax.Array,
                       num_segments: int) -> jax.Array:
    """Pure-jnp oracle."""
    seg = jnp.where(seg < num_segments, seg, num_segments)
    out = jax.ops.segment_sum(vals.astype(jnp.float32), seg,
                              num_segments=num_segments + 1)
    return out[:num_segments]
