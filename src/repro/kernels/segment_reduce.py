"""Segment reduction on the MXU (the Reduce stage on TPU).

Hadoop's Reduce iterates a key's value list with scalar code; a TPU wants
matrix units.  For a tile of R rows with segment ids ``seg[R]`` and values
``vals[R, D]``, the per-tile contribution to the output block [K, D] is

    onehot(seg)[R, K]^T @ vals[R, D]     (one 128x128-aligned MXU matmul)

The grid walks (row tiles x output blocks); each output block stays
resident in VMEM across the row-tile loop (BlockSpec index_map pins it),
accumulating partial sums — the classic stationary-output tiling.

Three kernels cover all four ``Reducer`` monoids:

  * ``segment_sum_mxu``    — sum and mean (mean = sum + count, the division
    happens in ``kvstore.finalize_reduce``); integer values accumulate in
    int32, floats in float32.
  * ``segment_minmax_mxu`` — min and max via a masked one-hot select
    (``where(onehot, vals, identity)`` reduced over the row axis); the MXU
    cannot min/max-accumulate, so this leg runs on the VPU with the same
    stationary-output tiling.
  * ``segment_reduce_mxu`` — the original float32 sum entry point, kept as
    the benchmark/back-compat surface.

``repro.kernels.ref`` holds the pure-jnp oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import segment_minmax_ref, segment_reduce_ref  # noqa: F401

DEFAULT_ROWS = 512      # rows per tile
DEFAULT_KBLK = 512      # output segments per block
MINMAX_ROWS = 256       # the select kernel materializes [rows, kblk, D]
MINMAX_KBLK = 128


def _sum_kernel(seg_ref, val_ref, out_ref, *, kblk: int, rows: int):
    i = pl.program_id(0)      # row tile

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]                        # [rows]
    vals = val_ref[...]                       # [rows, D]
    base = pl.program_id(1) * kblk
    local = seg - base
    onehot = (local[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (rows, kblk), 1))
    onehot = onehot.astype(vals.dtype)
    out_ref[...] += jnp.dot(onehot.T, vals,
                            preferred_element_type=out_ref.dtype)


def _minmax_kernel(seg_ref, val_ref, out_ref, *, kblk: int, rows: int,
                   is_min: bool, ident):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, ident)

    seg = seg_ref[...]
    vals = val_ref[...]
    base = pl.program_id(1) * kblk
    local = seg - base
    onehot = (local[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (rows, kblk), 1))
    # masked select: rows outside this output block contribute the identity
    expanded = jnp.where(onehot[:, :, None], vals[:, None, :],
                         jnp.asarray(ident, vals.dtype))
    if is_min:
        out_ref[...] = jnp.minimum(out_ref[...], expanded.min(axis=0))
    else:
        out_ref[...] = jnp.maximum(out_ref[...], expanded.max(axis=0))


def _pad_rows(seg, vals, rows, num_segments):
    n, d = vals.shape
    rows = min(rows, n)
    if n % rows != 0:
        pad = rows - n % rows
        seg = jnp.concatenate([seg, jnp.full(pad, num_segments, seg.dtype)])
        vals = jnp.concatenate([vals, jnp.zeros((pad, d), vals.dtype)])
    return seg, vals, rows


def _kblocks(num_segments, kblk):
    kblk = min(kblk, max(num_segments, 1))
    kpad = (kblk - num_segments % kblk) % kblk
    return kblk, num_segments + kpad


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "out_dtype", "rows",
                                    "kblk", "interpret"))
def segment_sum_mxu(seg: jax.Array, vals: jax.Array, num_segments: int, *,
                    out_dtype=jnp.float32, rows: int = DEFAULT_ROWS,
                    kblk: int = DEFAULT_KBLK,
                    interpret: bool = True) -> jax.Array:
    """seg [N] int32 (invalid rows: any id >= num_segments), vals [N, D].

    Returns [num_segments, D] sums in ``out_dtype``.  Padding rows outside
    [0, num_segments) may land in the kblk overhang; the slice drops them.
    """
    seg, vals, rows = _pad_rows(seg, vals, rows, num_segments)
    n, d = vals.shape
    kblk, kfull = _kblocks(num_segments, kblk)
    if jnp.issubdtype(vals.dtype, jnp.integer):
        vals = vals.astype(out_dtype)
    out = pl.pallas_call(
        functools.partial(_sum_kernel, kblk=kblk, rows=rows),
        grid=(n // rows, kfull // kblk),
        in_specs=[
            pl.BlockSpec((rows,), lambda i, j: (i,)),
            pl.BlockSpec((rows, d), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((kblk, d), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((kfull, d), out_dtype),
        interpret=interpret,
    )(seg.astype(jnp.int32), vals)
    return out[:num_segments]


@functools.partial(jax.jit,
                   static_argnames=("kind", "num_segments", "rows", "kblk",
                                    "interpret"))
def segment_minmax_mxu(kind: str, seg: jax.Array, vals: jax.Array,
                       num_segments: int, *, rows: int = MINMAX_ROWS,
                       kblk: int = MINMAX_KBLK,
                       interpret: bool = True) -> jax.Array:
    """Segment min/max; empty segments hold the reduction identity."""
    assert kind in ("min", "max"), kind
    if jnp.issubdtype(vals.dtype, jnp.floating):
        # XLA's segment_min/max identity for empty float segments is ±inf
        ident = float("inf") if kind == "min" else float("-inf")
    else:
        info = jnp.iinfo(vals.dtype)
        ident = info.max if kind == "min" else info.min
    n0 = vals.shape[0]
    # pad rows with the identity (not zero) so padding never wins
    rows = min(rows, n0)
    if n0 % rows != 0:
        pad = rows - n0 % rows
        seg = jnp.concatenate([seg, jnp.full(pad, num_segments, seg.dtype)])
        vals = jnp.concatenate(
            [vals, jnp.full((pad, vals.shape[1]), ident, vals.dtype)])
    n, d = vals.shape
    kblk, kfull = _kblocks(num_segments, kblk)
    out = pl.pallas_call(
        functools.partial(_minmax_kernel, kblk=kblk, rows=rows,
                          is_min=(kind == "min"), ident=ident),
        grid=(n // rows, kfull // kblk),
        in_specs=[
            pl.BlockSpec((rows,), lambda i, j: (i,)),
            pl.BlockSpec((rows, d), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((kblk, d), lambda i, j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((kfull, d), vals.dtype),
        interpret=interpret,
    )(seg.astype(jnp.int32), vals)
    return out[:num_segments]


@functools.partial(jax.jit,
                   static_argnames=("num_segments", "rows", "kblk",
                                    "interpret"))
def segment_reduce_mxu(seg: jax.Array, vals: jax.Array, num_segments: int,
                       *, rows: int = DEFAULT_ROWS, kblk: int = DEFAULT_KBLK,
                       interpret: bool = True) -> jax.Array:
    """Original float32-sum entry point (benchmarks, back-compat)."""
    return segment_sum_mxu(seg, vals.astype(jnp.float32), num_segments,
                           out_dtype=jnp.float32, rows=rows, kblk=kblk,
                           interpret=interpret)
