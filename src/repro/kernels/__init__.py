"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel module provides the ``pl.pallas_call`` kernel with explicit
BlockSpec VMEM tiling; ``ops.py`` is the backend-dispatch layer (the
jitted ``sort_pairs`` / ``segment_reduce`` entry points every engine layer
routes through, selectable via ``REPRO_BACKEND`` / ``ops.set_backend``)
and ``ref.py`` holds the pure-jnp oracles the tests compare against.

On this CPU container the kernels run with ``interpret=True`` (the kernel
body executes in Python); on TPU the same code lowers natively.  The
hardware adaptation: MapReduce's Reduce becomes a one-hot MXU
segment-matmul (masked one-hot select for min/max); the shuffle sort
becomes an in-VMEM bitonic network over (K2, MK, index) lanes with a
permutation output; PageRank's gather-scatter becomes output-block-tiled
one-hot accumulation; attention uses the standard streaming-softmax flash
schedule.
"""
