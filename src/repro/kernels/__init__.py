"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel module provides the ``pl.pallas_call`` kernel with explicit
BlockSpec VMEM tiling; ``ops.py`` holds the jitted wrappers and ``ref.py``
the pure-jnp oracles.

On this CPU container the kernels run with ``interpret=True`` (the kernel
body executes in Python); on TPU the same code lowers natively.  The
hardware adaptation: MapReduce's Reduce becomes a one-hot MXU
segment-matmul; the shuffle sort becomes an in-VMEM bitonic network;
PageRank's gather-scatter becomes output-block-tiled one-hot accumulation;
attention uses the standard streaming-softmax flash schedule.
"""
