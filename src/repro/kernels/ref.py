"""Pure-jnp oracles for the Pallas kernels.

Each function mirrors one kernel's contract exactly (masking semantics
included) with straight-line jax.numpy — the ground truth that the kernel
sweeps in ``tests/test_kernels.py`` and the backend parity tests compare
against.  No Pallas imports here: the oracles must run anywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG = -2.0e38


# -- sort -------------------------------------------------------------------

def sort_kv32_ref(keys, payload):
    order = jnp.argsort(keys, stable=True)
    return jnp.take(keys, order), jnp.take(payload, order)


def sort_lex_ref(hi, lo):
    """Stable lexicographic (hi, lo) sort; returns (hi, lo, perm)."""
    n = hi.shape[0]
    iota = jnp.arange(n, dtype=jnp.int32)
    *_, perm = jax.lax.sort((hi, lo, iota), num_keys=2, is_stable=True)
    return jnp.take(hi, perm), jnp.take(lo, perm), perm


# -- segment reduce ---------------------------------------------------------

def segment_reduce_ref(seg: jax.Array, vals: jax.Array,
                       num_segments: int) -> jax.Array:
    seg = jnp.where(seg < num_segments, seg, num_segments)
    out = jax.ops.segment_sum(vals.astype(jnp.float32), seg,
                              num_segments=num_segments + 1)
    return out[:num_segments]


def segment_minmax_ref(kind: str, seg: jax.Array, vals: jax.Array,
                       num_segments: int) -> jax.Array:
    """min/max oracle; segments with no rows hold the reduction identity."""
    op = jax.ops.segment_min if kind == "min" else jax.ops.segment_max
    seg = jnp.where(seg < num_segments, seg, num_segments)
    out = op(vals, seg, num_segments=num_segments + 1)
    return out[:num_segments]


# -- attention --------------------------------------------------------------

def mha_ref(q, k, v, *, causal=True, window=0, softcap=0.0):
    """Dense oracle with identical masking semantics."""
    b, h, sq, hd = q.shape
    kh, sk = k.shape[1], k.shape[2]
    rep = h // kh
    kx = jnp.repeat(k, rep, axis=1)
    vx = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kx.astype(jnp.float32)) / (hd ** 0.5)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx.astype(jnp.float32)
                      ).astype(q.dtype)


# -- spmv -------------------------------------------------------------------

def spmv_ell_ref(nbrs, contrib, num_vertices: int):
    flat_n = nbrs.reshape(-1)
    flat_c = contrib.reshape(-1).astype(jnp.float32)
    seg = jnp.where((flat_n >= 0) & (flat_n < num_vertices), flat_n,
                    num_vertices)
    out = jax.ops.segment_sum(jnp.where(seg < num_vertices, flat_c, 0.0),
                              seg, num_segments=num_vertices + 1)
    return out[:num_vertices]
