"""In-VMEM bitonic sort of (key, payload) pairs — the shuffle-sort on TPU.

Hadoop's shuffle sorts spill files with comparison mergesort on the CPU;
the TPU analogue is a data-parallel bitonic network over a VMEM-resident
tile: log²(T) compare-exchange stages, each a vectorized select between a
tile and its stride-permuted self (no data-dependent control flow, VPU
friendly).  Larger inputs are handled by the host-side run-merge in
MRBG-Store (this kernel is the per-tile building block).

Payload rides along as a second lane (values permuted with the keys).

ref.py oracle: ``sort_kv32_ref`` (jnp.argsort gather).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _stage(keys, payload, j, k):
    n = keys.shape[0]
    idx = jax.lax.iota(jnp.int32, n)
    partner = jnp.bitwise_xor(idx, j)
    pk = keys[partner]
    pp = payload[partner]
    up = (jnp.bitwise_and(idx, k) == 0)          # ascending region?
    is_lo = idx < partner
    keep = jnp.where(up == is_lo, jnp.minimum(keys, pk),
                     jnp.maximum(keys, pk))
    # equal keys: min == max == own key, so both sides keep their own
    # payload — a valid (if unstable) permutation
    take_self = keep == keys
    newp = jnp.where(take_self, payload, pp)
    return keep, newp


def _kernel(k_ref, p_ref, ko_ref, po_ref, *, length: int):
    keys = k_ref[...]
    payload = p_ref[...]
    k = 2
    while k <= length:
        j = k // 2
        while j >= 1:
            keys, payload = _stage(keys, payload, j, k)
            j //= 2
        k *= 2
    ko_ref[...] = keys
    po_ref[...] = payload


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_kv32(keys: jax.Array, payload: jax.Array, *,
              interpret: bool = True):
    """Sort uint32/int32 ``keys`` ascending, permuting int32 ``payload``.

    Length is padded to the next power of two with key = max_uint32.
    """
    n = keys.shape[0]
    m = 1
    while m < n:
        m *= 2
    if m != n:
        keys = jnp.concatenate(
            [keys, jnp.full(m - n, jnp.iinfo(jnp.uint32).max, keys.dtype)])
        payload = jnp.concatenate(
            [payload, jnp.zeros(m - n, payload.dtype)])
    ko, po = pl.pallas_call(
        functools.partial(_kernel, length=m),
        grid=(1,),
        in_specs=[pl.BlockSpec((m,), lambda i: (0,)),
                  pl.BlockSpec((m,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((m,), lambda i: (0,)),
                   pl.BlockSpec((m,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((m,), keys.dtype),
                   jax.ShapeDtypeStruct((m,), payload.dtype)],
        interpret=interpret,
    )(keys, payload)
    return ko[:n], po[:n]


def sort_kv32_ref(keys, payload):
    order = jnp.argsort(keys, stable=True)
    return jnp.take(keys, order), jnp.take(payload, order)
