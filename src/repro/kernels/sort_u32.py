"""In-VMEM bitonic sort of key/payload lanes — the shuffle-sort on TPU.

Hadoop's shuffle sorts spill files with comparison mergesort on the CPU;
the TPU analogue is a data-parallel bitonic network over a VMEM-resident
tile: log²(T) compare-exchange stages, each a vectorized select between a
tile and its stride-permuted self (no data-dependent control flow, VPU
friendly).  Larger inputs are handled by the host-side run-merge in
MRBG-Store (this kernel is the per-tile building block).

The network sorts three int lanes lexicographically: a primary key, a
secondary key, and the original row index.  Because the index lane is
unique, the comparison is a total order — which makes the (otherwise
unstable) bitonic network *stable* with respect to (primary, secondary)
and lets the index lane double as the output permutation.  The engine's
merge path (``incremental._merge_reduce``) depends on exactly this
stability for its last-writer-wins semantics, and arbitrary pytree
payloads are gathered once through the permutation instead of riding
through every compare-exchange stage.

``repro.kernels.ref`` holds the pure-jnp oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import sort_kv32_ref  # noqa: F401  (back-compat)


def _lex_lt(ah, al, ai, bh, bl, bi):
    """(ah, al, ai) < (bh, bl, bi) lexicographically."""
    return jnp.where(ah != bh, ah < bh, jnp.where(al != bl, al < bl, ai < bi))


def _stage(hi, lo, idx, j, k):
    n = hi.shape[0]
    pos = jax.lax.iota(jnp.int32, n)
    partner = jnp.bitwise_xor(pos, j)
    ph = hi[partner]
    plo = lo[partner]
    pi = idx[partner]
    up = (jnp.bitwise_and(pos, k) == 0)          # ascending region?
    is_lo = pos < partner
    want_min = up == is_lo
    own_lt = _lex_lt(hi, lo, idx, ph, plo, pi)   # never equal: idx is unique
    take_own = jnp.where(want_min, own_lt, ~own_lt)
    sel = lambda a, b: jnp.where(take_own, a, b)
    return sel(hi, ph), sel(lo, plo), sel(idx, pi)


def _kernel(hi_ref, lo_ref, idx_ref, ho_ref, lo_out_ref, po_ref, *,
            length: int):
    hi = hi_ref[...]
    lo = lo_ref[...]
    idx = idx_ref[...]
    k = 2
    while k <= length:
        j = k // 2
        while j >= 1:
            hi, lo, idx = _stage(hi, lo, idx, j, k)
            j //= 2
        k *= 2
    ho_ref[...] = hi
    lo_out_ref[...] = lo
    po_ref[...] = idx


def _type_max(dtype):
    return jnp.iinfo(dtype).max


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_lex_pallas(hi: jax.Array, lo: jax.Array, *, interpret: bool = True):
    """Stable lexicographic sort by (hi, lo); ties broken by row index.

    Returns ``(hi_sorted, lo_sorted, perm)`` where ``perm`` is the int32
    permutation (``hi_sorted == hi[perm]``).  Length is padded to the next
    power of two with both key lanes at their dtype max, so padding lands
    at the tail and ``perm[:n]`` is a permutation of ``range(n)``.
    """
    n = hi.shape[0]
    m = 1
    while m < max(n, 1):
        m *= 2
    iota = jnp.arange(m, dtype=jnp.int32)
    if m != n:
        hi = jnp.concatenate([hi, jnp.full(m - n, _type_max(hi.dtype),
                                           hi.dtype)])
        lo = jnp.concatenate([lo, jnp.full(m - n, _type_max(lo.dtype),
                                           lo.dtype)])
    ho, lo_out, perm = pl.pallas_call(
        functools.partial(_kernel, length=m),
        grid=(1,),
        in_specs=[pl.BlockSpec((m,), lambda i: (0,)),
                  pl.BlockSpec((m,), lambda i: (0,)),
                  pl.BlockSpec((m,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((m,), lambda i: (0,)),
                   pl.BlockSpec((m,), lambda i: (0,)),
                   pl.BlockSpec((m,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((m,), hi.dtype),
                   jax.ShapeDtypeStruct((m,), lo.dtype),
                   jax.ShapeDtypeStruct((m,), jnp.int32)],
        interpret=interpret,
    )(hi, lo, iota)
    return ho[:n], lo_out[:n], perm[:n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_kv32(keys: jax.Array, payload: jax.Array, *,
              interpret: bool = True):
    """Sort uint32/int32 ``keys`` ascending (stable), permuting ``payload``.

    Back-compat single-key entry point over the lexicographic network.
    """
    ko, _, perm = sort_lex_pallas(keys, jnp.zeros_like(keys, jnp.int32),
                                  interpret=interpret)
    return ko, jnp.take(payload, perm, axis=0)
