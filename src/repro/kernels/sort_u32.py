"""Multi-tile bitonic sort of key/payload lanes — the shuffle-sort on TPU.

Hadoop's shuffle sorts spill files with comparison mergesort on the CPU;
the TPU analogue is a data-parallel bitonic network over VMEM-resident
tiles: log²(T) compare-exchange stages, each a vectorized select between a
tile and its stride-permuted self (no data-dependent control flow, VPU
friendly).

The network sorts three int lanes lexicographically: a primary key, a
secondary key, and the original row index.  Because the index lane is
unique, the comparison is a total order — which makes the (otherwise
unstable) bitonic network *stable* with respect to (primary, secondary)
and lets the index lane double as the output permutation.  The engine's
merge path (``incremental._merge_reduce``) depends on exactly this
stability for its last-writer-wins semantics, and arbitrary pytree
payloads are gathered once through the permutation instead of riding
through every compare-exchange stage.

Inputs larger than one VMEM tile are handled by splitting the global
bitonic network at tile granularity (``SORT_TILE`` rows per tile):

  * a per-tile pass runs every stage with compare distance ``j < tile``
    entirely in VMEM (directions follow the *global* position, so each
    tile computes its slice of the one global network);
  * each stage with ``j >= tile`` pairs whole tiles (partner tile =
    ``tile_index XOR j/tile``) and becomes one grid launch over tile
    pairs, two tiles resident in VMEM per step.

Total work stays the bitonic O(n log² n) while VMEM is bounded by the
tile size — the old pad-the-whole-input-to-one-power-of-two block (and
its fall-off-a-cliff behavior past a few thousand rows) is gone.  Inputs
that do fit one tile take the exact single-launch path they always did.

``interpret`` defaults to auto-detection (interpret off TPU, native on
TPU); set ``REPRO_PALLAS_INTERPRET=0/1`` to override.  ``repro.kernels.
ref`` holds the pure-jnp oracles.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import sort_kv32_ref  # noqa: F401  (back-compat)

SORT_TILE = 4096        # rows per VMEM tile (power of two)


def default_interpret() -> bool:
    """Pallas interpret mode everywhere but real TPU.

    ``REPRO_PALLAS_INTERPRET=1`` forces interpret mode on TPU (debugging);
    ``REPRO_PALLAS_INTERPRET=0`` forces native lowering off TPU (fails
    loudly where Mosaic is unavailable — useful for lowering checks).
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env != "":
        if env.lower() in ("1", "true", "yes", "on"):
            return True
        if env.lower() in ("0", "false", "no", "off"):
            return False
        raise ValueError(
            f"REPRO_PALLAS_INTERPRET must be boolean-like, got {env!r}")
    return jax.default_backend() != "tpu"


def _lex_lt(ah, al, ai, bh, bl, bi):
    """(ah, al, ai) < (bh, bl, bi) lexicographically."""
    return jnp.where(ah != bh, ah < bh, jnp.where(al != bl, al < bl, ai < bi))


def _stage(hi, lo, idx, j, k, base):
    """One intra-tile compare-exchange stage of the *global* network.

    ``base`` is the tile's global row offset: directions are a function of
    global position, which is what lets independently launched tiles each
    compute their slice of one coherent bitonic network.
    """
    n = hi.shape[0]
    pos = jax.lax.iota(jnp.int32, n)
    partner = jnp.bitwise_xor(pos, j)
    ph = hi[partner]
    plo = lo[partner]
    pi = idx[partner]
    up = (jnp.bitwise_and(base + pos, k) == 0)   # ascending region?
    is_lo = pos < partner
    want_min = up == is_lo
    own_lt = _lex_lt(hi, lo, idx, ph, plo, pi)   # never equal: idx is unique
    take_own = jnp.where(want_min, own_lt, ~own_lt)
    sel = lambda a, b: jnp.where(take_own, a, b)
    return sel(hi, ph), sel(lo, plo), sel(idx, pi)


def _tile_sort_kernel(hi_ref, lo_ref, idx_ref, ho_ref, lo_out_ref, po_ref, *,
                      tile: int):
    """Stages k = 2..tile of the global network, one tile in VMEM."""
    base = pl.program_id(0) * tile
    hi = hi_ref[...]
    lo = lo_ref[...]
    idx = idx_ref[...]
    k = 2
    while k <= tile:
        j = k // 2
        while j >= 1:
            hi, lo, idx = _stage(hi, lo, idx, j, k, base)
            j //= 2
        k *= 2
    ho_ref[...] = hi
    lo_out_ref[...] = lo
    po_ref[...] = idx


def _tile_finish_kernel(hi_ref, lo_ref, idx_ref, ho_ref, lo_out_ref, po_ref,
                        *, tile: int, k: int):
    """Stages j = tile/2..1 of round ``k`` (> tile), one tile in VMEM."""
    base = pl.program_id(0) * tile
    hi = hi_ref[...]
    lo = lo_ref[...]
    idx = idx_ref[...]
    j = tile // 2
    while j >= 1:
        hi, lo, idx = _stage(hi, lo, idx, j, k, base)
        j //= 2
    ho_ref[...] = hi
    lo_out_ref[...] = lo
    po_ref[...] = idx


def _cross_kernel(ahi_ref, alo_ref, ai_ref, bhi_ref, blo_ref, bi_ref,
                  oh_ref, ol_ref, oi_ref, *, tile: int, k: int, dt: int):
    """One cross-tile stage (compare distance j = dt * tile).

    The grid runs over (tile pair, side): a pair's lower tile holds global
    positions ``p`` and its upper tile ``p XOR j``, so the stage is a pure
    elementwise compare-exchange between the two resident tiles.  The
    ``side`` grid axis selects which half the step writes (a BlockSpec
    maps one block per step), with both tiles resident either way.
    """
    p = pl.program_id(0)
    side = pl.program_id(1)                        # 0 = lower, 1 = upper
    lo_tile = (p // dt) * (2 * dt) + (p % dt)
    up = jnp.bitwise_and(lo_tile * tile, k) == 0   # scalar: whole tile
    ah, al, ai = ahi_ref[...], alo_ref[...], ai_ref[...]
    bh, bl, bi = bhi_ref[...], blo_ref[...], bi_ref[...]
    a_lt = _lex_lt(ah, al, ai, bh, bl, bi)         # never equal
    take_a = jnp.where(up, a_lt, ~a_lt)            # lower position keeps min
    want_a = take_a == (side == 0)                 # upper side keeps the rest
    oh_ref[...] = jnp.where(want_a, ah, bh)
    ol_ref[...] = jnp.where(want_a, al, bl)
    oi_ref[...] = jnp.where(want_a, ai, bi)


def _lane_specs(tile: int, index_map):
    return [pl.BlockSpec((tile,), index_map) for _ in range(3)]


def _lane_shapes(m: int, hi_dtype, lo_dtype):
    return [jax.ShapeDtypeStruct((m,), hi_dtype),
            jax.ShapeDtypeStruct((m,), lo_dtype),
            jax.ShapeDtypeStruct((m,), jnp.int32)]


def sorted_lanes(hi: jax.Array, lo: jax.Array, idx: jax.Array, *,
                 tile: int, interpret: bool):
    """Sort pre-padded (hi, lo, idx) lanes; length must be pow2·tile or a
    pow2 below one tile.  The building block shared with ``kernels.fused``.
    """
    m = hi.shape[0]
    if m <= tile:
        # single tile: the whole network in one launch (the original path)
        return pl.pallas_call(
            functools.partial(_tile_sort_kernel, tile=m),
            grid=(1,),
            in_specs=_lane_specs(m, lambda i: (0,)),
            out_specs=_lane_specs(m, lambda i: (0,)),
            out_shape=_lane_shapes(m, hi.dtype, lo.dtype),
            interpret=interpret,
        )(hi, lo, idx)

    tiles = m // tile
    per_tile = lambda i: (i,)
    hi, lo, idx = pl.pallas_call(
        functools.partial(_tile_sort_kernel, tile=tile),
        grid=(tiles,),
        in_specs=_lane_specs(tile, per_tile),
        out_specs=_lane_specs(tile, per_tile),
        out_shape=_lane_shapes(m, hi.dtype, lo.dtype),
        interpret=interpret,
    )(hi, lo, idx)

    k = tile * 2
    while k <= m:
        j = k // 2
        while j >= tile:
            dt = j // tile
            lo_map = lambda p, s, dt=dt: ((p // dt) * (2 * dt) + (p % dt),)
            hi_map = lambda p, s, dt=dt: (
                (p // dt) * (2 * dt) + (p % dt) + dt,)
            out_map = lambda p, s, dt=dt: (
                (p // dt) * (2 * dt) + (p % dt) + s * dt,)
            hi, lo, idx = pl.pallas_call(
                functools.partial(_cross_kernel, tile=tile, k=k, dt=dt),
                grid=(tiles // 2, 2),
                in_specs=_lane_specs(tile, lo_map) + _lane_specs(tile, hi_map),
                out_specs=_lane_specs(tile, out_map),
                out_shape=_lane_shapes(m, hi.dtype, lo.dtype),
                interpret=interpret,
            )(hi, lo, idx, hi, lo, idx)
            j //= 2
        hi, lo, idx = pl.pallas_call(
            functools.partial(_tile_finish_kernel, tile=tile, k=k),
            grid=(tiles,),
            in_specs=_lane_specs(tile, per_tile),
            out_specs=_lane_specs(tile, per_tile),
            out_shape=_lane_shapes(m, hi.dtype, lo.dtype),
            interpret=interpret,
        )(hi, lo, idx)
        k *= 2
    return hi, lo, idx


def _type_max(dtype):
    return jnp.iinfo(dtype).max


def padded_length(n: int, tile: int) -> int:
    """Pad policy: next power of two up to one tile, then tile multiples
    whose count is a power of two (the bitonic network needs pow2 total)."""
    m = 1
    while m < max(n, 1):
        m *= 2
    return m


def pad_lanes(hi: jax.Array, lo: jax.Array, m: int):
    """Pad both key lanes to ``m`` with their dtype max (sorts to the tail)."""
    n = hi.shape[0]
    if m == n:
        return hi, lo
    hi = jnp.concatenate([hi, jnp.full(m - n, _type_max(hi.dtype), hi.dtype)])
    lo = jnp.concatenate([lo, jnp.full(m - n, _type_max(lo.dtype), lo.dtype)])
    return hi, lo


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sort_lex_pallas(hi: jax.Array, lo: jax.Array, *, tile: int = SORT_TILE,
                    interpret: bool | None = None):
    """Stable lexicographic sort by (hi, lo); ties broken by row index.

    Returns ``(hi_sorted, lo_sorted, perm)`` where ``perm`` is the int32
    permutation (``hi_sorted == hi[perm]``).  Length is padded to the next
    power of two with both key lanes at their dtype max, so padding lands
    at the tail and ``perm[:n]`` is a permutation of ``range(n)``.  Inputs
    beyond ``tile`` rows run the multi-tile network: VMEM stays bounded by
    the tile size (two tiles per cross-stage launch) instead of the whole
    padded input.
    """
    if interpret is None:
        interpret = default_interpret()
    if tile & (tile - 1):
        raise ValueError(f"tile must be a power of two, got {tile}")
    n = hi.shape[0]
    m = padded_length(n, tile)
    hi, lo = pad_lanes(hi, lo, m)
    iota = jnp.arange(m, dtype=jnp.int32)
    ho, lo_out, perm = sorted_lanes(hi, lo, iota, tile=tile,
                                    interpret=interpret)
    return ho[:n], lo_out[:n], perm[:n]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def sort_kv32(keys: jax.Array, payload: jax.Array, *, tile: int = SORT_TILE,
              interpret: bool | None = None):
    """Sort uint32/int32 ``keys`` ascending (stable), permuting ``payload``.

    Back-compat single-key entry point over the lexicographic network.
    """
    ko, _, perm = sort_lex_pallas(keys, jnp.zeros_like(keys, jnp.int32),
                                  tile=tile, interpret=interpret)
    return ko, jnp.take(payload, perm, axis=0)
