"""Retrace/recompile telemetry + the persistent executable cache.

The streaming tier's latency tail is almost entirely trace + compile time:
a micro-batch whose delta shape has not been seen yet re-traces the whole
refresh path and waits on XLA.  This module makes that visible and
survivable:

  * **Trace counters** — every jitted kernel on the refresh path calls
    :func:`count_trace` at the top of its Python body.  A jit body only
    executes when JAX is *tracing* (a jit-cache miss), so the counter is
    an exact retrace count with zero steady-state overhead.  The
    monotonically increasing :func:`generation` lets a caller bracket a
    region ("did this refresh trace anything?") — the stream scheduler
    uses it to exclude compile-polluted cost observations.
  * **Compile counters** — a ``jax.monitoring`` listener counts actual
    XLA backend compiles (a persistent-cache hit traces but does not
    compile, so the two counters differ exactly by the cache's hits).
  * **Persistent compilation cache** — :func:`enable_persistent_cache`
    points JAX's disk cache at a directory (``RunConfig(
    compilation_cache_dir=...)``), with the entry-size/compile-time
    floors dropped so the many small refresh executables qualify.
    Executables then survive process restarts: a restarted serving node
    re-traces (milliseconds) but does not re-compile (hundreds of
    milliseconds per shape bucket).
"""
from __future__ import annotations

import collections
import threading
from typing import Dict, Optional

import jax

_lock = threading.Lock()
_traces: collections.Counter = collections.Counter()
_generation = 0
_compiles = 0
_compile_seconds = 0.0
_listener_installed = False
_cache_dir: Optional[str] = None

_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


def count_trace(name: str) -> None:
    """Record one retrace.  Call from *inside* a jitted function body —
    the body only runs on a jit-cache miss, i.e. exactly once per trace."""
    global _generation
    with _lock:
        _traces[name] += 1
        _generation += 1


def generation() -> int:
    """Monotonic counter bumped on every trace (bracket refreshes with it)."""
    return _generation


def trace_counts() -> Dict[str, int]:
    """Per-kernel retrace counts since process start."""
    with _lock:
        return dict(_traces)


def traces_total() -> int:
    with _lock:
        return sum(_traces.values())


def compiles_total() -> int:
    """XLA backend compiles since :func:`install_compile_listener`."""
    return _compiles


def compile_seconds_total() -> float:
    return _compile_seconds


def snapshot() -> Dict[str, float]:
    """One consistent view of all counters (for benchmarks/metrics)."""
    with _lock:
        return {"traces": sum(_traces.values()),
                "compiles": _compiles,
                "compile_seconds": _compile_seconds}


def _on_event_duration(event: str, duration: float, **_kw) -> None:
    global _compiles, _compile_seconds
    if event == _COMPILE_EVENT:
        with _lock:
            _compiles += 1
            _compile_seconds += duration


def install_compile_listener() -> None:
    """Idempotently subscribe the compile counter to jax.monitoring."""
    global _listener_installed
    with _lock:
        if _listener_installed:
            return
        _listener_installed = True
    jax.monitoring.register_event_duration_secs_listener(_on_event_duration)


def enable_persistent_cache(path) -> None:
    """Point JAX's persistent compilation cache at ``path`` (idempotent).

    Drops the default entry-size and compile-time floors so that the
    refresh path's many small executables are cached too, and enables the
    underlying XLA caches on every backend (the CPU leg included).
    """
    global _cache_dir
    path = str(path)
    if _cache_dir == path:
        return
    jax.config.update("jax_compilation_cache_dir", path)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:
        jax.config.update("jax_persistent_cache_enable_xla_caches", "all")
    except (AttributeError, ValueError):  # older jax: flag absent
        pass
    # JAX latches the cache-enabled decision at the first compile; if
    # anything compiled before this call (module import commonly does),
    # the latch must be cleared for the new directory to take effect
    try:
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except Exception:  # pragma: no cover — internal API moved
        pass
    _cache_dir = path


def persistent_cache_dir() -> Optional[str]:
    return _cache_dir


install_compile_listener()
