"""Fused shuffle+merge+Reduce — one kernel from sorted tiles to segments.

The engine's merge path (``incremental._merge_reduce``) is sort → roll-
compare last-writer-wins → searchsorted routing → one-hot segment matmul,
which costs an HBM round-trip between every step.  This module collapses
the chain for the sum/mean monoids:

  * inputs that fit one VMEM tile run ONE kernel: the stable 3-lane
    bitonic network (carrying the value rows, validity and sign lanes
    through every compare-exchange), the last-writer-wins scan, the
    affected-key one-hot and the MXU accumulation — the shuffle+reduce
    touches HBM exactly once in each direction;
  * larger inputs sort via the multi-tile network
    (``sort_u32.sorted_lanes``), gather the payload once through the
    permutation, and feed the sorted tiles straight into a fused
    LWW+reduce kernel — per tile, the merge decision and the segment
    accumulation happen in VMEM without re-materializing intermediate
    live masks or segment ids in HBM.  Cross-tile last-writer boundaries
    are resolved by handing each tile its successor's first (k2, mk).

Key routing is one-hot *equality* against the sorted ``affected_keys``
vector (segment id = the slot whose key matches), which is exactly the
searchsorted+membership test of the unfused path for a sorted, unique,
INVALID_KEY-padded key set — pad slots can't match because dead rows are
masked out of the one-hot.  ``repro.kernels.ops.shuffle_reduce`` is the
dispatcher that decides when this path applies; this module is pure
mechanism.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sort_u32 import (
    SORT_TILE, _lex_lt, default_interpret, pad_lanes, padded_length,
    sorted_lanes,
)

FUSED_KBLK = 512        # affected-key block for the multi-tile reduce


def _sort_lww_reduce_kernel(hi_ref, lo_ref, idx_ref, val_ref, vld_ref,
                            sgn_ref, key_ref, ho_ref, lo_o_ref, po_ref,
                            vo_ref, live_ref, acc_ref, cnt_ref, *, m: int):
    """Single-tile total fusion: network + LWW + one-hot reduce, one launch.

    Only the three int lanes ride the compare-exchange stages; the index
    lane *is* the sort permutation, so the payload (values, validity,
    sign) is gathered once afterwards — still inside the kernel, so the
    whole shuffle+merge+reduce is a single HBM round-trip.  (Routing the
    payload through every stage is semantically identical but makes XLA's
    CPU fusion pass blow up exponentially on the chained 2-D gathers.)
    """
    hi = hi_ref[...]
    lo = lo_ref[...]
    idx = idx_ref[...]
    pos = jax.lax.iota(jnp.int32, m)

    k = 2
    while k <= m:
        j = k // 2
        while j >= 1:
            partner = jnp.bitwise_xor(pos, j)
            ph = hi[partner]
            plo = lo[partner]
            pi = idx[partner]
            up = (jnp.bitwise_and(pos, k) == 0)
            want_min = up == (pos < partner)
            own_lt = _lex_lt(hi, lo, idx, ph, plo, pi)
            take_own = jnp.where(want_min, own_lt, ~own_lt)
            sel = lambda a, b: jnp.where(take_own, a, b)
            hi, lo, idx = sel(hi, ph), sel(lo, plo), sel(idx, pi)
            j //= 2
        k *= 2

    val = val_ref[...][idx]
    vld = vld_ref[...][idx]
    sgn = sgn_ref[...][idx]

    # last-writer-wins per (k2, mk); tombstones (sign <= 0) delete
    nhi = jnp.roll(hi, -1)
    nlo = jnp.roll(lo, -1)
    is_last = (pos == m - 1) | (nhi != hi) | (nlo != lo)
    live = (vld != 0) & is_last & (sgn > 0)

    keys = key_ref[...]
    onehot = (hi[:, None] == keys[None, :]) & live[:, None]
    acc_t = acc_ref.dtype
    acc_ref[...] = jnp.dot(onehot.astype(acc_t).T, val.astype(acc_t),
                           preferred_element_type=acc_t)
    cnt_ref[...] = jnp.sum(onehot.astype(jnp.int32), axis=0)
    ho_ref[...] = hi
    lo_o_ref[...] = lo
    po_ref[...] = idx
    vo_ref[...] = val
    live_ref[...] = live.astype(jnp.int32)


def _lww_reduce_kernel(hi_ref, lo_ref, val_ref, vld_ref, sgn_ref, nh_ref,
                       nl_ref, key_ref, live_ref, acc_ref, cnt_ref, *,
                       tile: int, tiles: int, kblk: int):
    """Multi-tile epilogue: sorted tile -> live mask -> segment block.

    Grid (tiles, kblocks); the output segment block stays resident across
    the tile loop (stationary-output index map, init at the first tile).
    ``nh/nl`` carry the successor tile's first (k2, mk) so the
    last-writer test never needs a second HBM pass over the sorted lanes.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    hi = hi_ref[...]
    lo = lo_ref[...]
    pos = jax.lax.iota(jnp.int32, tile)
    at_edge = pos == tile - 1
    nhi = jnp.where(at_edge, nh_ref[0], jnp.roll(hi, -1))
    nlo = jnp.where(at_edge, nl_ref[0], jnp.roll(lo, -1))
    is_last = (nhi != hi) | (nlo != lo)
    is_last = is_last | ((i == tiles - 1) & at_edge)
    live = (vld_ref[...] != 0) & is_last & (sgn_ref[...] > 0)
    live_ref[...] = live.astype(jnp.int32)

    keys = key_ref[...]
    onehot = (hi[:, None] == keys[None, :]) & live[:, None]
    acc_t = acc_ref.dtype
    acc_ref[...] += jnp.dot(onehot.astype(acc_t).T,
                            val_ref[...].astype(acc_t),
                            preferred_element_type=acc_t)
    cnt_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)


def _pad_rows_to(a: jax.Array, m: int, fill=0):
    n = a.shape[0]
    if m == n:
        return a
    return jnp.concatenate(
        [a, jnp.full((m - n,) + a.shape[1:], fill, a.dtype)])


@functools.partial(jax.jit,
                   static_argnames=("out_dtype", "tile", "kblk", "interpret"))
def fused_shuffle_reduce(k2: jax.Array, mk: jax.Array, vals: jax.Array,
                         valid: jax.Array, sign: jax.Array,
                         affected_keys: jax.Array, *, out_dtype,
                         tile: int = SORT_TILE, kblk: int = FUSED_KBLK,
                         interpret: bool | None = None):
    """Sort (k2, mk) stably, merge last-writer-wins, sum live rows per key.

    ``vals`` is [N, D]; ``affected_keys`` is sorted ascending, unique among
    real entries, padded with int32 max.  Returns
    ``(k2_s, mk_s, vals_s, live, perm, acc, counts)`` — the first five are
    the sorted/merged rows (length N), ``acc`` is [key_cap, D] in
    ``out_dtype`` and ``counts`` [key_cap] int32 counts the live rows per
    affected key.  Invalid rows must already carry k2 = int32 max.
    """
    if interpret is None:
        interpret = default_interpret()
    if tile & (tile - 1):
        raise ValueError(f"tile must be a power of two, got {tile}")
    n = k2.shape[0]
    d = vals.shape[1]
    key_cap = affected_keys.shape[0]
    assert n > 0 and key_cap > 0, "dispatcher must route empty inputs to xla"

    m = padded_length(n, tile)
    hi, lo = pad_lanes(k2, mk, m)
    idx = jnp.arange(m, dtype=jnp.int32)
    val = _pad_rows_to(vals, m)
    vld = _pad_rows_to(valid.astype(jnp.int32), m)
    sgn = _pad_rows_to(sign.astype(jnp.int32), m)

    if m <= tile:
        # whole problem in VMEM: one launch end to end
        kfull = key_cap
        outs = pl.pallas_call(
            functools.partial(_sort_lww_reduce_kernel, m=m),
            grid=(1,),
            in_specs=[
                pl.BlockSpec((m,), lambda i: (0,)),
                pl.BlockSpec((m,), lambda i: (0,)),
                pl.BlockSpec((m,), lambda i: (0,)),
                pl.BlockSpec((m, d), lambda i: (0, 0)),
                pl.BlockSpec((m,), lambda i: (0,)),
                pl.BlockSpec((m,), lambda i: (0,)),
                pl.BlockSpec((kfull,), lambda i: (0,)),
            ],
            out_specs=[
                pl.BlockSpec((m,), lambda i: (0,)),
                pl.BlockSpec((m,), lambda i: (0,)),
                pl.BlockSpec((m,), lambda i: (0,)),
                pl.BlockSpec((m, d), lambda i: (0, 0)),
                pl.BlockSpec((m,), lambda i: (0,)),
                pl.BlockSpec((kfull, d), lambda i: (0, 0)),
                pl.BlockSpec((kfull,), lambda i: (0,)),
            ],
            out_shape=[
                jax.ShapeDtypeStruct((m,), k2.dtype),
                jax.ShapeDtypeStruct((m,), mk.dtype),
                jax.ShapeDtypeStruct((m,), jnp.int32),
                jax.ShapeDtypeStruct((m, d), vals.dtype),
                jax.ShapeDtypeStruct((m,), jnp.int32),
                jax.ShapeDtypeStruct((kfull, d), out_dtype),
                jax.ShapeDtypeStruct((kfull,), jnp.int32),
            ],
            interpret=interpret,
        )(hi, lo, idx, val, vld, sgn, affected_keys)
        hi_s, lo_s, perm, val_s, live, acc, cnt = outs
        return (hi_s[:n], lo_s[:n], val_s[:n], live[:n] != 0, perm[:n],
                acc, cnt)

    # multi-tile: sort the lanes, gather the payload once, then the fused
    # LWW+reduce epilogue per (sorted tile, key block)
    hi_s, lo_s, perm = sorted_lanes(hi, lo, idx, tile=tile,
                                    interpret=interpret)
    val_s = jnp.take(val, perm, axis=0)
    vld_s = jnp.take(vld, perm, axis=0)
    sgn_s = jnp.take(sgn, perm, axis=0)

    tiles = m // tile
    sentinel = jnp.iinfo(jnp.int32).max
    nxt_hi = jnp.concatenate([hi_s[tile::tile],
                              jnp.array([sentinel], hi_s.dtype)])
    nxt_lo = jnp.concatenate([lo_s[tile::tile],
                              jnp.array([sentinel], lo_s.dtype)])

    kblk = min(kblk, key_cap)
    kpad = (kblk - key_cap % kblk) % kblk
    keys = _pad_rows_to(affected_keys, key_cap + kpad, fill=sentinel)
    kfull = key_cap + kpad

    live, acc, cnt = pl.pallas_call(
        functools.partial(_lww_reduce_kernel, tile=tile, tiles=tiles,
                          kblk=kblk),
        grid=(tiles, kfull // kblk),
        in_specs=[
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((tile, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((1,), lambda i, j: (i,)),
            pl.BlockSpec((kblk,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((kblk, d), lambda i, j: (j, 0)),
            pl.BlockSpec((kblk,), lambda i, j: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((kfull, d), out_dtype),
            jax.ShapeDtypeStruct((kfull,), jnp.int32),
        ],
        interpret=interpret,
    )(hi_s, lo_s, val_s, vld_s, sgn_s, nxt_hi, nxt_lo, keys)
    return (hi_s[:n], lo_s[:n], val_s[:n], live[:n] != 0, perm[:n],
            acc[:key_cap], cnt[:key_cap])
