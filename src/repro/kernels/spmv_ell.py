"""ELL-format SpMV for PageRank-style propagation (Map+shuffle+Reduce fused).

PageRank's per-iteration work is y[j] += x[i]/deg(i) over edges (i -> j).
On GPU this is a gather/scatter; the TPU adaptation tiles the *output*
vertex range into VMEM-resident blocks and turns the scatter into a one-hot
MXU matmul per (row-tile, output-block) grid cell:

    contrib[T·F] = x[rows]/deg broadcast over the padded neighbor slots
    y_blk += onehot(nbrs - blk_start)[T·F, KBLK]^T @ contrib[T·F, 1]

The output block is stationary in VMEM across the row-tile loop; invalid
slots (nbr = -1) land outside every block.  This is the fused form of
kernels/segment_reduce specialized to the graph workload the paper evaluates.

ref.py oracle: ``spmv_ell_ref`` (segment_sum over flattened edges).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.ref import spmv_ell_ref  # noqa: F401  (back-compat)

DEFAULT_ROWS = 256
DEFAULT_KBLK = 1024


def _kernel(nbr_ref, contrib_ref, out_ref, *, rows: int, kblk: int, f: int):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    nbrs = nbr_ref[...].reshape(rows * f)            # [T*F]
    contrib = contrib_ref[...].reshape(rows * f, 1)  # [T*F, 1]
    local = nbrs - j * kblk
    onehot = (local[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (rows * f, kblk), 1))
    out_ref[...] += jnp.dot(onehot.astype(contrib.dtype).T, contrib,
                            preferred_element_type=out_ref.dtype)[:, 0]


@functools.partial(jax.jit, static_argnames=("num_vertices", "rows", "kblk",
                                             "interpret"))
def spmv_ell(nbrs: jax.Array, contrib: jax.Array, num_vertices: int, *,
             rows: int = DEFAULT_ROWS, kblk: int = DEFAULT_KBLK,
             interpret: bool = True) -> jax.Array:
    """nbrs [S, F] int32 (-1 padding), contrib [S, F] float32.

    Returns y [num_vertices] with y[j] = sum of contrib over edges into j.
    """
    s, f = nbrs.shape
    rows_ = min(rows, s)
    if s % rows_ != 0:
        pad = rows_ - s % rows_
        nbrs = jnp.concatenate([nbrs, jnp.full((pad, f), -1, nbrs.dtype)])
        contrib = jnp.concatenate([contrib,
                                   jnp.zeros((pad, f), contrib.dtype)])
        s = nbrs.shape[0]
    kblk_ = min(kblk, max(num_vertices, 1))
    kpad = (kblk_ - num_vertices % kblk_) % kblk_
    kfull = num_vertices + kpad

    y = pl.pallas_call(
        functools.partial(_kernel, rows=rows_, kblk=kblk_, f=f),
        grid=(s // rows_, kfull // kblk_),
        in_specs=[
            pl.BlockSpec((rows_, f), lambda i, j: (i, 0)),
            pl.BlockSpec((rows_, f), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((kblk_,), lambda i, j: (j,)),
        out_shape=jax.ShapeDtypeStruct((kfull,), jnp.float32),
        interpret=interpret,
    )(nbrs.astype(jnp.int32), contrib.astype(jnp.float32))
    return y[:num_vertices]
