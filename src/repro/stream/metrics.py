"""Per-tenant streaming telemetry: throughput, latency percentiles, modes.

Latency is measured end-to-end per micro-batch: from the earliest buffered
row's enqueue timestamp to the moment the refreshed result is visible.
Sustained updates/sec counts delta rows entering the coalescer (the
tenant-facing unit of work), not engine rows.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional


class StreamMetrics:
    """Thread-safe counters + a bounded latency reservoir."""

    def __init__(self, max_samples: int = 4096):
        self._lock = threading.Lock()
        self.max_samples = max_samples
        self.t_start = time.perf_counter()
        self.busy_seconds = 0.0          # time spent inside refreshes
        self.rows_in = 0                 # delta rows ingested
        self.rows_engine = 0             # rows surviving the coalescer
        self.rows_cancelled = 0          # rows the coalescer cancelled
        self.net_inserts = 0             # records whose net effect inserted
        self.net_deletes = 0             # records whose net effect deleted
        self.rows_rejected = 0           # rows refused at ingest (bad ids)
        self.retrace_batches = 0         # batches that traced a jit kernel
        self.batches = 0
        self.refreshes: Dict[str, int] = {}   # action -> count
        self.compactions = 0
        self.bytes_reclaimed = 0
        self.last_epoch = -1             # highest source watermark applied
        self._latencies: List[float] = []     # end-to-end batch latency (s)
        self._refresh_seconds: List[float] = []

    # -- recording ---------------------------------------------------------
    def observe_batch(self, n_in: int, n_engine: int, action: str,
                      latency_s: float, refresh_s: float,
                      epoch: int = -1, retraced: bool = False,
                      n_cancelled: int = 0, n_inserts: int = 0,
                      n_deletes: int = 0) -> None:
        with self._lock:
            self.rows_in += n_in
            self.rows_engine += n_engine
            self.rows_cancelled += n_cancelled
            self.net_inserts += n_inserts
            self.net_deletes += n_deletes
            self.batches += 1
            self.retrace_batches += int(retraced)
            self.refreshes[action] = self.refreshes.get(action, 0) + 1
            self.busy_seconds += refresh_s
            self.last_epoch = max(self.last_epoch, epoch)
            for buf, v in ((self._latencies, latency_s),
                           (self._refresh_seconds, refresh_s)):
                buf.append(v)
                if len(buf) > self.max_samples:
                    del buf[:len(buf) - self.max_samples]

    def observe_compaction(self, bytes_reclaimed: int) -> None:
        with self._lock:
            self.compactions += 1
            self.bytes_reclaimed += bytes_reclaimed

    def observe_rejected(self, n_rows: int) -> None:
        """Rows refused at ingest validation (e.g. out-of-range ids)."""
        with self._lock:
            self.rows_rejected += n_rows

    # -- reading -----------------------------------------------------------
    @staticmethod
    def _pct(samples: List[float], p: float) -> float:
        if not samples:
            return 0.0
        s = sorted(samples)
        idx = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
        return s[idx]

    def latency_pct(self, p: float) -> float:
        with self._lock:
            return self._pct(self._latencies, p)

    def refresh_pct(self, p: float) -> float:
        with self._lock:
            return self._pct(self._refresh_seconds, p)

    def updates_per_sec(self) -> float:
        """Sustained ingested rows per second of refresh busy-time."""
        with self._lock:
            return self.rows_in / self.busy_seconds \
                if self.busy_seconds > 0 else 0.0

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            lat, ref = list(self._latencies), list(self._refresh_seconds)
            out = {
                "rows_in": self.rows_in,
                "rows_engine": self.rows_engine,
                "coalesce_savings": 1.0 - (self.rows_engine /
                                           max(self.rows_in, 1)),
                "rows_cancelled": self.rows_cancelled,
                "net_inserts": self.net_inserts,
                "net_deletes": self.net_deletes,
                "rows_rejected": self.rows_rejected,
                "batches": self.batches,
                "retrace_batches": self.retrace_batches,
                "refreshes": dict(self.refreshes),
                "busy_seconds": self.busy_seconds,
                "updates_per_sec": self.rows_in / self.busy_seconds
                if self.busy_seconds > 0 else 0.0,
                "compactions": self.compactions,
                "bytes_reclaimed": self.bytes_reclaimed,
                "last_epoch": self.last_epoch,
            }
        for name, buf in (("latency", lat), ("refresh", ref)):
            out[f"{name}_p50_ms"] = self._pct(buf, 50) * 1e3
            out[f"{name}_p95_ms"] = self._pct(buf, 95) * 1e3
        return out
