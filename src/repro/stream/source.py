"""Delta sources: where continuously-arriving updates enter the system.

A :class:`DeltaRecord` is one timestamped group of signed delta rows — the
paper's ΔD in motion, stamped with the producer's epoch watermark.  A
:class:`DeltaSource` emits them in arrival order; the StreamSession polls,
micro-batches, coalesces and refreshes.

Three sources cover the serving spectrum:

  * :class:`QueueSource`     — in-memory bounded queue (push-based
    producers; backpressure via blocking ``push``).
  * :class:`FileTailSource`  — replayable JSONL tail, the stand-in for a
    durable log (Kafka topic / HDFS append file): each line is one record,
    re-reads resume from the current offset, ``rewind()`` replays.
  * :class:`SyntheticSource` — wraps :class:`repro.data.DeltaStream` to
    generate an evolving dataset for examples/benchmarks.
"""
from __future__ import annotations

import json
import queue as queue_mod
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclass(frozen=True)
class DeltaRecord:
    """One group of signed delta rows ('-' old row then '+' new row for an
    update, exactly the paper's §3.1 encoding)."""

    record_ids: np.ndarray               # [N] int32
    values: Dict[str, np.ndarray]        # name -> [N, ...]
    sign: np.ndarray                     # [N] int8 (+1 insert / -1 delete)
    timestamp: float = 0.0               # producer wall-clock (seconds)
    epoch: int = 0                       # producer watermark

    def __post_init__(self):
        object.__setattr__(self, "record_ids",
                           np.asarray(self.record_ids, np.int32))
        object.__setattr__(self, "sign", np.asarray(self.sign, np.int8))
        object.__setattr__(self, "values",
                           {n: np.asarray(a) for n, a in self.values.items()})
        n = self.record_ids.shape[0]
        if self.sign.shape[0] != n or any(
                a.shape[0] != n for a in self.values.values()):
            raise ValueError("record_ids, sign and every values leaf must "
                             "share the leading row dimension")

    @property
    def n_rows(self) -> int:
        return int(self.record_ids.shape[0])


class DeltaSource:
    """Pull interface of the ingestion layer."""

    def poll(self, max_rows: int) -> List[DeltaRecord]:
        """Return available records (possibly []) without blocking.  May
        return slightly more than ``max_rows`` rows: records are atomic."""
        raise NotImplementedError

    @property
    def exhausted(self) -> bool:
        """True once no further record will ever be emitted."""
        raise NotImplementedError

    @property
    def watermark(self) -> int:
        """Highest epoch fully emitted so far (-1 before the first)."""
        raise NotImplementedError

    def close(self) -> None:
        pass


class QueueSource(DeltaSource):
    """Bounded in-memory queue: ``push`` blocks when full (backpressure to
    the producer), ``seal()`` marks the end of the stream."""

    def __init__(self, capacity: int = 1024):
        self._q: queue_mod.Queue = queue_mod.Queue(maxsize=capacity)
        self._sealed = False
        self._watermark = -1

    def push(self, record: DeltaRecord, timeout: Optional[float] = None):
        if self._sealed:
            raise RuntimeError("push() on a sealed QueueSource")
        self._q.put(record, block=True, timeout=timeout)

    def seal(self) -> None:
        self._sealed = True

    def poll(self, max_rows: int) -> List[DeltaRecord]:
        out: List[DeltaRecord] = []
        rows = 0
        while rows < max_rows:
            try:
                rec = self._q.get_nowait()
            except queue_mod.Empty:
                break
            out.append(rec)
            rows += rec.n_rows
            self._watermark = max(self._watermark, rec.epoch)
        return out

    @property
    def exhausted(self) -> bool:
        return self._sealed and self._q.empty()

    @property
    def watermark(self) -> int:
        return self._watermark


class FileTailSource(DeltaSource):
    """Replayable JSONL tail.

    Each line encodes one :class:`DeltaRecord`:

        {"epoch": 3, "ts": 1700000000.0, "record_ids": [5, 5],
         "sign": [-1, 1], "values": {"nbrs": [[...], [...]]}}

    ``poll`` consumes complete lines past the current offset, so a file
    being appended by another process is tailed incrementally;
    ``follow=False`` treats end-of-file as end-of-stream.  ``rewind()``
    replays from the beginning — the recovery story for a lost serving
    node is "restore the snapshot, rewind the log to the snapshot's
    watermark, drain".
    """

    def __init__(self, path: str, dtypes: Optional[Dict[str, str]] = None,
                 follow: bool = False):
        self.path = path
        self.dtypes = dtypes or {}
        self.follow = follow
        self._offset = 0
        self._watermark = -1
        self._skip_through = -1
        self._eof_seen = False

    def rewind(self, epoch: int = -1) -> None:
        """Replay records with epoch > ``epoch`` (default: everything)."""
        self._offset = 0
        self._watermark = -1
        self._skip_through = epoch
        self._eof_seen = False

    def _parse(self, line: str) -> Optional[DeltaRecord]:
        obj = json.loads(line)
        values = {n: np.asarray(a, dtype=self.dtypes.get(n))
                  for n, a in obj["values"].items()}
        return DeltaRecord(record_ids=obj["record_ids"], values=values,
                           sign=obj["sign"], timestamp=obj.get("ts", 0.0),
                           epoch=obj.get("epoch", 0))

    def poll(self, max_rows: int) -> List[DeltaRecord]:
        out: List[DeltaRecord] = []
        rows = 0
        try:
            with open(self.path, "r") as f:
                f.seek(self._offset)
                while rows < max_rows:
                    pos = f.tell()
                    line = f.readline()
                    if not line.endswith("\n"):   # incomplete tail / EOF
                        self._offset = pos
                        self._eof_seen = True
                        break
                    self._offset = f.tell()
                    if not line.strip():
                        continue
                    rec = self._parse(line)
                    if rec.epoch <= self._skip_through:
                        continue      # before the rewind cursor: replayed
                    out.append(rec)
                    rows += rec.n_rows
                    self._watermark = max(self._watermark, rec.epoch)
        except FileNotFoundError:
            self._eof_seen = True
        return out

    @property
    def exhausted(self) -> bool:
        return self._eof_seen and not self.follow

    @property
    def watermark(self) -> int:
        return self._watermark

    @staticmethod
    def write(path: str, records: Sequence[DeltaRecord],
              append: bool = True) -> None:
        """Append records to the log (the producer side, and the test rig)."""
        with open(path, "a" if append else "w") as f:
            for r in records:
                f.write(json.dumps(
                    {"epoch": r.epoch, "ts": r.timestamp,
                     "record_ids": np.asarray(r.record_ids).tolist(),
                     "sign": np.asarray(r.sign).tolist(),
                     "values": {n: np.asarray(a).tolist()
                                for n, a in r.values.items()}}) + "\n")


class SyntheticSource(DeltaSource):
    """Evolving-dataset generator: one DeltaRecord per epoch, ``epochs``
    total, produced by a :class:`repro.data.DeltaStream` mutator.  The
    mutated host mirror stays readable as ``self.values`` — the oracle
    input for end-to-end checks."""

    def __init__(self, values: Dict[str, np.ndarray], frac: float = 0.05,
                 seed: int = 0, epochs: int = 10,
                 mutator: Optional[Callable] = None):
        from repro.data import DeltaStream
        self.stream = DeltaStream(values, frac=frac, seed=seed,
                                  mutator=mutator)
        self.epochs = epochs
        self._emitted = 0

    @property
    def values(self) -> Dict[str, np.ndarray]:
        """The fully-updated dataset mirror (advances as polls consume)."""
        return self.stream.values

    def poll(self, max_rows: int) -> List[DeltaRecord]:
        out: List[DeltaRecord] = []
        rows = 0
        while self._emitted < self.epochs and rows < max_rows:
            rid, vals, sign = self.stream.delta()
            rec = DeltaRecord(record_ids=rid, values=vals, sign=sign,
                              timestamp=time.time(), epoch=self._emitted)
            out.append(rec)
            rows += rec.n_rows
            self._emitted += 1
        return out

    @property
    def exhausted(self) -> bool:
        return self._emitted >= self.epochs

    @property
    def watermark(self) -> int:
        return self._emitted - 1
