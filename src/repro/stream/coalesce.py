"""Micro-batch coalescer: cancel opposing +/- rows before the engine runs.

A streaming producer that updates record r three times in one micro-batch
emits six rows ('-' old, '+' new, three times); the engine only needs two —
a tombstone for the value the preserved MRBGraph was computed from, and an
insert of the newest value.  Per record id the net effect of an in-order
signed row sequence is fully determined by its first and last rows:

  first '-' , last '+'   ->  keep both   (update: tombstone old, insert new)
  first '-' , last '-'   ->  keep first  (net delete)
  first '+' , last '+'   ->  keep last   (net insert)
  first '+' , last '-'   ->  keep none   (created and destroyed in-batch)

The hot path is pure JAX riding the PR-3 backend dispatcher: a stable
lexicographic sort by (record id, arrival index) through
:func:`repro.kernels.ops.sort_pairs` groups each record's rows while
preserving arrival order, and a segment-sum of the signs through
:func:`repro.kernels.ops.segment_reduce` yields each record's net row
balance (the upsert/delete telemetry).  Only the final variable-length
compaction of surviving rows happens on the host — the same host/device
split as the incremental engine itself.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.incremental import DeltaKV, make_delta
from repro.core.kvstore import INVALID_KEY, next_bucket
from repro.kernels import jitcache, ops


class CoalesceResult(NamedTuple):
    delta: Optional[DeltaKV]   # None when every row cancelled out
    n_in: int                  # rows entering the coalescer
    n_out: int                 # rows surviving (== delta rows)
    n_records: int             # distinct record ids touched
    n_inserts: int             # records whose net effect is an insert
    n_deletes: int             # records whose net effect is a delete

    @property
    def n_cancelled(self) -> int:
        return self.n_in - self.n_out


@functools.partial(jax.jit, static_argnums=(0, 1))
def _coalesce_kernel(cap: int, backend: Optional[str], rid: jax.Array,
                     sign: jax.Array, valid: jax.Array):
    """Device part: sort + group-boundary flags + per-record net sign."""
    jitcache.count_trace("stream._coalesce_kernel")
    iota = jnp.arange(cap, dtype=jnp.int32)
    rid_m = jnp.where(valid, rid, INVALID_KEY)
    srt = ops.sort_pairs(rid_m, iota, payload=(sign, valid), num_keys=2,
                         backend=backend)
    sg, v = srt.payload
    k2 = srt.k2
    first = jnp.logical_or(iota == 0, k2 != jnp.roll(k2, 1))
    last = jnp.logical_or(iota == cap - 1, k2 != jnp.roll(k2, -1))
    keep = v & ((first & (sg < 0)) | (last & (sg > 0)))
    # net row balance per record: +1 net insert, -1 net delete, 0 update
    seg = jnp.cumsum(first.astype(jnp.int32)) - 1
    net, cnt = ops.segment_reduce("sum", seg, sg.astype(jnp.int32), v, cap,
                                  backend=backend)
    return srt.perm, keep, first & v, net, cnt


def coalesce_rows(record_ids: np.ndarray, values: Dict[str, np.ndarray],
                  sign: np.ndarray, *,
                  backend: Optional[str] = None) -> CoalesceResult:
    """Coalesce one micro-batch of signed rows (arrival order) into the
    minimal equivalent :class:`DeltaKV`."""
    record_ids = np.asarray(record_ids, np.int32)
    sign = np.asarray(sign, np.int8)
    n = int(record_ids.shape[0])
    if n == 0:
        return CoalesceResult(None, 0, 0, 0, 0, 0)
    bk = ops.resolve_backend(backend)
    cap = next_bucket(n, 64)
    rid_pad = np.full(cap, np.int32(2**31 - 1), np.int32)
    rid_pad[:n] = record_ids
    sg_pad = np.zeros(cap, np.int8)
    sg_pad[:n] = sign
    valid = np.zeros(cap, bool)
    valid[:n] = True

    perm, keep, firsts, net, cnt = _coalesce_kernel(
        cap, bk, jnp.asarray(rid_pad), jnp.asarray(sg_pad),
        jnp.asarray(valid))
    perm = np.asarray(perm)
    keep = np.asarray(keep)
    firsts = np.asarray(firsts)
    net = np.asarray(net)
    cnt = np.asarray(cnt)

    # host compaction: surviving rows in (record id, arrival) order
    sel = perm[keep]
    n_records = int(firsts.sum())
    real = cnt > 0                      # segments holding valid rows
    n_inserts = int(((net > 0) & real).sum())
    n_deletes = int(((net < 0) & real).sum())
    if sel.size == 0:
        return CoalesceResult(None, n, 0, n_records, n_inserts, n_deletes)
    delta = make_delta(record_ids[sel],
                       {nm: np.asarray(a)[sel] for nm, a in values.items()},
                       sign[sel])
    return CoalesceResult(delta, n, int(sel.size), n_records, n_inserts,
                          n_deletes)


def concat_records(records: Sequence[Any]):
    """Concatenate DeltaRecords (arrival order) into flat row arrays."""
    rids = np.concatenate([np.asarray(r.record_ids, np.int32)
                           for r in records])
    signs = np.concatenate([np.asarray(r.sign, np.int8) for r in records])
    names = records[0].values.keys()
    values = {n: np.concatenate([np.asarray(r.values[n]) for r in records])
              for n in names}
    return rids, values, signs


def coalesce(records: Sequence[Any], *,
             backend: Optional[str] = None) -> CoalesceResult:
    """Coalesce a sequence of :class:`repro.stream.DeltaRecord`s."""
    if not records:
        return CoalesceResult(None, 0, 0, 0, 0, 0)
    rids, values, signs = concat_records(records)
    return coalesce_rows(rids, values, signs, backend=backend)
