"""StreamSession: the async serving driver over one ``repro.api.Session``.

One thread owns the engine; producers push signed delta rows through a
bounded queue (blocking ``submit`` = backpressure) and/or a
:class:`repro.stream.DeltaSource` is polled.  Rows are micro-batched
(``StreamConfig.max_batch_records`` / ``max_batch_delay``), coalesced, and
applied through whichever refresh path the :class:`RefreshScheduler`
picks — fine-grain incremental ``update()`` or full ``rerun()`` on the
maintained input mirror.  ``drain()`` blocks until every available row is
reflected in ``result``; ``snapshot()`` checkpoints the session together
with the stream watermark so a replayable source can resume after
recovery.
"""
from __future__ import annotations

import json
import queue as queue_mod
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api.config import RunConfig, StreamConfig
from repro.api.session import Session, Spec
from repro.core.incremental import apply_delta_host, make_delta
from repro.core.kvstore import KV
from repro.stream.coalesce import CoalesceResult, coalesce, concat_records
from repro.stream.metrics import StreamMetrics
from repro.stream.scheduler import RefreshScheduler
from repro.stream.source import DeltaRecord, DeltaSource


class StreamSession:
    """Continuously refresh one declared job from a delta stream."""

    def __init__(self, spec: Spec, data: KV,
                 source: Optional[DeltaSource] = None,
                 config: Optional[RunConfig] = None,
                 stream: Optional[StreamConfig] = None,
                 name: str = "session"):
        self.name = name
        self.session = Session(spec, config)
        self.sconfig = stream or StreamConfig()
        self.source = source
        self.scheduler = RefreshScheduler(self.sconfig)
        self.metrics = StreamMetrics()

        # input mirror (the partitioned input file on HDFS): rerun() and
        # the cold-run oracle both read it
        self._mkeys = np.array(data.keys)
        self._mvalues = {n: np.array(a) for n, a in data.values.items()}
        self._mvalid = np.array(data.valid)

        self._inbox: queue_mod.Queue = queue_mod.Queue(
            maxsize=self.sconfig.queue_capacity)
        self._pending: List[Tuple[DeltaRecord, float]] = []
        self._pending_rows = 0
        self._lock = threading.RLock()       # engine + mirror + scheduler
        self._stop_evt = threading.Event()
        self._flush = False
        self._busy = False
        self._starved = False                # last ingest found nothing
        self._thread: Optional[threading.Thread] = None
        self._managed = False                # scheduled by a server
        self._error: Optional[BaseException] = None

    # -- lifecycle ---------------------------------------------------------
    def start(self, background: bool = True) -> "StreamSession":
        """Run the initial job, then (optionally) start the worker thread.

        ``background=False`` leaves batch processing to explicit
        :meth:`step` calls — the mode :class:`MultiSessionServer` uses to
        time-slice many tenants over one thread.
        """
        with self._lock:
            if self.session.epoch < 0:
                rep = self.session.run(self._mirror_kv())
                self.scheduler.seed(rep.seconds)
        if background and self._thread is None:
            self._stop_evt.clear()           # allow stop() -> start() cycles
            self._thread = threading.Thread(
                target=self._loop, name=f"stream-{self.name}", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        """Stop the worker; rows not yet processed stay buffered."""
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    def __enter__(self) -> "StreamSession":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- ingestion ---------------------------------------------------------
    def submit(self, record_ids, values, sign, *, epoch: int = 0,
               timeout: Optional[float] = None) -> None:
        """Push one group of signed delta rows.  Blocks while the ingest
        queue is full (backpressure); raises ``queue.Full`` on timeout."""
        rec = DeltaRecord(record_ids=record_ids, values=values, sign=sign,
                          timestamp=time.time(), epoch=epoch)
        self.submit_record(rec, timeout=timeout)

    def submit_record(self, record: DeltaRecord,
                      timeout: Optional[float] = None) -> None:
        self._inbox.put((record, time.perf_counter()), block=True,
                        timeout=timeout)

    def _ingest(self) -> bool:
        """Move rows from the inbox and the source into the pending batch
        (never beyond one batch's budget: the inbox stays bounded and the
        producers blocked — that is the backpressure path)."""
        # not idle while probing: a concurrent drain() must not observe the
        # window where a record left the inbox but isn't pending yet
        self._starved = False
        progressed = False
        budget = self.sconfig.max_batch_records - self._pending_rows
        while budget > 0:
            try:
                rec, arrival = self._inbox.get_nowait()
            except queue_mod.Empty:
                break
            self._pending.append((rec, arrival))
            self._pending_rows += rec.n_rows
            budget -= rec.n_rows
            progressed = True
        if self.source is not None and budget > 0 and \
                not self.source.exhausted:
            now = time.perf_counter()
            for rec in self.source.poll(budget):
                self._pending.append((rec, now))
                self._pending_rows += rec.n_rows
                progressed = True
        self._starved = not progressed and not self._pending
        return progressed

    def _should_fire(self) -> bool:
        if not self._pending:
            return False
        if self._flush or self._pending_rows >= self.sconfig.max_batch_records:
            return True
        oldest = self._pending[0][1]
        return (time.perf_counter() - oldest) >= self.sconfig.max_batch_delay

    # -- the refresh step --------------------------------------------------
    def step(self) -> bool:
        """One synchronous scheduling quantum: ingest, then process at most
        one micro-batch.  Returns True if a refresh ran."""
        self._ingest()
        if not self._should_fire():
            return False
        self._process_batch()
        return True

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                if not self.step():
                    time.sleep(self.sconfig.poll_interval)
            except BaseException as e:       # noqa: BLE001 — surfaced via
                self._error = e              # _check_error on drain/result
                return

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError(
                f"stream worker for {self.name!r} died; the failing "
                f"micro-batch was dropped") from self._error

    def _process_batch(self) -> None:
        self._busy = True
        try:
            batch = self._pending
            self._pending = []
            self._pending_rows = 0
            records = [r for r, _ in batch]
            first_arrival = min(a for _, a in batch)
            epoch = max(r.epoch for r in records)
            n_in = sum(r.n_rows for r in records)

            backend = self.session.config.backend
            if self.sconfig.coalesce:
                res = coalesce(records, backend=backend)
            else:
                rids, vals, signs = concat_records(records)
                res = CoalesceResult(make_delta(rids, vals, signs),
                                     n_in, n_in, 0, 0, 0)
            if res.delta is not None:
                rid = np.asarray(res.delta.record_ids)
                if rid.size and int(rid.max()) >= self._mkeys.shape[0]:
                    raise ValueError(
                        f"record id {int(rid.max())} outside the input "
                        f"mirror capacity {self._mkeys.shape[0]}; grow the "
                        f"initial data's padding to stream inserts")

            with self._lock:
                if res.delta is None:          # everything cancelled out
                    action, refresh_s = "noop", 0.0
                else:
                    apply_delta_host(self._mkeys, self._mvalues,
                                     self._mvalid, res.delta)
                    st = self.session.store
                    decision = self.scheduler.decide(
                        res.n_out, state_rows=int(self._mvalid.sum()),
                        store_file_bytes=st.file_bytes() if st else 0,
                        store_live_bytes=st.live_bytes() if st else 0)
                    if decision.action == "update":
                        rep = self.session.update(res.delta)
                    else:
                        rep = self.session.rerun(self._mirror_kv())
                    self.scheduler.observe(decision.action, res.n_out,
                                           rep.seconds)
                    action, refresh_s = decision.action, rep.seconds
            self.metrics.observe_batch(
                n_in=n_in, n_engine=res.n_out, action=action,
                latency_s=time.perf_counter() - first_arrival,
                refresh_s=refresh_s, epoch=epoch)
        finally:
            self._busy = False

    # -- synchronization ---------------------------------------------------
    @property
    def idle(self) -> bool:
        """No buffered input, no batch in flight, nothing the source can
        offer right now."""
        return (self._inbox.empty() and not self._pending
                and not self._busy and self._starved)

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every available delta row is reflected in
        ``result`` (flushes partial micro-batches immediately)."""
        deadline = time.perf_counter() + timeout
        self._flush = True
        try:
            while True:
                self._check_error()
                if self._thread is None and not self._managed:
                    self.step()              # sync mode: we are the consumer
                if self.idle:
                    return
                if time.perf_counter() > deadline:
                    raise TimeoutError(
                        f"drain() exceeded {timeout}s "
                        f"(inbox={self._inbox.qsize()}, "
                        f"pending={self._pending_rows} rows)")
                if self._thread is not None or self._managed:
                    time.sleep(min(self.sconfig.poll_interval, 0.005))
        finally:
            self._flush = False

    # -- outputs -----------------------------------------------------------
    @property
    def result(self) -> Dict[str, np.ndarray]:
        self._check_error()
        with self._lock:
            return self.session.result

    def report(self, **kw):
        with self._lock:
            return self.session.report(**kw)

    def _mirror_kv(self) -> KV:
        return KV(jnp.asarray(self._mkeys),
                  {n: jnp.asarray(a) for n, a in self._mvalues.items()},
                  jnp.asarray(self._mvalid))

    def mirror_kv(self) -> KV:
        """The fully-updated input as of the last processed batch — what a
        cold ``run()`` would consume to reproduce ``result``."""
        with self._lock:
            return self._mirror_kv()

    def snapshot(self, path: Optional[str] = None) -> Path:
        """Checkpoint the session plus the stream watermark; a replayable
        source can ``rewind(watermark)`` after restore and re-drain."""
        with self._lock:
            out = self.session.checkpoint(path)
            root = Path(path or self.session.config.checkpoint_dir)
            (root / "stream.json").write_text(json.dumps(
                {"watermark": self.metrics.last_epoch,
                 "epoch": self.session.epoch, "name": self.name}))
        return out

    def compact_store(self) -> int:
        """Reclaim obsolete MRBG bytes (the server's budget lever)."""
        with self._lock:
            reclaimed = self.session.compact_store()
        if reclaimed:
            self.metrics.observe_compaction(reclaimed)
        return reclaimed

    def store_bytes(self) -> int:
        with self._lock:
            return self.session.store_bytes()
