"""StreamSession: the async serving driver over one ``repro.api.Session``.

One thread owns the engine; producers push signed delta rows through a
bounded queue (blocking ``submit`` = backpressure) and/or a
:class:`repro.stream.DeltaSource` is polled.  Rows are micro-batched
(``StreamConfig.max_batch_records`` / ``max_batch_delay``), coalesced, and
applied through whichever refresh path the :class:`RefreshScheduler`
picks — fine-grain incremental ``update()`` or full ``rerun()`` on the
maintained input mirror.  ``drain()`` blocks until every available row is
reflected in ``result``; ``snapshot()`` checkpoints the session together
with the stream watermark so a replayable source can resume after
recovery.
"""
from __future__ import annotations

import json
import queue as queue_mod
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.api.config import RunConfig, StreamConfig
from repro.api.session import Session, Spec
from repro.core.incremental import apply_delta_host, make_delta
from repro.core.kvstore import KV, next_bucket
from repro.kernels import jitcache
from repro.stream.coalesce import (
    CoalesceResult, coalesce, coalesce_rows, concat_records,
)
from repro.stream.metrics import StreamMetrics
from repro.stream.scheduler import RefreshScheduler
from repro.stream.source import DeltaRecord, DeltaSource


@dataclass
class PreparedBatch:
    """One micro-batch after coalescing and mirror application, before the
    refresh itself.  ``StreamSession._process_batch`` consumes these
    in-place; the serving tier's batched cross-tenant path pulls them out
    via :meth:`StreamSession.prepare_batch`, runs many tenants' refreshes
    through one kernel launch, then calls ``commit_batch``/``rollback_batch``.
    """

    records: List[DeltaRecord]
    first_arrival: float
    epoch: int
    n_in: int
    res: CoalesceResult
    rows: Optional[np.ndarray]       # mirror rows saved for rollback
    saved: Optional[tuple]           # (keys, values, valid) at those rows
    decision: Optional[Any]          # scheduler decision; None => noop


class StreamSession:
    """Continuously refresh one declared job from a delta stream."""

    def __init__(self, spec: Spec, data: KV,
                 source: Optional[DeltaSource] = None,
                 config: Optional[RunConfig] = None,
                 stream: Optional[StreamConfig] = None,
                 name: str = "session"):
        self.name = name
        self.session = Session(spec, config)
        self.sconfig = stream or StreamConfig()
        self.source = source
        self.scheduler = RefreshScheduler(self.sconfig)
        self.metrics = StreamMetrics()

        # input mirror (the partitioned input file on HDFS): rerun() and
        # the cold-run oracle both read it
        self._mkeys = np.array(data.keys)
        self._mvalues = {n: np.array(a) for n, a in data.values.items()}
        self._mvalid = np.array(data.valid)

        self._inbox: queue_mod.Queue = queue_mod.Queue(
            maxsize=self.sconfig.queue_capacity)
        self._pending: List[Tuple[DeltaRecord, float]] = []
        self._pending_rows = 0
        self._lock = threading.RLock()       # engine + mirror + scheduler
        self._stop_evt = threading.Event()
        self._flush = False
        self._busy = False
        self._starved = False                # last ingest found nothing
        self._thread: Optional[threading.Thread] = None
        self._managed = False                # scheduled by a server
        self._error: Optional[BaseException] = None
        self._prewarmed = False
        self.grow_events = 0                 # mirror-capacity doublings

    # -- lifecycle ---------------------------------------------------------
    def start(self, background: bool = True) -> "StreamSession":
        """Run the initial job, then (optionally) start the worker thread.

        ``background=False`` leaves batch processing to explicit
        :meth:`step` calls — the mode :class:`MultiSessionServer` uses to
        time-slice many tenants over one thread.
        """
        with self._lock:
            if self.session.epoch < 0:
                rep = self.session.run(self._mirror_kv())
                self.scheduler.seed(rep.seconds)
            if self.sconfig.prewarm and not self._prewarmed:
                self._prewarm()
                self._prewarmed = True
        if background and self._thread is None:
            self._stop_evt.clear()           # allow stop() -> start() cycles
            self._thread = threading.Thread(
                target=self._loop, name=f"stream-{self.name}", daemon=True)
            self._thread.start()
        return self

    def _prewarm(self) -> None:
        """Compile the delta bucket ladder before real traffic arrives.

        Pushes numerically inert deltas ('-' then '+' of a record's current
        mirror value — a no-op on every refresh path) through
        ``session.update()`` at each power-of-two row capacity of the
        ladder, so the first real micro-batch of any bucket hits an
        already-cached executable instead of paying trace + compile time.
        """
        rows = np.nonzero(self._mvalid)[0]
        if rows.size == 0:
            return
        minimum = self.session.config.delta_bucket_min
        top = next_bucket(
            self.sconfig.prewarm_rows or self.sconfig.max_batch_records,
            minimum)
        floor = next_bucket(1, max(minimum, 2))
        backend = self.session.config.backend
        # ladder sizes: one full noop per row bucket above the minimum
        # (above the floor the valid count pins the downstream edge bucket),
        # plus a doubling sub-ladder inside the minimum bucket — there the
        # row capacity is clamped to the floor while the *valid* count (and
        # with it the edge bucket) still varies freely
        sizes, v = [], 2
        while v < floor:
            sizes.append(v)
            v *= 2
        while v <= top:
            sizes.append(v)
            v *= 2
        for size in sizes:
            delta = self._noop_delta(size, rows)
            if self.sconfig.coalesce:
                # real batches hit the coalescer kernel first; trace it at
                # this bucket too (its output is discarded — the engine is
                # warmed with the delta below)
                coalesce_rows(np.asarray(delta.record_ids),
                              {n: np.asarray(a)
                               for n, a in delta.values.items()},
                              np.asarray(delta.sign), backend=backend)
            self.session.update(delta)

    def _noop_delta(self, cap: int, rows: np.ndarray):
        """A ``cap``-row delta of '-'/'+' pairs replaying current values."""
        sel = rows[np.arange(cap // 2) % rows.size]
        rid = np.repeat(sel, 2).astype(np.int32)
        values = {n: np.repeat(a[sel], 2, axis=0)
                  for n, a in self._mvalues.items()}
        sign = np.tile(np.array([-1, 1], np.int8), cap // 2)
        keys = np.repeat(self._mkeys[sel], 2).astype(np.int32)
        return make_delta(rid, values, sign, keys=keys)

    def stop(self) -> None:
        """Stop the worker; rows not yet processed stay buffered."""
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    def __enter__(self) -> "StreamSession":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- ingestion ---------------------------------------------------------
    def submit(self, record_ids, values, sign, *, epoch: int = 0,
               timeout: Optional[float] = None) -> None:
        """Push one group of signed delta rows.  Blocks while the ingest
        queue is full (backpressure); raises ``queue.Full`` on timeout."""
        rec = DeltaRecord(record_ids=record_ids, values=values, sign=sign,
                          timestamp=time.time(), epoch=epoch)
        self.submit_record(rec, timeout=timeout)

    def submit_record(self, record: DeltaRecord,
                      timeout: Optional[float] = None) -> None:
        """Validate and enqueue one record; raises ``ValueError`` on record
        ids outside the input mirror (the batch it would have joined — and
        the worker thread — are unaffected)."""
        self._validate_record(record)
        self._inbox.put((record, time.perf_counter()), block=True,
                        timeout=timeout)

    def _validate_record(self, rec: DeltaRecord) -> None:
        rid = np.asarray(rec.record_ids)
        if rid.size == 0:
            return
        lo, hi = int(rid.min()), int(rid.max())
        if lo < 0:
            raise ValueError(
                f"record id {lo} outside the input mirror capacity "
                f"{self._mkeys.shape[0]}; record ids must be >= 0")
        # with grow_records (the default) the mirror grows geometrically on
        # overflow, so only a configured ceiling rejects inserts
        if self.sconfig.grow_records:
            limit = self.sconfig.max_records
        else:
            limit = self._mkeys.shape[0]
        if limit is not None and hi >= limit:
            hint = ("raise StreamConfig(max_records=...)"
                    if self.sconfig.grow_records
                    else "pass StreamConfig(grow_records=True) to stream "
                         "inserts")
            raise ValueError(
                f"record id {hi} outside the input mirror capacity "
                f"{limit}; {hint}")

    def _grow_to(self, needed: int) -> None:
        """Geometric input-mirror growth: extend the mirror (invalid rows)
        and the session driver's record structures to the next power-of-two
        capacity >= ``needed``.  Caller holds ``_lock``."""
        cap = self._mkeys.shape[0]
        if needed <= cap:
            return
        # next power of two >= max(needed, 2*cap): O(log) growth events
        new_cap = next_bucket(max(needed, 2 * cap), 1)
        if self.sconfig.max_records is not None:
            new_cap = min(new_cap, self.sconfig.max_records)
        pad = new_cap - cap
        self._mkeys = np.concatenate(
            [self._mkeys,
             np.zeros((pad,) + self._mkeys.shape[1:], self._mkeys.dtype)])
        self._mvalues = {
            n: np.concatenate([a, np.zeros((pad,) + a.shape[1:], a.dtype)])
            for n, a in self._mvalues.items()}
        self._mvalid = np.concatenate(
            [self._mvalid, np.zeros(pad, bool)])
        self.session.grow_records(new_cap)
        self.grow_events += 1

    def _ingest(self) -> bool:
        """Move rows from the inbox and the source into the pending batch
        (never beyond one batch's budget: the inbox stays bounded and the
        producers blocked — that is the backpressure path)."""
        # not idle while probing: a concurrent drain() must not observe the
        # window where a record left the inbox but isn't pending yet
        self._starved = False
        progressed = False
        budget = self.sconfig.max_batch_records - self._pending_rows
        while budget > 0:
            try:
                rec, arrival = self._inbox.get_nowait()
            except queue_mod.Empty:
                break
            self._pending.append((rec, arrival))
            self._pending_rows += rec.n_rows
            budget -= rec.n_rows
            progressed = True
        if self.source is not None and budget > 0 and \
                not self.source.exhausted:
            now = time.perf_counter()
            for rec in self.source.poll(budget):
                try:
                    self._validate_record(rec)
                except ValueError:
                    # drop the bad record, keep the stream (and the other
                    # records of this poll) alive
                    self.metrics.observe_rejected(rec.n_rows)
                    continue
                self._pending.append((rec, now))
                self._pending_rows += rec.n_rows
                progressed = True
        self._starved = not progressed and not self._pending
        return progressed

    def _should_fire(self) -> bool:
        if not self._pending:
            return False
        if self._flush or self._pending_rows >= self.sconfig.max_batch_records:
            return True
        oldest = self._pending[0][1]
        return (time.perf_counter() - oldest) >= self.sconfig.max_batch_delay

    # -- the refresh step --------------------------------------------------
    def step(self) -> bool:
        """One synchronous scheduling quantum: ingest, then process at most
        one micro-batch.  Returns True if a refresh ran."""
        self._ingest()
        if not self._should_fire():
            return False
        self._process_batch()
        return True

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                if not self.step():
                    time.sleep(self.sconfig.poll_interval)
            except BaseException as e:       # noqa: BLE001 — surfaced via
                self._error = e              # _check_error on drain/result
                return

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError(
                f"stream worker for {self.name!r} died; the failing "
                f"micro-batch was dropped") from self._error

    def prepare_batch(self) -> Optional[PreparedBatch]:
        """Assemble the pending micro-batch into an applied-but-unrefreshed
        unit of work: coalesce, grow + mutate the input mirror (rollback
        state captured), and take the scheduler's refresh decision.

        Caller must hold ``_lock`` and must follow up with exactly one of
        :meth:`commit_batch` (after executing the decision — here or in the
        serving tier's batched cross-tenant launch) or
        :meth:`rollback_batch` (on failure).  Marks the session busy until
        then.  Returns ``None`` when nothing is pending.
        """
        if not self._pending:
            return None
        self._busy = True
        try:
            batch = self._pending
            self._pending = []
            self._pending_rows = 0
            records = [r for r, _ in batch]
            first_arrival = min(a for _, a in batch)
            epoch = max(r.epoch for r in records)
            n_in = sum(r.n_rows for r in records)

            backend = self.session.config.backend
            if self.sconfig.coalesce:
                res = coalesce(records, backend=backend)
            else:
                rids, vals, signs = concat_records(records)
                res = CoalesceResult(make_delta(rids, vals, signs),
                                     n_in, n_in, 0, 0, 0)
            if res.delta is None:              # everything cancelled out
                return PreparedBatch(records, first_arrival, epoch, n_in,
                                     res, None, None, None)
            # mirror mutation must be rollback-able: rerun() consumes the
            # updated mirror, so it cannot simply be deferred until after
            # the refresh succeeds
            rid = np.asarray(res.delta.record_ids)
            dvalid = np.asarray(res.delta.valid)
            if dvalid.any():
                self._grow_to(int(rid[dvalid].max()) + 1)
            rows = np.unique(rid[dvalid])
            saved = (self._mkeys[rows].copy(),
                     {n: a[rows].copy() for n, a in self._mvalues.items()},
                     self._mvalid[rows].copy())
            apply_delta_host(self._mkeys, self._mvalues, self._mvalid,
                             res.delta)
            decision = self.scheduler.decide(
                res.n_out, state_rows=int(self._mvalid.sum()),
                store_file_bytes=self.session.store_bytes(),
                store_live_bytes=self.session.store_live_bytes())
            return PreparedBatch(records, first_arrival, epoch, n_in, res,
                                 rows, saved, decision)
        except BaseException:
            self._busy = False
            raise

    def rollback_batch(self, prep: PreparedBatch) -> None:
        """Put the mirror back after a failed refresh so it keeps matching
        the state the engine actually computed.  (Mirror growth is *not*
        undone — the extra rows are invalid and harmless.)"""
        try:
            if prep.saved is not None:
                skeys, svals, svalid = prep.saved
                self._mkeys[prep.rows] = skeys
                for n, a in self._mvalues.items():
                    a[prep.rows] = svals[n]
                self._mvalid[prep.rows] = svalid
        finally:
            self._busy = False

    def commit_batch(self, prep: PreparedBatch, action: str,
                     refresh_s: float, retraced: bool) -> None:
        """Record a completed refresh (run here or by the serving tier) in
        the scheduler's cost model and the metrics."""
        try:
            if prep.decision is not None and action != "noop":
                self.scheduler.observe(action, prep.res.n_out, refresh_s,
                                       compiled=retraced)
            res = prep.res
            self.metrics.observe_batch(
                n_in=prep.n_in, n_engine=res.n_out, action=action,
                latency_s=time.perf_counter() - prep.first_arrival,
                refresh_s=refresh_s, epoch=prep.epoch, retraced=retraced,
                n_cancelled=res.n_cancelled, n_inserts=res.n_inserts,
                n_deletes=res.n_deletes)
        finally:
            self._busy = False

    def execute_prepared(self, prep: PreparedBatch) -> str:
        """Run a prepared batch's scheduled refresh on this session's own
        engine — the per-tenant path (the serving tier's batched path runs
        the engine itself and calls commit/rollback directly).  Caller
        holds ``_lock``.  Returns the action taken."""
        if prep.decision is None:
            self.commit_batch(prep, "noop", 0.0, False)
            return "noop"
        # a bumped trace generation marks this batch's wall-clock as
        # compile-tainted
        gen0 = jitcache.generation()
        try:
            if prep.decision.action == "update":
                rep = self.session.update(prep.res.delta)
            else:
                rep = self.session.rerun(self._mirror_kv())
        except BaseException:
            self.rollback_batch(prep)
            raise
        # surface the coalescer's savings on the epoch's RunReport so the
        # session history (the scheduler's raw material) carries them
        rep.coalesce = {
            "n_in": prep.res.n_in, "n_out": prep.res.n_out,
            "n_records": prep.res.n_records,
            "n_inserts": prep.res.n_inserts,
            "n_deletes": prep.res.n_deletes,
            "n_cancelled": prep.res.n_cancelled}
        retraced = jitcache.generation() != gen0
        self.commit_batch(prep, prep.decision.action, rep.seconds, retraced)
        return prep.decision.action

    def _process_batch(self) -> None:
        with self._lock:
            prep = self.prepare_batch()
            if prep is not None:
                self.execute_prepared(prep)

    # -- synchronization ---------------------------------------------------
    @property
    def idle(self) -> bool:
        """No buffered input, no batch in flight, nothing the source can
        offer right now."""
        return (self._inbox.empty() and not self._pending
                and not self._busy and self._starved)

    def drain(self, timeout: float = 60.0) -> None:
        """Block until every available delta row is reflected in
        ``result`` (flushes partial micro-batches immediately)."""
        deadline = time.perf_counter() + timeout
        self._flush = True
        try:
            while True:
                self._check_error()
                if self._thread is None and not self._managed:
                    self.step()              # sync mode: we are the consumer
                if self.idle:
                    return
                if time.perf_counter() > deadline:
                    raise TimeoutError(
                        f"drain() exceeded {timeout}s "
                        f"(inbox={self._inbox.qsize()}, "
                        f"pending={self._pending_rows} rows)")
                if self._thread is not None or self._managed:
                    time.sleep(min(self.sconfig.poll_interval, 0.005))
        finally:
            self._flush = False

    # -- outputs -----------------------------------------------------------
    @property
    def result(self) -> Dict[str, np.ndarray]:
        self._check_error()
        with self._lock:
            return self.session.result

    def report(self, **kw):
        with self._lock:
            return self.session.report(**kw)

    def _mirror_kv(self) -> KV:
        return KV(jnp.asarray(self._mkeys),
                  {n: jnp.asarray(a) for n, a in self._mvalues.items()},
                  jnp.asarray(self._mvalid))

    def mirror_kv(self) -> KV:
        """The fully-updated input as of the last processed batch — what a
        cold ``run()`` would consume to reproduce ``result``."""
        with self._lock:
            return self._mirror_kv()

    def snapshot(self, path: Optional[str] = None) -> Path:
        """Checkpoint the session plus the stream watermark; a replayable
        source can ``rewind(watermark)`` after restore and re-drain."""
        with self._lock:
            out = self.session.checkpoint(path)
            root = Path(path or self.session.config.checkpoint_dir)
            (root / "stream.json").write_text(json.dumps(
                {"watermark": self.metrics.last_epoch,
                 "epoch": self.session.epoch, "name": self.name}))
        return out

    def compact_store(self) -> int:
        """Reclaim obsolete MRBG bytes (the server's budget lever)."""
        with self._lock:
            reclaimed = self.session.compact_store()
        if reclaimed:
            self.metrics.observe_compaction(reclaimed)
        return reclaimed

    def store_bytes(self) -> int:
        with self._lock:
            return self.session.store_bytes()
