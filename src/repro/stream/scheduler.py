"""Refresh scheduling: incremental ``update()`` vs full ``rerun()``.

The paper's Fig. 8 shows the crossover offline: fine-grain incremental
refresh wins while |Δ|/|D| is small and loses to plain recomputation once
the delta grows past a workload-dependent ratio.  A serving layer has to
take that decision *online*, per micro-batch.  Three policies:

  * ``paper``      — the static crossover: rerun iff the delta-to-state
    ratio exceeds ``StreamConfig.crossover``.  Deterministic and
    reproduces the paper's offline choice; the baseline the other two are
    judged against.
  * ``latency``    — minimize this batch's wall-clock: EWMA cost models of
    both paths (seconds-per-delta-row for update, seconds-per-rerun from
    the Session's RunReport history) are compared and the cheaper path
    taken; until both paths have been observed the crossover prior
    decides.
  * ``throughput`` — like ``latency``, but additionally forces a rerun
    when the MRBG file has bloated past ``store_bloat`` x live bytes:
    a rerun rebuilds the store from scratch (free compaction), trading one
    slow batch for sustained refresh speed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.api.config import StreamConfig

ACTIONS = ("update", "rerun")


@dataclass(frozen=True)
class RefreshDecision:
    action: str                  # "update" | "rerun"
    reason: str                  # human-readable justification
    delta_ratio: float           # |Δ| rows / |D| live records
    est_update: Optional[float]  # predicted seconds (None: no model yet)
    est_rerun: Optional[float]


class RefreshScheduler:
    """Online cost-model refresh policy for one session."""

    MAX_DECISIONS = 256          # kept decision tail (counters are exact)

    def __init__(self, config: Optional[StreamConfig] = None):
        self.config = config or StreamConfig()
        self._sec_per_delta_row: Optional[float] = None   # EWMA, update path
        self._sec_per_rerun: Optional[float] = None       # EWMA, rerun path
        self.decisions: List[RefreshDecision] = []        # bounded tail
        self.action_counts = {a: 0 for a in ACTIONS}
        self.compile_skips = 0       # observations excluded (compile-tainted)

    # -- cost model --------------------------------------------------------
    def _ewma(self, old: Optional[float], new: float) -> float:
        a = self.config.cost_ema
        return new if old is None else (1 - a) * old + a * new

    def seed(self, initial_run_seconds: float) -> None:
        """The initial ``run()`` is the first observation of rerun cost."""
        self._sec_per_rerun = self._ewma(self._sec_per_rerun,
                                         initial_run_seconds)

    def observe(self, action: str, n_delta_rows: int,
                seconds: float, *, compiled: bool = False) -> None:
        """Fold one measured refresh into the model.

        ``compiled=True`` marks an observation whose wall-clock includes
        trace + XLA compile time (a cold shape bucket).  Folding such a
        one-off into the EWMA would make the touched path look orders of
        magnitude slower than its steady state and skew update-vs-rerun
        decisions for many batches; it is excluded instead (counted in
        ``compile_skips``).
        """
        if compiled:
            self.compile_skips += 1
            return
        if action == "rerun":
            self._sec_per_rerun = self._ewma(self._sec_per_rerun, seconds)
        elif n_delta_rows > 0:
            self._sec_per_delta_row = self._ewma(
                self._sec_per_delta_row, seconds / n_delta_rows)

    def estimates(self, n_delta_rows: int):
        est_u = (None if self._sec_per_delta_row is None
                 else self._sec_per_delta_row * n_delta_rows)
        return est_u, self._sec_per_rerun

    # -- the decision ------------------------------------------------------
    def decide(self, n_delta_rows: int, state_rows: int,
               store_file_bytes: int = 0,
               store_live_bytes: int = 0) -> RefreshDecision:
        cfg = self.config
        ratio = n_delta_rows / max(state_rows, 1)
        est_u, est_r = self.estimates(n_delta_rows)

        def done(action, reason):
            d = RefreshDecision(action, reason, ratio, est_u, est_r)
            self.decisions.append(d)
            if len(self.decisions) > self.MAX_DECISIONS:
                del self.decisions[:-self.MAX_DECISIONS]
            self.action_counts[action] += 1
            return d

        if cfg.policy == "paper":
            if ratio >= cfg.crossover:
                return done("rerun", f"delta ratio {ratio:.3f} >= "
                                     f"crossover {cfg.crossover} (Fig. 8)")
            return done("update", f"delta ratio {ratio:.3f} < "
                                  f"crossover {cfg.crossover}")

        if cfg.policy == "throughput" and store_live_bytes > 0 and \
                store_file_bytes > cfg.store_bloat * store_live_bytes:
            return done("rerun",
                        f"store bloat {store_file_bytes}B > "
                        f"{cfg.store_bloat:g}x live {store_live_bytes}B "
                        f"(rerun rebuilds the MRBG file)")

        # latency (and throughput when not bloated): cheapest predicted path
        if est_u is not None and est_r is not None:
            if est_u <= est_r:
                return done("update", f"predicted {est_u * 1e3:.2f}ms <= "
                                      f"rerun {est_r * 1e3:.2f}ms")
            return done("rerun", f"predicted update {est_u * 1e3:.2f}ms > "
                                 f"rerun {est_r * 1e3:.2f}ms")
        # cold model: fall back to the crossover prior
        if ratio >= cfg.crossover:
            return done("rerun", f"cold cost model; delta ratio "
                                 f"{ratio:.3f} >= crossover prior")
        return done("update", f"cold cost model; delta ratio {ratio:.3f} "
                              f"< crossover prior")
