"""repro.stream — continuous-ingestion serving layer over ``repro.api``.

The paper's premise is that "new data and updates are constantly arriving";
the engine below this package refreshes a preserved job against one delta
at a time.  This layer closes the loop:

  * :mod:`repro.stream.source`    — ``DeltaSource``: timestamped signed
    delta records with epoch watermarks (in-memory queue, replayable JSONL
    tail, synthetic generator).
  * :mod:`repro.stream.coalesce`  — micro-batch coalescer: merges/cancels
    opposing +/- rows per record *before* the engine sees them (the sort
    and segment-sum ride ``repro.kernels.ops``, so the hot path follows
    the backend dispatcher).
  * :mod:`repro.stream.scheduler` — cost-model-driven choice between the
    fine-grain incremental ``update()`` and full ``rerun()`` re-computation
    per micro-batch (the paper's Fig. 8 crossover as an online policy).
  * :mod:`repro.stream.session`   — ``StreamSession``: async driver with a
    bounded ingest queue (backpressure), ``drain``/``stop``/``snapshot``.
  * :mod:`repro.stream.server`    — ``MultiSessionServer``: many tenant
    StreamSessions time-sliced over one process under a shared MRBG-store
    byte budget.
  * :mod:`repro.stream.metrics`   — per-tenant counters, sustained
    updates/sec, refresh-latency percentiles.

    from repro.stream import StreamSession
    from repro.apps import pagerank as pr

    spec, data, source = pr.make_stream(nbrs, frac=0.02, epochs=10)
    with StreamSession(spec, data, source=source) as ss:
        ss.drain()
    ss.result["r"]                       # == cold run on the final input
"""
from repro.api.config import STREAM_POLICIES, StreamConfig
from repro.stream.coalesce import CoalesceResult, coalesce, coalesce_rows
from repro.stream.metrics import StreamMetrics
from repro.stream.scheduler import RefreshDecision, RefreshScheduler
from repro.stream.session import PreparedBatch, StreamSession
from repro.stream.source import (
    DeltaRecord, DeltaSource, FileTailSource, QueueSource, SyntheticSource,
)

__all__ = [
    "StreamConfig", "STREAM_POLICIES",
    "DeltaRecord", "DeltaSource", "QueueSource", "FileTailSource",
    "SyntheticSource",
    "CoalesceResult", "coalesce", "coalesce_rows",
    "RefreshScheduler", "RefreshDecision",
    "StreamSession", "PreparedBatch", "MultiSessionServer",
    "StreamMetrics",
]


def __getattr__(name):
    # lazy: repro.stream.server shims onto repro.serve, which itself
    # imports repro.stream.session — a cycle if resolved at package init
    if name == "MultiSessionServer":
        from repro.stream.server import MultiSessionServer
        return MultiSessionServer
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
