"""MultiSessionServer: many tenant StreamSessions in one process.

One scheduler thread round-robins the tenants' :meth:`StreamSession.step`
quanta — the serving analogue of the paper's shared MapReduce cluster:
every tenant keeps its own preserved job (Session, MRBG store, mirror),
nothing is shared but compute and the host-memory byte budget.

The budget covers the sum of all tenants' MRBG files ("local disk" in the
paper's deployment).  When a sweep ends over budget the server compacts
stores in obsolete-bytes order — reclaiming superseded chunk versions —
until the total fits (or nothing reclaimable remains, which is reported
in ``stats()`` as ``over_budget``).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

from repro.kernels import jitcache
from repro.stream.session import StreamSession


class MultiSessionServer:
    """Time-slice tenant stream sessions over one engine process."""

    def __init__(self, store_budget_bytes: Optional[int] = None,
                 poll_interval: float = 0.002):
        self.store_budget_bytes = store_budget_bytes
        self.poll_interval = poll_interval
        self.tenants: Dict[str, StreamSession] = {}
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._over_budget = False
        self._sweeps = 0
        self._error: Optional[BaseException] = None

    # -- tenancy -----------------------------------------------------------
    def add(self, tenant: StreamSession) -> StreamSession:
        """Register a tenant; the server owns its scheduling from now on
        (the tenant must not run its own worker thread).

        Admission runs the tenant's initial job — and, when its
        ``StreamConfig(prewarm=True)``, compiles its delta bucket ladder —
        before the tenant enters the sweep, so a newly added tenant never
        pays cold-compile latency out of the shared scheduler thread's
        first quantum.
        """
        if tenant.name in self.tenants:
            raise ValueError(f"tenant {tenant.name!r} already registered")
        if tenant._thread is not None:
            raise ValueError(f"tenant {tenant.name!r} already runs its own "
                             f"worker; construct it unstarted")
        tenant.start(background=False)     # initial run, no thread
        tenant._managed = True             # this thread is its consumer now
        self.tenants[tenant.name] = tenant
        return tenant

    def __getitem__(self, name: str) -> StreamSession:
        return self.tenants[name]

    # -- scheduling --------------------------------------------------------
    def start(self) -> "MultiSessionServer":
        if self._thread is None:
            self._stop_evt.clear()
            self._thread = threading.Thread(target=self._loop,
                                            name="stream-server", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    def __enter__(self) -> "MultiSessionServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def sweep(self) -> bool:
        """One round-robin pass: a step() quantum per tenant, then budget
        enforcement.  Returns True if any tenant refreshed."""
        progressed = False
        for tenant in list(self.tenants.values()):
            progressed |= tenant.step()
        self._enforce_budget()
        self._sweeps += 1
        return progressed

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                if not self.sweep():
                    time.sleep(self.poll_interval)
            except BaseException as e:       # noqa: BLE001 — surfaced via
                self._error = e              # _check_error on drain
                return

    def _check_error(self) -> None:
        if self._error is not None:
            raise RuntimeError("stream server scheduler thread died; the "
                               "failing micro-batch was dropped"
                               ) from self._error

    # -- shared store budget ----------------------------------------------
    def total_store_bytes(self) -> int:
        return sum(t.store_bytes() for t in self.tenants.values())

    def _enforce_budget(self) -> None:
        if self.store_budget_bytes is None:
            return
        total = self.total_store_bytes()
        if total <= self.store_budget_bytes:
            self._over_budget = False
            return
        # compact fattest-obsolete first until the total fits
        order = sorted(
            self.tenants.values(),
            key=lambda t: t.session.store_obsolete_bytes(),
            reverse=True)
        for tenant in order:
            if total <= self.store_budget_bytes:
                break
            total -= tenant.compact_store()
        self._over_budget = total > self.store_budget_bytes

    # -- synchronization / outputs ----------------------------------------
    def drain(self, timeout: float = 60.0) -> None:
        """Flush and process everything buffered in every tenant."""
        deadline = time.perf_counter() + timeout
        for t in self.tenants.values():
            t._flush = True
        try:
            while True:
                self._check_error()
                if self._thread is None:
                    self.sweep()
                if all(t.idle for t in self.tenants.values()):
                    return
                if time.perf_counter() > deadline:
                    lag = {n: t._pending_rows + t._inbox.qsize()
                           for n, t in self.tenants.items() if not t.idle}
                    raise TimeoutError(f"server drain exceeded {timeout}s; "
                                       f"lagging tenants: {lag}")
                if self._thread is not None:
                    time.sleep(self.poll_interval)
        finally:
            for t in self.tenants.values():
                t._flush = False

    def stats(self) -> Dict[str, object]:
        tenants = {n: t.metrics.snapshot() for n, t in self.tenants.items()}
        return {
            "tenants": tenants,
            "total_store_bytes": self.total_store_bytes(),
            "store_budget_bytes": self.store_budget_bytes,
            "over_budget": self._over_budget,
            "sweeps": self._sweeps,
            # process-wide latency-tail telemetry (shared jit caches)
            "retrace_batches": sum(t["retrace_batches"]
                                   for t in tenants.values()),
            "rows_rejected": sum(t["rows_rejected"]
                                 for t in tenants.values()),
            "jit": jitcache.snapshot(),
        }
