"""MultiSessionServer — deprecated shim over :class:`repro.serve.ServeTier`.

The round-robin multi-tenant server grew into a real serving tier with
SLO classes, admission control, batched cross-tenant refresh, and
cold-store spill; that code now lives in :mod:`repro.serve`.  This class
keeps the old name and behavior (plain FIFO sweeps, per-tenant refresh,
no spill) alive for one release so existing callers migrate on their own
schedule:

    server = MultiSessionServer(...)       # before
    tier   = repro.serve.ServeTier(...)    # after (adds slo=/group= etc.)
"""
from __future__ import annotations

import warnings
from typing import Optional

from repro.serve.tier import ServeTier


class MultiSessionServer(ServeTier):
    """Deprecated: use :class:`repro.serve.ServeTier`."""

    def __init__(self, store_budget_bytes: Optional[int] = None,
                 poll_interval: float = 0.002):
        warnings.warn(
            "MultiSessionServer is deprecated; use repro.serve.ServeTier "
            "(adds SLO classes, admission control, batched cross-tenant "
            "refresh, and cold-store spill)",
            DeprecationWarning, stacklevel=2)
        super().__init__(store_budget_bytes=store_budget_bytes,
                         poll_interval=poll_interval,
                         batch_refresh=False)
