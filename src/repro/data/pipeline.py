"""Data pipeline: deterministic sharded token streams + delta-input streams.

Two consumers:

  * the LM stack: ``lm_batches`` yields {"inputs","targets","mask"} batches.
    Tokens are generated *hash-deterministically* per (stream, position), so
    any data shard can materialize exactly its slice without coordination —
    the property that makes the pipeline restartable and elastic (a restarted
    or re-sharded job regenerates byte-identical data from the step counter
    alone).  A file-backed mode memory-maps a token bin for real corpora.

  * the MapReduce engine: ``DeltaStream`` produces the paper's signed delta
    inputs from an evolving dataset (graph edits / new documents per epoch).
"""
from __future__ import annotations

import threading
import queue as queue_mod
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

_MUL = np.uint64(0x9E3779B97F4A7C15)


def _mix(x: np.ndarray) -> np.ndarray:
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


def synthetic_tokens(start: int, count: int, vocab: int,
                     seed: int = 0) -> np.ndarray:
    """Deterministic pseudo-corpus: token[i] = mix(i, seed) % vocab, with
    mild bigram structure so losses are learnable."""
    idx = np.arange(start, start + count, dtype=np.uint64)
    h = _mix(idx * _MUL + np.uint64(seed))
    toks = (h % np.uint64(max(vocab - 2, 1))).astype(np.int64)
    # inject structure: every 4th token repeats the previous one
    rep = (idx % np.uint64(4)) == np.uint64(3)
    toks = np.where(rep, np.roll(toks, 1), toks)
    return toks.astype(np.int32)


@dataclass
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    bin_path: Optional[str] = None     # file-backed corpus (int32 bin)
    mask_prob: float = 0.0             # >0: masked-LM batches (hubert-style)


def _tokens_at(cfg: LMDataConfig, start: int, count: int) -> np.ndarray:
    if cfg.bin_path:
        data = np.memmap(cfg.bin_path, dtype=np.int32, mode="r")
        idx = (np.arange(start, start + count) % data.shape[0])
        return np.asarray(data[idx])
    return synthetic_tokens(start, count, cfg.vocab, cfg.seed)


def lm_batch_at_step(cfg: LMDataConfig, step: int) -> Dict[str, np.ndarray]:
    """Materialize the full global batch for ``step`` (deterministic)."""
    n = cfg.global_batch * (cfg.seq_len + 1)
    flat = _tokens_at(cfg, step * n, n).reshape(cfg.global_batch,
                                                cfg.seq_len + 1)
    inputs = flat[:, :-1]
    targets = flat[:, 1:]
    mask = np.ones_like(targets, bool)
    if cfg.mask_prob > 0:
        rng = np.random.default_rng(cfg.seed * 100003 + step)
        mask = rng.random(targets.shape) < cfg.mask_prob
    return {"inputs": np.ascontiguousarray(inputs),
            "targets": np.ascontiguousarray(targets), "mask": mask}


def lm_batches(cfg: LMDataConfig, start_step: int = 0,
               prefetch: int = 2) -> Iterator[Dict[str, np.ndarray]]:
    """Host prefetch iterator (background thread keeps ``prefetch`` batches
    ready while the device step runs)."""
    q: queue_mod.Queue = queue_mod.Queue(maxsize=prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(lm_batch_at_step(cfg, step), timeout=0.5)
                step += 1
            except queue_mod.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()


class DeltaStream:
    """Evolving-dataset generator for the MapReduce engine.

    Each epoch mutates ``frac`` of the records; ``delta(epoch)`` returns the
    paper-format signed delta ('-' old row, '+' new row) and updates the
    mirror.
    """

    def __init__(self, values: Dict[str, np.ndarray], frac: float = 0.1,
                 seed: int = 0, mutator=None):
        self.values = {k: v.copy() for k, v in values.items()}
        self.frac = frac
        self.seed = seed
        self.epoch = 0
        self.mutator = mutator

    def delta(self):
        rng = np.random.default_rng(self.seed * 7919 + self.epoch)
        n = next(iter(self.values.values())).shape[0]
        k = max(1, int(n * self.frac))
        rows = np.sort(rng.choice(n, k, replace=False)).astype(np.int32)
        old = {nm: a[rows].copy() for nm, a in self.values.items()}
        if self.mutator is not None:
            new = self.mutator(rng, rows, old)
        else:
            new = {nm: rng.permutation(a) for nm, a in old.items()}
        for nm in self.values:
            self.values[nm][rows] = new[nm]
        self.epoch += 1

        record_ids = np.repeat(rows, 2)
        sign = np.tile(np.array([-1, 1], np.int8), k)
        vals = {}
        for nm in old:
            buf = np.empty((2 * k,) + old[nm].shape[1:], old[nm].dtype)
            buf[0::2] = old[nm]
            buf[1::2] = new[nm]
            vals[nm] = buf
        return record_ids, vals, sign
