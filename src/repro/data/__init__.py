from repro.data.pipeline import (  # noqa
    DeltaStream, LMDataConfig, lm_batch_at_step, lm_batches, synthetic_tokens,
)
