"""Model / mesh / sharding configuration for the LM stack.

A ``ModelConfig`` fully describes one of the assigned architectures; the
layer stack is a cycled ``block_pattern`` (scanned as stacked super-blocks to
keep the HLO compact), with optional unrolled prefix layers (e.g.
DeepSeek-V3's first-3-dense).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0            # shared (always-on) experts
    d_ff_shared: int = 0
    # mesh axes the expert dimension is sharded over ("model",) or
    # ("data", "model") -- the latter gives 256-way EP for DeepSeek-V3
    ep_axes: Tuple[str, ...] = ("model",)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block."""
    d_rnn: int = 2560
    conv_width: int = 4
    block_width: int = 2560        # lru gate width


@dataclass(frozen=True)
class ShardingRules:
    """Logical tensor axes -> mesh axis names (None = replicated).

    The hillclimb lever: every rule change re-lowers into a different
    collective schedule.
    """
    batch: Tuple[str, ...] = ("pod", "data")
    seq: Optional[str] = None               # sequence parallelism if set
    heads: Optional[str] = "model"          # attention heads (q)
    kv_heads: Optional[str] = "model"
    d_model: Optional[str] = None           # residual stream
    d_ff: Optional[str] = "model"
    vocab: Optional[str] = "model"
    expert: Tuple[str, ...] = ("model",)
    kv_seq: Optional[str] = None            # decode KV-cache sequence dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | xlstm | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    d_ff_dense: int = 0            # dense-FFN width for mixed MoE stacks
    block_pattern: Tuple[str, ...] = ("attn_dense",)
    prefix_blocks: Tuple[str, ...] = ()     # unrolled layers before the scan
    causal: bool = True                     # False for encoder-only (hubert)
    tie_embeddings: bool = False
    # attention options
    qk_norm: bool = False                   # qwen3 / chameleon
    ffn_kind: str = "swiglu"                # swiglu | geglu | gelu
    attn_softcap: float = 0.0               # gemma2
    logit_softcap: float = 0.0              # gemma2
    local_window: int = 4096                # for "attn_local" blocks
    rope_theta: float = 10000.0
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    rglru: Optional[RGLRUConfig] = None
    mtp: bool = False                       # DeepSeek multi-token prediction
    # frontend stub: inputs are precomputed embeddings [B, T, d_model]
    embed_inputs: bool = True               # False => frontend-embedded input
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    opt_dtype: str = "float32"
    remat: str = "full"                     # full | dots | none
    scan_layers: bool = True                # False => unrolled (cost probes)
    moe_impl: str = "gather"                # gather | a2a (shard_map shuffle)
    attn_impl: str = "dense"                # dense | blockwise (flash-style)
    attn_block: int = 1024                  # kv block for blockwise attention
    loss_chunk: int = 0                     # 0 = unchunked cross-entropy
    norm_eps: float = 1e-6
    post_norms: bool = False                # gemma2 pre+post norms
    sharding: ShardingRules = field(default_factory=ShardingRules)

    # ---- derived -------------------------------------------------------
    @property
    def n_rep(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def cycles(self) -> int:
        body = self.n_layers - len(self.prefix_blocks)
        return body // len(self.block_pattern)

    @property
    def remainder_blocks(self) -> Tuple[str, ...]:
        body = self.n_layers - len(self.prefix_blocks)
        rem = body % len(self.block_pattern)
        return tuple(self.block_pattern[:rem])

    def dtype(self, which: str):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                "float16": jnp.float16}[getattr(self, which + "_dtype")]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(len(cfg.block_pattern) + len(cfg.prefix_blocks), 2),
        d_model=64, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) or 1,
        d_ff=128, vocab=256, head_dim=16, local_window=32,
        d_ff_dense=128 if cfg.d_ff_dense else 0,
        remat="none",
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=64, d_ff_shared=64 if cfg.moe.num_shared else 0,
            ep_axes=("model",))
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                              qk_nope_head_dim=16, qk_rope_head_dim=8,
                              v_head_dim=16)
    if cfg.rglru is not None:
        kw["rglru"] = RGLRUConfig(d_rnn=64, conv_width=4, block_width=64)
    return cfg.replace(**kw)
