"""Transformer / recurrent block zoo covering the 10 assigned architectures.

Every block kind provides:
  * ``plan_<kind>(cfg)``   -> ParamSpec tree
  * ``apply_<kind>(cfg, p, x, pos, cache)`` -> (y, new_cache)

``cache=None`` means train/prefill over the full sequence; a cache dict means
single-token decode.  ``pos`` is [B, S] token positions (decode: the current
position broadcast).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import (
    ParamSpec, apply_rope, constrain, rms_norm, rope_table, softcap, swiglu,
)
from repro.models.config import ModelConfig

NEG = -2.0e38


# ---------------------------------------------------------------------------
# Attention (GQA, local windows, softcap, qk-norm) and MLA
# ---------------------------------------------------------------------------

def plan_attention(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "norm": ParamSpec((d,), ("d_model",), "zeros"),
        "wq": ParamSpec((d, h, hd), ("d_model", "heads", None)),
        "wk": ParamSpec((d, k, hd), ("d_model", "kv_heads", None)),
        "wv": ParamSpec((d, k, hd), ("d_model", "kv_heads", None)),
        "wo": ParamSpec((h, hd, d), ("heads", None, "d_model")),
    }
    if cfg.qk_norm:
        p["q_scale"] = ParamSpec((hd,), (None,), "zeros")
        p["k_scale"] = ParamSpec((hd,), (None,), "zeros")
    if cfg.post_norms:
        p["post_norm"] = ParamSpec((d,), ("d_model",), "zeros")
    return p


def _attend(cfg: ModelConfig, q, k, v, q_pos, k_pos, window: int = 0):
    """q [B,S,H,hd], k/v [B,T,K,hd]; positions give the causal/local mask."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    rep = h // kh
    q = q.reshape(b, s, kh, rep, hd)
    scores = jnp.einsum("bskrd,btkd->bkrst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    scores = softcap(scores, cfg.attn_softcap)
    # constrain on the *full* head axis (kh*rep) so GSPMD shards heads evenly
    from repro.models.config import ModelConfig as _MC  # noqa
    scores = scores.reshape(b, h, s, t)
    from repro.models.common import constrain as _constrain
    scores = _constrain(scores, cfg.sharding, ("batch", "heads", None, None))
    mask = k_pos[:, None, :] <= q_pos[:, :, None] if cfg.causal else \
        jnp.ones((b, s, t), bool)
    if window > 0:
        mask &= (q_pos[:, :, None] - k_pos[:, None, :]) < window
    mask &= (k_pos >= 0)[:, None, :]
    scores = jnp.where(mask[:, None, :, :], scores, NEG)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    probs = probs.reshape(b, kh, rep, s, t)
    out = jnp.einsum("bkrst,btkd->bskrd", probs, v)
    return out.reshape(b, s, h, v.shape[-1])   # v head dim may differ (MLA)


def _attend_blockwise(cfg: ModelConfig, q, k, v, q_pos, k_pos,
                      window: int = 0):
    """Flash-style streaming-softmax attention (scan over KV blocks).

    Algorithmically identical to the Pallas flash kernel in
    ``repro.kernels.flash_attention`` — this is its XLA lowering for
    dry-runs/CPU; it never materializes the [S, T] score matrix.
    """
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    rep = h // kh
    blk = min(cfg.attn_block, t)
    if t % blk != 0:
        return _attend(cfg, q, k, v, q_pos, k_pos, window)
    nb = t // blk
    qf = q.reshape(b, s, kh, rep, hd).astype(jnp.float32)

    kb = k.reshape(b, nb, blk, kh, hd).swapaxes(0, 1)
    vb = v.reshape(b, nb, blk, kh, vd).swapaxes(0, 1)
    kpb = k_pos.reshape(b, nb, blk).swapaxes(0, 1)

    def step(carry, inp):
        m, l, acc = carry
        kblk, vblk, kp = inp
        sc = jnp.einsum("bskrd,btkd->bkrst", qf, kblk.astype(jnp.float32))
        sc = sc / (hd ** 0.5)
        sc = softcap(sc, cfg.attn_softcap)
        mask = kp[:, None, :] <= q_pos[:, :, None] if cfg.causal else \
            jnp.ones((b, s, blk), bool)
        if window > 0:
            mask &= (q_pos[:, :, None] - kp[:, None, :]) < window
        mask &= (kp >= 0)[:, None, :]
        sc = jnp.where(mask[:, None, None, :, :], sc, NEG)
        mb = jnp.maximum(m, sc.max(axis=-1))
        corr = jnp.exp(m - mb)
        pexp = jnp.exp(sc - mb[..., None])
        l2 = l * corr + pexp.sum(axis=-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "bkrst,btkd->bkrsd", pexp, vblk.astype(jnp.float32))
        return (mb, l2, acc2), None

    m0 = jnp.full((b, kh, rep, s), -jnp.inf)
    l0 = jnp.zeros((b, kh, rep, s))
    a0 = jnp.zeros((b, kh, rep, s, vd))
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, kpb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, vd)
    return out.astype(v.dtype)


def attend(cfg: ModelConfig, q, k, v, q_pos, k_pos, window: int = 0):
    if cfg.attn_impl == "blockwise" and q.shape[1] > 1:
        return _attend_blockwise(cfg, q, k, v, q_pos, k_pos, window)
    return _attend(cfg, q, k, v, q_pos, k_pos, window)


def apply_attention(cfg: ModelConfig, p, x, pos, cache=None, *,
                    window: int = 0):
    """Standard GQA attention; ``window>0`` = sliding-window (local)."""
    rules = cfg.sharding
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"].astype(xn.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"].astype(xn.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"].astype(xn.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_scale"], cfg.norm_eps)
    sin, cos = rope_table(pos, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    q = constrain(q, rules, ("batch", "seq", "heads", None))

    if cache is None:
        out = attend(cfg, q, k, v, pos, pos, window)
        new_cache = None
    else:
        ck, cv = cache["k"], cache["v"]
        cpos = pos.reshape(-1)[0]
        tmax = ck.shape[1]
        slot = jnp.mod(cpos, tmax) if window > 0 else cpos
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, slot, 0, 0))
        idx = jnp.arange(tmax)
        if window > 0:    # rotating window buffer: slot idx holds position
            age = jnp.mod(cpos - idx, tmax)      # cpos - age, if written yet
            k_pos = jnp.where(age <= cpos, cpos - age, -1)
        else:
            k_pos = jnp.where(idx <= cpos, idx, -1)
        b = x.shape[0]
        k_pos_b = jnp.broadcast_to(k_pos[None, :], (b, tmax))
        q_pos = jnp.broadcast_to(cpos[None, None], (b, 1))
        out = _attend(cfg, q, ck.astype(q.dtype), cv.astype(q.dtype),
                      q_pos, k_pos_b, window)
        new_cache = {"k": ck, "v": cv}
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    if cfg.post_norms:
        y = rms_norm(y, p["post_norm"], cfg.norm_eps)
    return x + y, new_cache


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    window: int = 0):
    t = min(window, max_len) if window > 0 else max_len
    k, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": ParamSpec((batch, t, k, hd), ("batch", "kv_seq", "kv_heads",
                                           None), "zeros"),
        "v": ParamSpec((batch, t, k, hd), ("batch", "kv_seq", "kv_heads",
                                           None), "zeros"),
    }


# ----------------------------- MLA (DeepSeek-V3) ---------------------------

def plan_mla(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "norm": ParamSpec((d,), ("d_model",), "zeros"),
        "wq_a": ParamSpec((d, m.q_lora_rank), ("d_model", None)),
        "q_norm": ParamSpec((m.q_lora_rank,), (None,), "zeros"),
        "wq_b": ParamSpec((m.q_lora_rank, h, qk), (None, "heads", None)),
        "wkv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("d_model", None)),
        "kv_norm": ParamSpec((m.kv_lora_rank,), (None,), "zeros"),
        "wk_b": ParamSpec((m.kv_lora_rank, h, m.qk_nope_head_dim),
                          (None, "heads", None)),
        "wv_b": ParamSpec((m.kv_lora_rank, h, m.v_head_dim),
                          (None, "heads", None)),
        "wo": ParamSpec((h, m.v_head_dim, d), ("heads", None, "d_model")),
    }


def apply_mla(cfg: ModelConfig, p, x, pos, cache=None):
    m = cfg.mla
    rules = cfg.sharding
    nope, rope_d, vd = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    cq = rms_norm(xn @ p["wq_a"].astype(xn.dtype), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"].astype(cq.dtype))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    ckv = xn @ p["wkv_a"].astype(xn.dtype)
    latent = rms_norm(ckv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = ckv[..., m.kv_lora_rank:][:, :, None, :]     # [B,S,1,rope]
    sin, cos = rope_table(pos, rope_d, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope, sin, cos)

    if cache is None:
        # training: expand latent to per-head K/V (MXU-friendly)
        k_nope = jnp.einsum("bsr,rhk->bshk", latent,
                            p["wk_b"].astype(latent.dtype))
        v = jnp.einsum("bsr,rhv->bshv", latent, p["wv_b"].astype(latent.dtype))
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, k_nope.shape[:3] + (rope_d,))],
            axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = attend(cfg, qfull, k, v, pos, pos)
        new_cache = None
    else:
        # decode: *absorbed* attention in latent space — the KV cache holds
        # only (latent, k_rope): the MLA memory saving, per DeepSeek-V3.
        clat, crope = cache["latent"], cache["k_rope"]
        cpos = pos.reshape(-1)[0]
        clat = jax.lax.dynamic_update_slice(
            clat, latent.astype(clat.dtype), (0, cpos, 0))
        crope = jax.lax.dynamic_update_slice(
            crope, k_rope[:, :, 0, :].astype(crope.dtype), (0, cpos, 0))
        q_lat = jnp.einsum("bshk,rhk->bshr", q_nope,
                           p["wk_b"].astype(q_nope.dtype))
        s1 = jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                        clat.astype(jnp.float32))
        s2 = jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                        crope.astype(jnp.float32))
        scores = (s1 + s2) / ((nope + rope_d) ** 0.5)
        tmax = clat.shape[1]
        k_pos = jnp.arange(tmax)[None, None, None, :]
        scores = jnp.where(k_pos <= cpos, scores, NEG)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", probs,
                         clat.astype(jnp.float32)).astype(x.dtype)
        out = jnp.einsum("bshr,rhv->bshv", ctx, p["wv_b"].astype(x.dtype))
        new_cache = {"latent": clat, "k_rope": crope}
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"].astype(out.dtype))
    return x + y, new_cache


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int):
    m = cfg.mla
    return {
        "latent": ParamSpec((batch, max_len, m.kv_lora_rank),
                            ("batch", "kv_seq", None), "zeros"),
        "k_rope": ParamSpec((batch, max_len, m.qk_rope_head_dim),
                            ("batch", "kv_seq", None), "zeros"),
    }


# ---------------------------------------------------------------------------
# FFN: dense (swiglu / geglu / gelu) and MoE
# ---------------------------------------------------------------------------

def plan_ffn(cfg: ModelConfig, d_ff: Optional[int] = None,
             kind: str = "swiglu") -> Dict[str, ParamSpec]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    p = {"norm": ParamSpec((d,), ("d_model",), "zeros")}
    if kind == "gelu":
        p["w_in"] = ParamSpec((d, ff), ("d_model", "d_ff"))
        p["w_out"] = ParamSpec((ff, d), ("d_ff", "d_model"))
    else:
        p["w_in"] = ParamSpec((d, 2 * ff), ("d_model", "d_ff"))
        p["w_out"] = ParamSpec((ff, d), ("d_ff", "d_model"))
    if cfg.post_norms:
        p["post_norm"] = ParamSpec((d,), ("d_model",), "zeros")
    return p


def apply_ffn(cfg: ModelConfig, p, x, kind: str = "swiglu"):
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    h = xn @ p["w_in"].astype(xn.dtype)
    h = jax.nn.gelu(h, approximate=True) if kind == "gelu" else swiglu(h, kind)
    h = constrain(h, cfg.sharding, ("batch", "seq", "d_ff"))
    y = h @ p["w_out"].astype(h.dtype)
    if cfg.post_norms:
        y = rms_norm(y, p["post_norm"], cfg.norm_eps)
    return x + y


def plan_moe(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    mo = cfg.moe
    d = cfg.d_model
    p = {
        "norm": ParamSpec((d,), ("d_model",), "zeros"),
        "router": ParamSpec((d, mo.num_experts), ("d_model", None)),
        "w_in": ParamSpec((mo.num_experts, d, 2 * mo.d_ff_expert),
                          ("expert", "d_model", None)),
        "w_out": ParamSpec((mo.num_experts, mo.d_ff_expert, d),
                           ("expert", None, "d_model")),
    }
    if mo.num_shared:
        ffs = mo.d_ff_shared or mo.d_ff_expert
        p["shared_in"] = ParamSpec((d, 2 * ffs * mo.num_shared),
                                   ("d_model", "d_ff"))
        p["shared_out"] = ParamSpec((ffs * mo.num_shared, d),
                                    ("d_ff", "d_model"))
    return p


def apply_moe(cfg: ModelConfig, p, x):
    if cfg.moe_impl == "a2a":
        from repro.models.meshctx import get_mesh
        mesh = get_mesh()
        if mesh is not None and all(a in mesh.axis_names
                                    for a in cfg.moe.ep_axes):
            return apply_moe_a2a(cfg, p, x, mesh)
    return apply_moe_gather(cfg, p, x)


def apply_moe_a2a(cfg: ModelConfig, p, x, mesh):
    """Expert-parallel MoE: the paper's shuffle as a first-class LM layer.

    Tokens are hash-partitioned by K2 = expert id and exchanged with ONE
    ``jax.lax.all_to_all`` over the EP mesh axes (exactly
    ``core.distributed``'s shuffle); the combine is the segment reduction.
    Inside the shard_map region each device owns E/P experts (DeepSeek-V3 on
    the 256-chip pod: exactly one), computes its expert GEMMs on the
    received capacity buffer, and the return all_to_all routes outputs back.

    vs. the gather/scatter baseline this removes the giant [E, cap_global,
    d] scatter (GSPMD lowered it to all-gathers of the full token buffer:
    the 66 TB/device/step catastrophe in the baseline dry-run).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mo = cfg.moe
    rules = cfg.sharding
    b, s, d = x.shape
    ep_axes = tuple(a for a in mo.ep_axes if a in mesh.axis_names)
    p_ep = 1
    for a in ep_axes:
        p_ep *= mesh.shape[a]
    e_loc = mo.num_experts // p_ep
    batch_axes = tuple(a for a in rules.batch if a in mesh.axis_names)
    seq_ax = "model" if "model" in mesh.axis_names else None

    xn = rms_norm(x, p["norm"], cfg.norm_eps)

    def local_moe(xn_l, router, w_in, w_out):
        # xn_l [B_loc, S_loc, d]; router [d, E]; w_in [E_loc, d, 2ff]
        bl, sl, _ = xn_l.shape
        toks = xn_l.reshape(bl * sl, d)
        n = toks.shape[0]
        logits = toks.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, eid = jax.lax.top_k(probs, mo.top_k)            # [n, K]
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        # shuffle: bucket (token, k) slots by destination EP shard
        cap = int(n * mo.top_k * mo.capacity_factor) // p_ep + 1
        cap = max(cap, min(n * mo.top_k, 32))
        dest = (eid // e_loc).reshape(-1)                     # [n*K]
        order = jnp.argsort(dest)
        sdest = jnp.take(dest, order)
        rank = jnp.arange(n * mo.top_k) - jnp.searchsorted(sdest, sdest,
                                                           side="left")
        ok = rank < cap
        send = jnp.zeros((p_ep, cap, d), toks.dtype)
        tok_idx = order // mo.top_k
        send = send.at[jnp.where(ok, sdest, 0),
                       jnp.where(ok, rank, 0)].set(
            jnp.where(ok[:, None], jnp.take(toks, tok_idx, axis=0), 0),
            mode="drop")
        send_eid = jnp.full((p_ep, cap), -1, jnp.int32)
        send_eid = send_eid.at[jnp.where(ok, sdest, 0),
                               jnp.where(ok, rank, 0)].set(
            jnp.where(ok, jnp.take(eid.reshape(-1), order), -1),
            mode="drop")

        recv = jax.lax.all_to_all(send, ep_axes, 0, 0, tiled=True)
        recv_eid = jax.lax.all_to_all(send_eid, ep_axes, 0, 0, tiled=True)
        # recv [p_ep*cap, d] tokens destined to this shard's local experts
        rt = recv.reshape(p_ep * cap, d)
        re = recv_eid.reshape(p_ep * cap)
        my = jnp.int32(0)
        mul = 1
        for a in reversed(ep_axes):
            my = my + jax.lax.axis_index(a) * mul
            mul *= mesh.shape[a]
        local_e = re - my * e_loc                              # [-, E_loc)

        out = jnp.zeros_like(rt)
        for le in range(e_loc):
            sel = (local_e == le)[:, None]
            h = jnp.where(sel, rt, 0) @ w_in[le].astype(rt.dtype)
            h = swiglu(h)
            out = out + jnp.where(sel, h @ w_out[le].astype(h.dtype), 0)

        back = jax.lax.all_to_all(out.reshape(p_ep, cap, d), ep_axes, 0, 0,
                                  tiled=True)
        # combine: gather each (token, k) slot's output, weight, reduce
        flat = back.reshape(p_ep * cap, d)
        slot_of = jnp.full(n * mo.top_k, p_ep * cap - 1, jnp.int32)
        slot_of = slot_of.at[order].set(
            jnp.where(ok, sdest * cap + rank, p_ep * cap - 1))
        gathered = jnp.take(flat, slot_of, axis=0)             # [n*K, d]
        ok_slot = jnp.zeros(n * mo.top_k, bool).at[order].set(ok)
        w = (gate.reshape(-1) * ok_slot).astype(gathered.dtype)
        y = (gathered * w[:, None]).reshape(n, mo.top_k, d).sum(axis=1)
        return y.reshape(bl, sl, d)

    wspec = P(*[e if isinstance(e, tuple) else (e,) for e in [ep_axes]][0]) \
        if False else P(ep_axes)
    moe_fn = shard_map(
        local_moe, mesh=mesh,
        in_specs=(P(batch_axes, seq_ax, None), P(), P(ep_axes), P(ep_axes)),
        out_specs=P(batch_axes, seq_ax, None),
        check_rep=False)
    y = moe_fn(xn, p["router"], p["w_in"], p["w_out"])

    if mo.num_shared:
        hs = swiglu(xn.reshape(b * s, d) @ p["shared_in"].astype(xn.dtype))
        y = y + (hs @ p["shared_out"].astype(hs.dtype)).reshape(b, s, d)
    return x + y


def apply_moe_gather(cfg: ModelConfig, p, x):
    """Capacity-based top-k MoE, einsum dispatch (GSPMD shards experts).

    Dispatch = the paper's shuffle: tokens are partitioned by K2 = expert id
    and combined with a segment reduction; on the production mesh the expert
    dimension is sharded over ``moe.ep_axes`` and GSPMD lowers the dispatch
    einsums into the corresponding all_to_all/reduce-scatter schedule.
    """
    mo = cfg.moe
    b, s, d = x.shape
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    tokens = xn.reshape(b * s, d)
    n = tokens.shape[0]

    logits = (tokens.astype(jnp.float32)
              @ p["router"].astype(jnp.float32))             # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, mo.top_k)                # [N, K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, (n * mo.top_k * mo.capacity_factor) // mo.num_experts))
    # small-batch floor: decode / smoke batches must never drop (keeps
    # decode == teacher-forced parity exact); production sizes unaffected
    cap = max(cap, min(n, 32))
    # position of each (token, k) within its expert's capacity buffer
    onehot = jax.nn.one_hot(eid, mo.num_experts, dtype=jnp.int32)  # [N,K,E]
    pos_in_e = (jnp.cumsum(onehot.reshape(n * mo.top_k, mo.num_experts),
                           axis=0) - 1).reshape(n, mo.top_k, mo.num_experts)
    pos_k = jnp.take_along_axis(pos_in_e, eid[..., None],
                                axis=2)[..., 0]               # [N, K]
    keep = pos_k < cap
    # dispatch: scatter tokens into [E, cap, d]
    flat_e = jnp.where(keep, eid, mo.num_experts).reshape(-1)
    flat_pos = jnp.where(keep, pos_k, 0).reshape(-1)
    disp = jnp.zeros((mo.num_experts + 1, cap, d), tokens.dtype)
    tok_rep = jnp.repeat(tokens, mo.top_k, axis=0)
    disp = disp.at[flat_e, flat_pos].set(tok_rep)
    disp = disp[:mo.num_experts]
    disp = constrain(disp, cfg.sharding, ("expert", None, None))

    h = jnp.einsum("ecd,edf->ecf", disp, p["w_in"].astype(disp.dtype))
    h = swiglu(h)
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(h.dtype))
    eout = constrain(eout, cfg.sharding, ("expert", None, None))

    # combine: gather back and weight by gate (segment-sum over k slots)
    gath = eout[flat_e % mo.num_experts,
                flat_pos]                                     # [N*K, d]
    gath = gath * (gate.reshape(-1, 1) * keep.reshape(-1, 1)).astype(gath.dtype)
    y = gath.reshape(n, mo.top_k, d).sum(axis=1)

    if mo.num_shared:
        hs = swiglu(tokens @ p["shared_in"].astype(tokens.dtype))
        y = y + hs @ p["shared_out"].astype(hs.dtype)
    return x + y.reshape(b, s, d)


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

def plan_rglru(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    r = cfg.rglru.d_rnn
    cw = cfg.rglru.conv_width
    return {
        "norm": ParamSpec((d,), ("d_model",), "zeros"),
        "w_x": ParamSpec((d, r), ("d_model", "d_ff")),
        "w_gate": ParamSpec((d, r), ("d_model", "d_ff")),
        "conv_w": ParamSpec((cw, r), (None, "d_ff")),
        "conv_b": ParamSpec((r,), ("d_ff",), "zeros"),
        "w_a": ParamSpec((r, r), ("d_ff", None)),
        "w_i": ParamSpec((r, r), ("d_ff", None)),
        "lam": ParamSpec((r,), (None,), "ones"),
        "w_out": ParamSpec((r, d), ("d_ff", "d_model")),
    }


def _causal_conv(u, w, b, state=None):
    """u [B,S,R], w [CW,R] depthwise causal conv; state [B,CW-1,R]."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros(u.shape[:1] + (cw - 1,) + u.shape[2:], u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * w[i] for i in range(cw))
    new_state = full[:, -(cw - 1):] if cw > 1 else None
    return out + b, new_state


def apply_rglru(cfg: ModelConfig, p, x, cache=None):
    c = 8.0
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    u = xn @ p["w_x"].astype(xn.dtype)
    g = jax.nn.gelu(xn @ p["w_gate"].astype(xn.dtype), approximate=True)
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, p["conv_w"].astype(u.dtype),
                               p["conv_b"].astype(u.dtype), conv_state)
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32))
    log_a = -c * r * jax.nn.softplus(p["lam"].astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    bterm = beta * (i * uf)

    if cache is None:
        # h_t = a_t h_{t-1} + b_t  via associative scan over time
        def combine(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, bl * ar + br
        av, bv = jax.lax.associative_scan(combine, (a, bterm), axis=1)
        h = bv
        new_cache = None
    else:
        h = a[:, 0] * cache["h"].astype(jnp.float32) + bterm[:, 0]
        new_cache = {"h": h.astype(cache["h"].dtype), "conv": new_conv}
        h = h[:, None]
    y = (h.astype(x.dtype) * g) @ p["w_out"].astype(x.dtype)
    return x + y, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int):
    r = cfg.rglru.d_rnn
    cw = cfg.rglru.conv_width
    return {
        "h": ParamSpec((batch, r), ("batch", None), "zeros"),
        "conv": ParamSpec((batch, cw - 1, r), ("batch", None, None), "zeros"),
    }


# ---------------------------------------------------------------------------
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory) blocks
# ---------------------------------------------------------------------------

def plan_mlstm(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h = cfg.d_model, cfg.n_heads
    m = 2 * d                       # projection factor 2
    dh = m // h
    return {
        "norm": ParamSpec((d,), ("d_model",), "zeros"),
        "w_up": ParamSpec((d, 2 * m), ("d_model", "d_ff")),
        "wq": ParamSpec((m, m), ("d_ff", None)),
        "wk": ParamSpec((m, m), ("d_ff", None)),
        "wv": ParamSpec((m, m), ("d_ff", None)),
        "w_if": ParamSpec((m, 2 * h), ("d_ff", None)),
        "gn": ParamSpec((m,), (None,), "zeros"),
        "w_down": ParamSpec((m, d), ("d_ff", "d_model")),
    }


def _mlstm_step(carry, inp):
    (C, n, mstab) = carry
    (q, k, v, i_t, f_t) = inp       # q/k/v [B,H,dh]; i/f [B,H]
    mnew = jnp.maximum(f_t + mstab, i_t)
    fp = jnp.exp(f_t + mstab - mnew)[..., None]
    ip = jnp.exp(i_t - mnew)[..., None]
    C = fp[..., None] * C + ip[..., None] * (v[..., :, None] *
                                             k[..., None, :])
    n = fp * n + ip * k
    denom = jnp.maximum(jnp.abs((n * q).sum(-1)), 1.0)[..., None]
    h = (C * q[..., None, :]).sum(-1) / denom
    return (C, n, mnew), h


def apply_mlstm(cfg: ModelConfig, p, x, cache=None):
    b, s, d = x.shape
    h_ = cfg.n_heads
    m = 2 * d
    dh = m // h_
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    up = xn @ p["w_up"].astype(xn.dtype)
    z, gate = jnp.split(up, 2, axis=-1)
    q = (z @ p["wq"].astype(z.dtype)).reshape(b, s, h_, dh)
    k = (z @ p["wk"].astype(z.dtype)).reshape(b, s, h_, dh) / (dh ** 0.5)
    v = (z @ p["wv"].astype(z.dtype)).reshape(b, s, h_, dh)
    gf = (z.astype(jnp.float32) @ p["w_if"].astype(jnp.float32))
    i_t = gf[..., :h_]
    f_t = jax.nn.log_sigmoid(gf[..., h_:])

    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    if cache is None:
        carry = (jnp.zeros((b, h_, dh, dh)), jnp.zeros((b, h_, dh)),
                 jnp.zeros((b, h_)))
        xs = (qf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1),
              i_t.swapaxes(0, 1), f_t.swapaxes(0, 1))
        _, hs = jax.lax.scan(_mlstm_step, carry, xs)
        hs = hs.swapaxes(0, 1)                      # [B,S,H,dh]
        new_cache = None
    else:
        carry = (cache["C"].astype(jnp.float32),
                 cache["n"].astype(jnp.float32),
                 cache["m"].astype(jnp.float32))
        carry, h1 = _mlstm_step(carry, (qf[:, 0], kf[:, 0], vf[:, 0],
                                        i_t[:, 0], f_t[:, 0]))
        hs = h1[:, None]
        new_cache = {"C": carry[0], "n": carry[1], "m": carry[2]}
    hs = hs.reshape(b, -1, m).astype(x.dtype)
    hs = rms_norm(hs, p["gn"], cfg.norm_eps)
    y = (hs * jax.nn.silu(gate)) @ p["w_down"].astype(x.dtype)
    return x + y, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int):
    h_ = cfg.n_heads
    dh = 2 * cfg.d_model // h_
    return {
        "C": ParamSpec((batch, h_, dh, dh), ("batch", None, None, None),
                       "zeros"),
        "n": ParamSpec((batch, h_, dh), ("batch", None, None), "zeros"),
        "m": ParamSpec((batch, h_), ("batch", None), "zeros"),
    }


def plan_slstm(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    ff = max(int(4 * d / 3) // 2 * 2, 8)
    return {
        "norm": ParamSpec((d,), ("d_model",), "zeros"),
        "w_gates": ParamSpec((d, 4 * d), ("d_model", None)),
        "r_gates": ParamSpec((4, h, dh, dh), (None, None, None, None)),
        "gn": ParamSpec((d,), (None,), "zeros"),
        "norm2": ParamSpec((d,), ("d_model",), "zeros"),
        "up": ParamSpec((d, 2 * ff), ("d_model", "d_ff")),
        "down": ParamSpec((ff, d), ("d_ff", "d_model")),
    }


def _slstm_step(params, carry, wx_t):
    """carry: (c, n, h, m) each [B, H, dh]; wx_t [B, 4, H, dh]."""
    r = params
    c, n, h, mstab = carry
    rec = jnp.einsum("ghij,bhj->bghi", r, h)
    pre = wx_t + rec                             # [B,4,H,dh]
    z = jnp.tanh(pre[:, 0])
    i_t = pre[:, 1]
    f_t = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    mnew = jnp.maximum(f_t + mstab, i_t)
    ip = jnp.exp(i_t - mnew)
    fp = jnp.exp(f_t + mstab - mnew)
    c = fp * c + ip * z
    n = jnp.maximum(fp * n + ip, 1e-6)
    h = o * c / n
    return (c, n, h, mnew), h


def apply_slstm(cfg: ModelConfig, p, x, cache=None):
    b, s, d = x.shape
    h_ = cfg.n_heads
    dh = d // h_
    xn = rms_norm(x, p["norm"], cfg.norm_eps)
    wx = (xn.astype(jnp.float32) @ p["w_gates"].astype(jnp.float32))
    wx = wx.reshape(b, s, 4, h_, dh)
    r = p["r_gates"].astype(jnp.float32)
    step = functools.partial(_slstm_step, r)
    if cache is None:
        zero = jnp.zeros((b, h_, dh))
        carry = (zero, zero, zero, jnp.zeros((b, h_, dh)))
        _, hs = jax.lax.scan(lambda c_, w: step(c_, w), carry,
                             wx.swapaxes(0, 1))
        hs = hs.swapaxes(0, 1)
        new_cache = None
    else:
        carry = tuple(cache[k].astype(jnp.float32)
                      for k in ("c", "n", "h", "m"))
        carry, h1 = step(carry, wx[:, 0])
        hs = h1[:, None]
        new_cache = dict(zip(("c", "n", "h", "m"), carry))
    hs = hs.reshape(b, -1, d).astype(x.dtype)
    hs = rms_norm(hs, p["gn"], cfg.norm_eps)
    y = x + hs
    # post-FFN (GLU, projection factor 4/3)
    hff = swiglu(rms_norm(y, p["norm2"], cfg.norm_eps)
                 @ p["up"].astype(y.dtype))
    return y + hff @ p["down"].astype(y.dtype), new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int):
    h_ = cfg.n_heads
    dh = cfg.d_model // h_
    sp = ParamSpec((batch, h_, dh), ("batch", None, None), "zeros")
    return {"c": sp, "n": sp, "h": sp, "m": sp}
