"""LM assembly: embedding, cycled super-block stack (lax.scan), loss, decode.

The layer stack is three segments — unrolled ``prefix`` blocks, a scanned
body of ``cycles`` super-blocks (stacked params, compact HLO — mandatory for
512-way SPMD compiles on the CPU host), and unrolled ``remainder`` blocks.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.common import (
    ParamSpec, constrain, cross_entropy, rms_norm, softcap, tree_init,
    tree_shape_structs, tree_shardings,
)
from repro.models.config import ModelConfig, ShapeCell


# ---------------------------------------------------------------------------
# Parameter plan
# ---------------------------------------------------------------------------

def plan_block(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    if kind == "attn_dense":
        return {"attn": B.plan_attention(cfg),
                "ffn": B.plan_ffn(cfg, kind=cfg.ffn_kind)}
    if kind == "attn_local":
        return {"attn": B.plan_attention(cfg),
                "ffn": B.plan_ffn(cfg, kind=cfg.ffn_kind)}
    if kind == "mla_dense":
        return {"attn": B.plan_mla(cfg),
                "ffn": B.plan_ffn(cfg, d_ff=cfg.d_ff_dense,
                                  kind=cfg.ffn_kind)}
    if kind == "attn_moe":
        attn = B.plan_mla(cfg) if cfg.mla is not None else \
            B.plan_attention(cfg)
        return {"attn": attn, "moe": B.plan_moe(cfg)}
    if kind == "rec":
        return {"rec": B.plan_rglru(cfg),
                "ffn": B.plan_ffn(cfg, kind=cfg.ffn_kind)}
    if kind == "mlstm":
        return {"cell": B.plan_mlstm(cfg)}
    if kind == "slstm":
        return {"cell": B.plan_slstm(cfg)}
    raise ValueError(kind)


def plan_model(cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    plan: Dict[str, Any] = {}
    if cfg.embed_inputs:
        plan["embed"] = ParamSpec((cfg.vocab, d), ("vocab", "d_model"))
    plan["final_norm"] = ParamSpec((d,), ("d_model",), "zeros")
    if not cfg.tie_embeddings or not cfg.embed_inputs:
        plan["head"] = ParamSpec((d, cfg.vocab), ("d_model", "vocab"))
    plan["prefix"] = [plan_block(cfg, k) for k in cfg.prefix_blocks]
    if cfg.cycles > 0:
        super_plan = {f"b{i}_{k}": plan_block(cfg, k)
                      for i, k in enumerate(cfg.block_pattern)}
        plan["body"] = jax.tree.map(
            lambda s: ParamSpec((cfg.cycles,) + s.shape, (None,) + s.axes,
                                s.init),
            super_plan, is_leaf=lambda x: isinstance(x, ParamSpec))
    plan["rem"] = [plan_block(cfg, k) for k in cfg.remainder_blocks]
    if cfg.mtp:
        plan["mtp_proj"] = ParamSpec((2 * d, d), ("d_model", None))
        plan["mtp_block"] = plan_block(cfg, "attn_dense")
        plan["mtp_norm"] = ParamSpec((d,), ("d_model",), "zeros")
    return plan


# ---------------------------------------------------------------------------
# Caches (decode)
# ---------------------------------------------------------------------------

def plan_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int):
    if kind in ("attn_dense",):
        return {"attn": B.init_attn_cache(cfg, batch, max_len)}
    if kind == "attn_local":
        return {"attn": B.init_attn_cache(cfg, batch, max_len,
                                          window=cfg.local_window)}
    if kind in ("mla_dense",):
        return {"attn": B.init_mla_cache(cfg, batch, max_len)}
    if kind == "attn_moe":
        c = B.init_mla_cache(cfg, batch, max_len) if cfg.mla is not None \
            else B.init_attn_cache(cfg, batch, max_len)
        return {"attn": c}
    if kind == "rec":
        return {"rec": B.init_rglru_cache(cfg, batch)}
    if kind == "mlstm":
        return {"cell": B.init_mlstm_cache(cfg, batch)}
    if kind == "slstm":
        return {"cell": B.init_slstm_cache(cfg, batch)}
    raise ValueError(kind)


def plan_caches(cfg: ModelConfig, batch: int, max_len: int):
    plan: Dict[str, Any] = {"pos": ParamSpec((), (), "zeros")}
    plan["prefix"] = [plan_block_cache(cfg, k, batch, max_len)
                      for k in cfg.prefix_blocks]
    if cfg.cycles > 0:
        sup = {f"b{i}_{k}": plan_block_cache(cfg, k, batch, max_len)
               for i, k in enumerate(cfg.block_pattern)}
        plan["body"] = jax.tree.map(
            lambda s: ParamSpec((cfg.cycles,) + s.shape, (None,) + s.axes,
                                s.init),
            sup, is_leaf=lambda x: isinstance(x, ParamSpec))
    plan["rem"] = [plan_block_cache(cfg, k, batch, max_len)
                   for k in cfg.remainder_blocks]
    return plan


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def apply_block(cfg: ModelConfig, kind: str, p, x, pos, cache):
    if kind in ("attn_dense", "attn_local"):
        win = cfg.local_window if kind == "attn_local" else 0
        x, c = B.apply_attention(cfg, p["attn"], x, pos,
                                 cache["attn"] if cache else None, window=win)
        x = B.apply_ffn(cfg, p["ffn"], x, kind=cfg.ffn_kind)
        return x, ({"attn": c} if cache else None)
    if kind == "mla_dense":
        x, c = B.apply_mla(cfg, p["attn"], x, pos,
                           cache["attn"] if cache else None)
        x = B.apply_ffn(cfg, p["ffn"], x, kind=cfg.ffn_kind)
        return x, ({"attn": c} if cache else None)
    if kind == "attn_moe":
        if cfg.mla is not None:
            x, c = B.apply_mla(cfg, p["attn"], x, pos,
                               cache["attn"] if cache else None)
        else:
            x, c = B.apply_attention(cfg, p["attn"], x, pos,
                                     cache["attn"] if cache else None)
        x = B.apply_moe(cfg, p["moe"], x)
        return x, ({"attn": c} if cache else None)
    if kind == "rec":
        x, c = B.apply_rglru(cfg, p["rec"], x,
                             cache["rec"] if cache else None)
        x = B.apply_ffn(cfg, p["ffn"], x, kind=cfg.ffn_kind)
        return x, ({"rec": c} if cache else None)
    if kind == "mlstm":
        x, c = B.apply_mlstm(cfg, p["cell"], x,
                             cache["cell"] if cache else None)
        return x, ({"cell": c} if cache else None)
    if kind == "slstm":
        x, c = B.apply_slstm(cfg, p["cell"], x,
                             cache["cell"] if cache else None)
        return x, ({"cell": c} if cache else None)
    raise ValueError(kind)


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def forward(cfg: ModelConfig, params, inputs, pos, caches=None):
    """inputs: token ids [B,S] (embed_inputs) or embeddings [B,S,d].

    Returns (hidden [B,S,d], new_caches).
    """
    rules = cfg.sharding
    if cfg.embed_inputs:
        emb = params["embed"]
        x = jnp.take(emb, inputs, axis=0).astype(cfg.dtype("compute"))
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    else:
        x = inputs.astype(cfg.dtype("compute"))
    x = constrain(x, rules, ("batch", "seq", "d_model"))

    new_caches = {"pos": caches["pos"] + 1} if caches is not None else None

    def seg_list(name, kinds, plist, clist):
        nonlocal x
        out_caches = []
        for i, kind in enumerate(kinds):
            c = clist[i] if clist is not None else None
            x2, nc = apply_block(cfg, kind, plist[i], x, pos, c)
            x = constrain(x2, rules, ("batch", "seq", "d_model"))
            out_caches.append(nc)
        return out_caches

    pc = caches["prefix"] if caches is not None else None
    new_prefix = seg_list("prefix", cfg.prefix_blocks, params.get(
        "prefix", []), pc)

    if cfg.cycles > 0:
        pattern = cfg.block_pattern

        def body(xc, layer):
            lp, lc = layer
            xx = xc
            ncs = {}
            for i, kind in enumerate(pattern):
                key = f"b{i}_{kind}"
                c = lc[key] if lc is not None else None
                xx, nc = apply_block(cfg, kind, lp[key], xx, pos, c)
                xx = constrain(xx, rules, ("batch", "seq", "d_model"))
                ncs[key] = nc
            return xx, ncs

        body_r = _remat(cfg, body)
        if caches is not None:
            if cfg.scan_layers:
                x, body_caches = jax.lax.scan(
                    body_r, x, (params["body"], caches["body"]))
            else:
                ncs = []
                for i in range(cfg.cycles):
                    lp = jax.tree.map(lambda a: a[i], params["body"])
                    lc = jax.tree.map(lambda a: a[i], caches["body"])
                    x, nc = body_r(x, (lp, lc))
                    ncs.append(nc)
                body_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
            new_caches["body"] = body_caches
        else:
            if cfg.scan_layers:
                def body_noc(xc, lp):
                    xx, _ = body_r(xc, (lp, None))
                    return xx, None
                x, _ = jax.lax.scan(body_noc, x, params["body"])
            else:
                for i in range(cfg.cycles):
                    lp = jax.tree.map(lambda a: a[i], params["body"])
                    x, _ = body_r(x, (lp, None))

    rc = caches["rem"] if caches is not None else None
    new_rem = seg_list("rem", cfg.remainder_blocks, params.get("rem", []), rc)

    if caches is not None:
        new_caches["prefix"] = new_prefix
        new_caches["rem"] = new_rem
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_caches


def logits_fn(cfg: ModelConfig, params, hidden):
    if cfg.tie_embeddings and cfg.embed_inputs:
        w = params["embed"].astype(hidden.dtype).T
    else:
        w = params["head"].astype(hidden.dtype)
    return hidden @ w


# ---------------------------------------------------------------------------
# Loss / train step / serve step
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params, batch):
    inputs = batch["inputs"]
    pos = batch.get("pos")
    if pos is None:
        s = inputs.shape[1]
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                               inputs.shape[:2])
    hidden, _ = forward(cfg, params, inputs, pos)
    targets, mask = batch["targets"], batch["mask"]

    if cfg.loss_chunk and hidden.shape[1] % cfg.loss_chunk == 0 \
            and hidden.shape[1] > cfg.loss_chunk:
        # blockwise CE: never materialize the full [B,S,V] logits
        nch = hidden.shape[1] // cfg.loss_chunk
        hs = hidden.reshape(hidden.shape[0], nch, cfg.loss_chunk, -1)
        ts = targets.reshape(targets.shape[0], nch, cfg.loss_chunk)
        ms = mask.reshape(mask.shape[0], nch, cfg.loss_chunk)

        def chunk(carry, xs):
            h, t, m = xs
            lg = logits_fn(cfg, params, h)
            lg = softcap(lg.astype(jnp.float32), cfg.logit_softcap)
            logz = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, t[..., None], axis=-1)[..., 0]
            nll = ((logz - gold) * m).sum()
            return (carry[0] + nll, carry[1] + m.sum()), None

        (tot, cnt), _ = jax.lax.scan(
            chunk, (jnp.float32(0), jnp.float32(0)),
            (hs.swapaxes(0, 1), ts.swapaxes(0, 1), ms.swapaxes(0, 1)))
        loss = tot / jnp.maximum(cnt, 1.0)
    else:
        logits = logits_fn(cfg, params, hidden)
        loss = cross_entropy(logits, targets, mask.astype(jnp.float32),
                             cfg.logit_softcap)

    if cfg.mtp and cfg.embed_inputs:
        # DeepSeek-V3-style multi-token prediction: one extra block predicts
        # token t+2 from [h_t ; emb(tok_{t+1})]
        emb = params["embed"]
        nxt = jnp.take(emb, batch["targets"], axis=0).astype(hidden.dtype)
        h2 = jnp.concatenate([hidden, nxt], axis=-1) @ \
            params["mtp_proj"].astype(hidden.dtype)
        pos2 = jnp.broadcast_to(
            jnp.arange(h2.shape[1], dtype=jnp.int32)[None], h2.shape[:2])
        h2, _ = apply_block(cfg, "attn_dense", params["mtp_block"], h2,
                            pos2, None)
        h2 = rms_norm(h2, params["mtp_norm"], cfg.norm_eps)
        lg2 = logits_fn(cfg, params, h2)
        t2 = jnp.concatenate([batch["targets"][:, 1:],
                              batch["targets"][:, -1:]], axis=1)
        m2 = mask.astype(jnp.float32) * \
            jnp.concatenate([jnp.ones_like(mask[:, 1:]),
                             jnp.zeros_like(mask[:, :1])],
                            axis=1).astype(jnp.float32)
        loss = loss + 0.3 * cross_entropy(lg2, t2, m2, cfg.logit_softcap)
    return loss


def serve_step(cfg: ModelConfig, params, caches, tokens):
    """One decode step: tokens [B, 1] -> logits [B, vocab], new caches."""
    cpos = caches["pos"]
    pos = jnp.broadcast_to(cpos[None, None], tokens.shape[:2]).astype(
        jnp.int32)
    hidden, new_caches = forward(cfg, params, tokens, pos, caches)
    logits = logits_fn(cfg, params, hidden[:, -1:, :])
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits[:, 0], new_caches


# ---------------------------------------------------------------------------
# Materialization helpers
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    return tree_init(plan_model(cfg), key, cfg.dtype("param"))


def param_specs(cfg: ModelConfig, mesh=None):
    return tree_shape_structs(plan_model(cfg), cfg.sharding, mesh,
                              cfg.dtype("param"))


def param_shardings(cfg: ModelConfig, mesh):
    return tree_shardings(plan_model(cfg), cfg.sharding, mesh)


def init_caches(cfg: ModelConfig, batch: int, max_len: int, key=None):
    plan = plan_caches(cfg, batch, max_len)
    caches = tree_init(plan, jax.random.PRNGKey(0) if key is None else key,
                       cfg.dtype("compute"))
    # pos is an int32 scalar
    caches["pos"] = jnp.int32(0)
    return caches


def cache_specs(cfg: ModelConfig, batch: int, max_len: int, mesh=None):
    plan = plan_caches(cfg, batch, max_len)
    specs = tree_shape_structs(plan, cfg.sharding, mesh,
                               cfg.dtype("compute"))
    def fix_pos(tree):
        tree["pos"] = jax.ShapeDtypeStruct((), jnp.int32) if mesh is None \
            else jax.ShapeDtypeStruct(
                (), jnp.int32,
                sharding=jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()))
        return tree
    return fix_pos(specs)
