"""Shared model machinery: parameter plans, logical-axis sharding, norms,
rotary embeddings, activation helpers.

Parameters are declared as ``ParamSpec`` trees (shape + logical axes), from
which we derive (a) real initialized arrays for smoke training, (b)
``ShapeDtypeStruct`` stand-ins with ``NamedSharding`` for the dry-run, and
(c) the in_shardings pytree for pjit — one source of truth.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShardingRules


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]    # logical axis per dim
    init: str = "normal"               # normal | zeros | ones | lecun


def _mesh_axes(rules: ShardingRules, logical: Optional[str]):
    if logical is None:
        return None
    table = {
        "batch": rules.batch, "seq": rules.seq,
        "heads": rules.heads, "kv_heads": rules.kv_heads,
        "d_model": rules.d_model, "d_ff": rules.d_ff,
        "vocab": rules.vocab, "expert": rules.expert,
        "kv_seq": rules.kv_seq,
    }
    return table.get(logical, None)


def pspec(rules: ShardingRules, axes: Tuple[Optional[str], ...]) -> P:
    return P(*[_mesh_axes(rules, a) for a in axes])


def _divisible_entry(entry, dim: int, mesh: Mesh):
    """Drop mesh axes from a pspec entry until they evenly divide ``dim``.

    Explicit input shardings must tile evenly (GSPMD may pad intermediates,
    but inputs may not) — e.g. kv_heads=8 cannot take an explicit 16-way
    shard; it falls back to replicated and GSPMD re-shards downstream.
    """
    if entry is None:
        return None
    names = list(entry) if isinstance(entry, (tuple, list)) else [entry]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    while names:
        prod = 1
        for n in names:
            prod *= sizes.get(n, 1)
        if prod > 0 and dim % prod == 0:
            break
        names.pop()
    if not names:
        return None
    return tuple(names) if len(names) > 1 else names[0]


def valid_pspec(rules: ShardingRules, axes: Tuple[Optional[str], ...],
                shape: Tuple[int, ...], mesh: Mesh) -> P:
    entries = [_mesh_axes(rules, a) for a in axes]
    return P(*[_divisible_entry(e, d, mesh)
               for e, d in zip(entries, shape)])


def tree_shardings(spec_tree, rules: ShardingRules, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, valid_pspec(rules, s.axes, s.shape,
                                                  mesh)),
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_shape_structs(spec_tree, rules: ShardingRules, mesh: Optional[Mesh],
                       dtype):
    def mk(s: ParamSpec):
        if mesh is None:
            return jax.ShapeDtypeStruct(s.shape, dtype)
        return jax.ShapeDtypeStruct(
            s.shape, dtype,
            sharding=NamedSharding(mesh, valid_pspec(rules, s.axes, s.shape,
                                                     mesh)))
    return jax.tree.map(mk, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def tree_init(spec_tree, key, dtype):
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for s, k in zip(leaves, keys):
        if s.init == "zeros":
            out.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            out.append(jnp.ones(s.shape, dtype))
        else:
            fan_in = s.shape[0] if len(s.shape) > 1 else max(s.shape[0], 1)
            if s.init == "lecun" and len(s.shape) >= 2:
                fan_in = int(np.prod(s.shape[:-1]))
            scale = 1.0 / math.sqrt(max(fan_in, 1))
            out.append((jax.random.normal(k, s.shape, jnp.float32)
                        * scale).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def constrain(x, rules: ShardingRules, axes: Tuple[Optional[str], ...]):
    """with_sharding_constraint via logical axes (no-op outside jit/mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, pspec(rules, axes))
    except (ValueError, RuntimeError):
        return x


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def softcap(x, cap: float):
    if cap <= 0.0:
        return x
    return jnp.tanh(x / cap) * cap


def rope_table(positions, dim: int, theta: float):
    """positions [*, T] -> (sin, cos) each [*, T, dim/2] in fp32."""
    half = dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x [..., T, H, D]; sin/cos [..., T, D/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :]
    c = cos[..., None, :]
    x1f = x1.astype(jnp.float32)
    x2f = x2.astype(jnp.float32)
    return jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s],
                           axis=-1).astype(x.dtype)


def swiglu(x, kind: str = "swiglu"):
    """x [..., 2*ff] fused gate+up -> [..., ff]."""
    gate, up = jnp.split(x, 2, axis=-1)
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    return jax.nn.silu(gate) * up


def cross_entropy(logits, targets, mask, logit_cap: float = 0.0):
    """Token-mean CE in fp32. logits [..., V], targets int [...]."""
    logits = softcap(logits.astype(jnp.float32), logit_cap)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
