"""Ambient mesh for shard_map regions inside GSPMD-jitted models.

``launch.steps.input_specs`` / the drivers set this before lowering; the MoE
all_to_all implementation reads it.  (ModelConfig is a frozen, hashable
dataclass and cannot carry the mesh object itself.)
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH
