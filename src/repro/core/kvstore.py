"""Fixed-width key-value pair arrays: the TPU-native representation of MapReduce records.

Hadoop streams variable-length records off disk; a TPU wants dense, statically
shaped arrays resident in HBM.  We therefore represent a batch of kv-pairs as a
``KV`` pytree of arrays with an explicit validity mask (padding), and the
MRBGraph intermediate edges as an ``Edges`` pytree carrying (K2, MK, V2) per
the paper's Section 3.2.

Keys are int32 ids.  Invalid/padding entries carry key == INVALID_KEY so that a
lexicographic sort pushes them to the end of the buffer.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

INVALID_KEY = jnp.int32(2**31 - 1)
_HASH_MULT = np.uint32(2654435761)


class KV(NamedTuple):
    """A batch of kv-pairs.  ``values`` may be any pytree of [N, ...] arrays."""

    keys: jax.Array          # [N] int32
    values: Any              # pytree of [N, ...]
    valid: jax.Array         # [N] bool

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))


class Edges(NamedTuple):
    """MRBGraph edges: fine-grain intermediate state (K2, MK, V2).

    ``sign`` distinguishes insertions (+1) from deletion tombstones (-1) in a
    *delta* MRBGraph; a preserved MRBGraph has sign == +1 everywhere.
    """

    k2: jax.Array            # [E] int32  destination Reduce instance
    mk: jax.Array            # [E] int32  globally unique Map instance key
    v2: Any                  # pytree of [E, ...] edge values
    valid: jax.Array         # [E] bool
    sign: jax.Array          # [E] int8   +1 insert, -1 delete

    @property
    def capacity(self) -> int:
        return self.k2.shape[0]

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))


def make_kv(keys, values, valid=None) -> KV:
    keys = jnp.asarray(keys, jnp.int32)
    if valid is None:
        valid = jnp.ones(keys.shape[0], jnp.bool_)
    values = jax.tree.map(jnp.asarray, values)
    return KV(keys, values, jnp.asarray(valid, jnp.bool_))


def make_edges(k2, mk, v2, valid=None, sign=None) -> Edges:
    k2 = jnp.asarray(k2, jnp.int32)
    mk = jnp.asarray(mk, jnp.int32)
    if valid is None:
        valid = jnp.ones(k2.shape[0], jnp.bool_)
    if sign is None:
        sign = jnp.ones(k2.shape[0], jnp.int8)
    v2 = jax.tree.map(jnp.asarray, v2)
    return Edges(k2, mk, v2, jnp.asarray(valid, jnp.bool_),
                 jnp.asarray(sign, jnp.int8))


def hash32(keys: jax.Array, buckets: int) -> jax.Array:
    """Knuth multiplicative hash onto ``buckets`` partitions (uint32 domain)."""
    h = (keys.astype(jnp.uint32) * _HASH_MULT) >> jnp.uint32(16)
    return (h % jnp.uint32(buckets)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Sorting (the TPU analogue of Hadoop's shuffle-sort) — thin wrappers over
# the backend dispatcher in repro.kernels.ops
# ---------------------------------------------------------------------------

def sort_edges(edges: Edges, *, num_keys: int = 2,
               backend: Optional[str] = None) -> Edges:
    """Lexicographic stable sort of edges by (k2[, mk]).

    Invalid edges get k2 = INVALID_KEY so they land at the tail.  This mirrors
    the MapReduce shuffle: intermediate kv-pairs arrive at a Reduce task sorted
    by K2 (Section 3.3), and within a chunk by MK so that merge-joins are
    sequential.
    """
    k2 = jnp.where(edges.valid, edges.k2, INVALID_KEY)
    res = ops.sort_pairs(k2, edges.mk, (edges.v2, edges.valid, edges.sign),
                         num_keys=num_keys, backend=backend)
    v2, valid, sign = res.payload
    return Edges(res.k2, res.mk, v2, valid, sign)


def sort_kv(kv: KV, *, backend: Optional[str] = None) -> KV:
    keys = jnp.where(kv.valid, kv.keys, INVALID_KEY)
    res = ops.sort_pairs(keys, None, (kv.values, kv.valid), num_keys=1,
                         backend=backend)
    values, valid = res.payload
    return KV(res.k2, values, valid)


# ---------------------------------------------------------------------------
# Reducers (the Reduce function, expressed as a segment monoid)
# ---------------------------------------------------------------------------

class Reducer(NamedTuple):
    """Associative Reduce functions as segment monoids.

    All of the paper's applications (sum for PageRank/GIM-V/WordCount/APriori,
    min for SSSP, mean for Kmeans) are monoids, which is what makes both the
    MXU-friendly segment reduction and the accumulator-Reduce optimization of
    Section 3.5 applicable.

    ``invertible`` marks monoids that are abelian groups (sum): deletions can
    then be applied as inverse contributions *without* consulting the
    MRBGraph.  This generalizes the paper's accumulator optimization (which
    requires insert-only deltas) and is used as a beyond-paper fast path.
    """

    kind: str                                 # 'sum' | 'min' | 'max' | 'mean'
    finalize: Optional[Callable] = None       # (key, acc, count) -> value
    invertible: bool = False

    def identity_like(self, v2_leaf: jax.Array) -> jax.Array:
        if self.kind in ("sum", "mean"):
            return jnp.zeros_like(v2_leaf)
        if self.kind == "min":
            return jnp.full_like(v2_leaf, _type_max(v2_leaf.dtype))
        if self.kind == "max":
            return jnp.full_like(v2_leaf, _type_min(v2_leaf.dtype))
        raise ValueError(self.kind)


def _type_max(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.finfo(dtype).max
    return jnp.iinfo(dtype).max


def _type_min(dtype):
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.finfo(dtype).min
    return jnp.iinfo(dtype).min


def sum_reducer(finalize=None) -> Reducer:
    return Reducer("sum", finalize, invertible=True)


def min_reducer(finalize=None) -> Reducer:
    return Reducer("min", finalize)


def max_reducer(finalize=None) -> Reducer:
    return Reducer("max", finalize)


def mean_reducer(finalize=None) -> Reducer:
    return Reducer("mean", finalize)


def segment_reduce(reducer: Reducer, segment_ids: jax.Array, values: Any,
                   valid: jax.Array, num_segments: int,
                   indices_are_sorted: bool = False,
                   backend: Optional[str] = None):
    """Reduce ``values`` into ``num_segments`` groups.

    Thin wrapper over the backend dispatcher (:mod:`repro.kernels.ops`).
    Returns (accumulated values pytree [K, ...], counts [K] int32).
    Invalid rows are routed to a scratch segment (index ``num_segments``)
    so they never pollute real groups.
    """
    return ops.segment_reduce(reducer, segment_ids, values, valid,
                              num_segments,
                              indices_are_sorted=indices_are_sorted,
                              backend=backend)


def finalize_reduce(reducer: Reducer, keys: jax.Array, acc: Any,
                    counts: jax.Array):
    """Apply mean division and the user finalize hook."""
    if reducer.kind == "mean":
        denom = jnp.maximum(counts, 1)
        acc = jax.tree.map(
            lambda a: a / denom.reshape((-1,) + (1,) * (a.ndim - 1)).astype(a.dtype),
            acc)
    if reducer.finalize is not None:
        acc = reducer.finalize(keys, acc, counts)
    return acc


# ---------------------------------------------------------------------------
# Compaction: gather the valid prefix of a padded buffer (bucketed capacity)
# ---------------------------------------------------------------------------

def next_bucket(n: int, minimum: int = 256) -> int:
    """Round up to the next power-of-two capacity bucket.

    Bucketing bounds the number of distinct shapes (hence XLA recompiles) to
    log2(N) while letting incremental work scale with the true delta size --
    the JAX replacement for Hadoop's dynamically sized spill files.
    """
    n = max(int(n), 1)
    b = minimum
    while b < n:
        b *= 2
    return b


@functools.partial(jax.jit, static_argnums=(1,))
def compact_edges(edges: Edges, capacity: int) -> Edges:
    """Gather valid edges to the front of a ``capacity``-sized buffer."""
    order = jnp.argsort(~edges.valid, stable=True)  # valid first
    n = order.shape[0]
    if capacity > n:
        order = jnp.concatenate(
            [order, jnp.zeros(capacity - n, order.dtype)])
    take = order[:capacity]

    def g(leaf):
        return jnp.take(leaf, take, axis=0)

    n_valid = jnp.sum(edges.valid.astype(jnp.int32))
    new_valid = jnp.arange(capacity, dtype=jnp.int32) < n_valid
    return Edges(
        jnp.where(new_valid, g(edges.k2), INVALID_KEY),
        jnp.where(new_valid, g(edges.mk), INVALID_KEY),
        jax.tree.map(g, edges.v2),
        new_valid,
        jnp.where(new_valid, g(edges.sign), jnp.int8(0)),
    )


def edges_to_host(edges: Edges, *, sorted_valid_first: bool = False) -> dict:
    """Pull valid edges to host numpy (index maintenance lives host-side,
    exactly as Hadoop's chunk index lives outside the task JVM heap).

    ``sorted_valid_first=True`` (post-``sort_edges`` buffers): slice the
    valid prefix *on device* before the host transfer, so PCIe traffic is
    O(valid) instead of O(capacity) — sparse-emission Maps (e.g. APriori's
    presence tests) often fill <10% of their static edge buffer.
    """
    if sorted_valid_first:
        nvalid = int(jnp.sum(edges.valid))
        cap = min(edges.capacity, next_bucket(max(nvalid, 1), 64))
        sl = lambda a: a[:cap]
        edges = Edges(sl(edges.k2), sl(edges.mk),
                      jax.tree.map(sl, edges.v2), sl(edges.valid),
                      sl(edges.sign))
    valid = np.asarray(edges.valid)
    idx = np.nonzero(valid)[0]
    return {
        "k2": np.asarray(edges.k2)[idx],
        "mk": np.asarray(edges.mk)[idx],
        "v2": jax.tree.map(lambda l: np.asarray(l)[idx], edges.v2),
        "sign": np.asarray(edges.sign)[idx],
    }
