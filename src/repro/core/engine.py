"""One-step MapReduce engine (vectorized, TPU-native).

Maps the classic map -> shuffle -> reduce dataflow (Section 2 of the paper)
onto JAX:

  * Map        : a user function vectorized over the whole record batch.
  * Shuffle    : a lexicographic sort of intermediate (K2, MK, V2) edges
                 (single device) or a hash-partitioned all_to_all
                 (``repro.core.distributed``).
  * Reduce     : an MXU-friendly segment reduction over K2 groups.

The engine can *preserve* the intermediate edges -- the MRBGraph of
Section 3.2 -- which is what enables fine-grain incremental recomputation.

The Map function signature carries a per-record ``sign`` (+1/-1): a full run
passes all +1; the incremental engine (Section 3.3) passes the delta input's
insert/delete marks, and the emit helpers stamp them onto the produced edges
so that edges of deleted records become tombstones.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.kvstore import (
    KV, Edges, Reducer, finalize_reduce, make_kv, segment_reduce, sort_edges,
)
from repro.kernels import jitcache, ops

# map_fn(kv, record_sign) -> Edges.  Fanout must be static; helpers below
# derive globally unique MKs from (record id, slot).
MapFn = Callable[[KV, jax.Array], Edges]


@dataclass(frozen=True)
class JobSpec:
    """A one-step MapReduce job over a dense int key space [0, num_keys)."""

    map_fn: MapFn
    reducer: Reducer
    num_keys: int
    name: str = "job"


class JobResult:
    def __init__(self, results: KV, edges: Optional[Edges], counts: jax.Array):
        self.results = results      # KV over the dense key space
        self.edges = edges          # preserved MRBGraph (sorted) or None
        self.counts = counts        # [num_keys] in-edge counts per reduce key


def make_mk(record_ids: jax.Array, slot: int, fanout: int) -> jax.Array:
    """Globally unique Map key: the paper assigns each Map call instance a
    unique MK (Section 3.2); we derive it structurally from record id x slot
    so it is stable across jobs -- required for delta matching."""
    return record_ids.astype(jnp.int32) * jnp.int32(fanout) + jnp.int32(slot)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _run(spec_static, preserve: bool, inp: KV, record_sign: jax.Array):
    jitcache.count_trace("engine._run")
    map_fn, reducer, num_keys, backend = spec_static
    edges = map_fn(inp, record_sign)
    acc, counts = segment_reduce(reducer, edges.k2, edges.v2, edges.valid,
                                 num_keys, backend=backend)
    keys = jnp.arange(num_keys, dtype=jnp.int32)
    values = finalize_reduce(reducer, keys, acc, counts)
    results = KV(keys, values, counts > 0)
    preserved = sort_edges(edges, backend=backend) if preserve else None
    return results, preserved, counts


def run_onestep(spec: JobSpec, inp: KV, *, preserve: bool = False,
                backend: Optional[str] = None) -> JobResult:
    """Run a full (non-incremental) MapReduce job.

    ``preserve=True`` additionally returns the sorted MRBGraph edges, ready to
    be ingested by :class:`repro.core.mrbg_store.MRBGStore`.  ``backend``
    overrides the shuffle/reduce backend (resolved outside the jit so that
    switching backends retraces).

    Engine-internal: user code drives jobs through ``repro.api.Session``.
    """
    spec_static = (spec.map_fn, spec.reducer, spec.num_keys,
                   ops.resolve_backend(backend))
    sign = jnp.ones(inp.capacity, jnp.int8)
    results, preserved, counts = _run(spec_static, preserve, inp, sign)
    return JobResult(results, preserved, counts)


# ---------------------------------------------------------------------------
# Map helpers: build Edges from per-record emissions
# ---------------------------------------------------------------------------

def emit_single(k2, v2, record_ids, valid, record_sign=None,
                slot: int = 0, fanout: int = 1) -> Edges:
    """Each record emits exactly one intermediate kv-pair."""
    mk = make_mk(record_ids, slot, fanout)
    n = mk.shape[0]
    sign = (jnp.ones(n, jnp.int8) if record_sign is None
            else jnp.asarray(record_sign, jnp.int8))
    return Edges(jnp.asarray(k2, jnp.int32), mk, v2,
                 jnp.asarray(valid, jnp.bool_), sign)


def emit_multi(k2_slots, v2_slots, record_ids, valid_slots,
               record_sign=None) -> Edges:
    """Each record emits F intermediate kv-pairs (static fanout F).

    Args are [N, F] (+ value trailing dims); the result is flattened [N*F].
    """
    n, f = k2_slots.shape
    rid = jnp.repeat(record_ids.astype(jnp.int32), f)
    slot = jnp.tile(jnp.arange(f, dtype=jnp.int32), n)
    mk = rid * jnp.int32(f) + slot
    if record_sign is None:
        sign = jnp.ones(n * f, jnp.int8)
    else:
        sign = jnp.repeat(jnp.asarray(record_sign, jnp.int8), f)
    flat_v2 = jax.tree.map(lambda l: l.reshape((n * f,) + l.shape[2:]), v2_slots)
    return Edges(k2_slots.reshape(-1).astype(jnp.int32), mk, flat_v2,
                 valid_slots.reshape(-1).astype(jnp.bool_), sign)
