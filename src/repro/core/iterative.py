"""General-purpose iterative MapReduce model (paper Section 4).

Two kinds of data:

  * **structure** kv-pairs <SK, SV>: loop-invariant (graph adjacency, points,
    matrix blocks).  Dense SK record ids in [0, num_struct).
  * **state** kv-pairs <DK, DV>: loop-variant, updated by each iteration's
    prime Reduce.  Dense DK ids in [0, num_state).

``project(SK) -> DK`` declares the interdependency (one-to-one/many-to-one
after the Fig. 5 normalization; all-to-one is expressed with
``replicate_state=True``, the paper's "smaller number of state kv-pairs"
case).

The Hadoop mechanics — co-partitioning by hash(project(SK)), sorted
structure/state file merge-join, Reduce-to-Map local loopback — map onto the
TPU as: state lives as a dense HBM array indexed by DK, the merge-join is a
``jnp.take`` gather (state is co-resident, so the paper's "no backward
transfer" is the degenerate local case), and the prime loop is a jitted
``step`` reused across iterations (the analogue of keeping jobs alive across
iterations instead of paying job startup).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kvstore import (
    KV, Edges, Reducer, finalize_reduce, segment_reduce, sort_edges,
)
from repro.kernels import jitcache, ops

# prime Map: map_fn(struct_kv, state_dv, record_sign) -> Edges
#   state_dv is the gathered DV pytree aligned to the structure records
#   ([N, ...]), or the *whole* state pytree when replicate_state=True.
IterMapFn = Callable[[KV, Any, jax.Array], Edges]


def default_difference(curr: Dict[str, jax.Array],
                       prev: Dict[str, jax.Array]) -> jax.Array:
    """Max-abs change across all DV leaves, per state key."""
    diffs = []
    for n in curr:
        d = jnp.abs(curr[n].astype(jnp.float32) - prev[n].astype(jnp.float32))
        diffs.append(d.reshape(d.shape[0], -1).max(axis=1))
    return functools.reduce(jnp.maximum, diffs)


@dataclass(frozen=True)
class IterSpec:
    map_fn: IterMapFn
    reducer: Reducer
    project: Callable[[jax.Array], jax.Array]    # [N] SK -> [N] DK
    num_state: int
    init_state: Callable[[jax.Array], Any]       # [K] DK -> DV pytree
    # difference(DV_curr, DV_prev) -> [K] per-key change magnitude;
    # None resolves to default_difference, so readers may call it directly
    difference: Optional[Callable[[Any, Any], jax.Array]] = None
    replicate_state: bool = False                # all-to-one (Kmeans)
    stable_topology: bool = True                 # map K2 fanout fixed per SK
    name: str = "iter_job"

    def __post_init__(self):
        if self.difference is None:
            object.__setattr__(self, "difference", default_difference)


class State:
    """Dense loop-variant state <DK, DV> (device-resident)."""

    def __init__(self, values: Dict[str, jax.Array], valid: jax.Array):
        self.values = values
        self.valid = valid

    @classmethod
    def init(cls, spec: IterSpec) -> "State":
        dks = jnp.arange(spec.num_state, dtype=jnp.int32)
        return cls(spec.init_state(dks), jnp.ones(spec.num_state, jnp.bool_))

    def to_host(self) -> Dict[str, np.ndarray]:
        return {n: np.array(a) for n, a in self.values.items()}


# ---------------------------------------------------------------------------
# One full (non-incremental) iteration
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0, 1))
def _iter_step(spec_static, preserve: bool, struct: KV, state_values: Any,
               dks: jax.Array):
    """One prime Map -> shuffle -> prime Reduce pass over the full input."""
    jitcache.count_trace("iterative._iter_step")
    map_fn, reducer, project, num_state, replicate, backend = spec_static
    if replicate:
        dv = state_values
    else:
        dv = jax.tree.map(lambda a: jnp.take(a, dks, axis=0), state_values)
    sign = jnp.ones(struct.capacity, jnp.int8)
    edges = map_fn(struct, dv, sign)
    acc, counts = segment_reduce(reducer, edges.k2, edges.v2, edges.valid,
                                 num_state, backend=backend)
    keys = jnp.arange(num_state, dtype=jnp.int32)
    new_values = finalize_reduce(reducer, keys, acc, counts)
    preserved = sort_edges(edges, backend=backend) if preserve else None
    return new_values, counts, preserved


def run_iterative(spec: IterSpec, struct: KV, state: Optional[State] = None,
                  *, max_iters: int = 50, tol: float = 1e-4,
                  preserve_last: bool = False,
                  on_iteration: Optional[Callable] = None,
                  backend: Optional[str] = None):
    """Run the prime loop to convergence (iterMR recomp mode).

    Returns (state, history dict).  ``preserve_last`` additionally returns the
    final iteration's MRBGraph edges (to seed incremental jobs, Section 5.1).

    Engine-internal: user code drives jobs through ``repro.api.Session``.
    """
    if state is None:
        state = State.init(spec)
    diff_fn = spec.difference
    spec_static = (spec.map_fn, spec.reducer, spec.project, spec.num_state,
                   spec.replicate_state, ops.resolve_backend(backend))
    dks = spec.project(struct.keys) if not spec.replicate_state else \
        jnp.zeros(struct.capacity, jnp.int32)
    history = {"iters": 0, "max_change": []}
    edges = None
    counts = None
    for it in range(max_iters):
        want_edges = preserve_last
        new_values, counts, edges = _iter_step(spec_static, want_edges,
                                               struct, state.values, dks)
        change = diff_fn(new_values, state.values)
        max_change = float(jnp.max(jnp.where(state.valid, change, 0.0)))
        state = State(new_values, state.valid)
        history["iters"] = it + 1
        history["max_change"].append(max_change)
        if on_iteration is not None:
            on_iteration(it, state, max_change)
        if max_change < tol:
            break
    history["counts"] = counts
    history["last_edges"] = edges
    return state, history


def run_plain(spec: IterSpec, struct: KV, state: Optional[State] = None,
              **kw):
    """plainMR recomp baseline: same math, but models vanilla-MapReduce cost
    by re-shuffling the *structure* data every iteration (the extra join job
    of Algorithm 5 / HaLoop).  Used by the benchmark harness for the cost
    comparison; results are identical to :func:`run_iterative`.

    Engine-internal: user code drives this through ``repro.api.Session``
    with ``RunConfig(plain_shuffle=True)``."""
    def on_it(it, st, ch):
        # the extra structure shuffle: sort structure kv-pairs by key and
        # gather every value column through the permutation
        res = ops.sort_pairs(struct.keys, None, struct.values, num_keys=1,
                             backend=kw.get("backend"))
        _ = jax.tree.map(lambda a: a.block_until_ready()
                         if hasattr(a, 'block_until_ready') else a,
                         res.payload)
    kw.setdefault("on_iteration", on_it)
    return run_iterative(spec, struct, state, **kw)
