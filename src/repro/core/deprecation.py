"""Deprecation plumbing for the pre-`repro.api` entry points.

The engine's historical entry points (``run_onestep``, ``IncrementalJob``,
``run_iterative``/``run_plain``, ``IncrIterJob``, ``run_distributed``,
``AccumulatorJob``, ``checkpoint_job``/``restore_job``) remain the *internal
implementation* that :class:`repro.api.Session` drives, but direct use is
deprecated for one release.  ``internal_use()`` suppresses the warning while
the façade (or another engine layer) calls through them, so a user only ever
sees the warning for *their own* legacy call.
"""
from __future__ import annotations

import contextlib
import warnings

_suppress_depth = 0


@contextlib.contextmanager
def internal_use():
    """Mark the enclosed legacy-entry-point calls as engine-internal."""
    global _suppress_depth
    _suppress_depth += 1
    try:
        yield
    finally:
        _suppress_depth -= 1


def warn_deprecated(old: str, new: str, *, stacklevel: int = 3) -> None:
    if _suppress_depth == 0:
        warnings.warn(
            f"{old} is deprecated and will be removed after one release; "
            f"use {new} instead (see README migration table)",
            DeprecationWarning, stacklevel=stacklevel)
