"""Accumulator-Reduce optimization (paper Section 3.5) + invertible fast path.

When the Reduce function is a distributive accumulation `⊕` and the delta is
insert-only, the MRBGraph need not be preserved at all: the engine keeps only
the Reduce *output* and folds `f(ΔD)` into it:

    f(D ∪ ΔD) = f(D) ⊕ f(ΔD)

Beyond the paper: for reducers that form an abelian *group* (sum), deletions
and updates are handled without the MRBGraph either, by accumulating the
*negated* contribution of '-' records.  ``mean`` is handled as the paper
suggests -- partial (sum, count) accumulators finalized on read.

Work per refresh is proportional to |Δ| (plus an O(|affected|) gather/patch
of the dense output view), never to |D|.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import JobSpec
from repro.core.incremental import DeltaKV, ResultView, _pad_edges
from repro.core.kvstore import (
    INVALID_KEY, KV, Edges, Reducer, edges_to_host, finalize_reduce,
    next_bucket, segment_reduce, sort_edges,
)
from repro.kernels import jitcache, ops


@functools.partial(jax.jit, static_argnums=(0,))
def _delta_map_acc(spec_static, delta: DeltaKV) -> Edges:
    # NOTE: no shuffle-sort here — the accumulator path needs neither chunk
    # grouping nor merge order (that is exactly its §3.5 saving); host-side
    # nonzero extraction replaces it.
    jitcache.count_trace("accumulator._delta_map_acc")
    map_fn, = spec_static
    kv = KV(delta.keys, delta.values, delta.valid)
    return map_fn(kv, delta.sign)


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(5, 6))
def _accumulate(reducer: Reducer, key_cap: int, backend, edges: Edges,
                affected_keys: jax.Array, old_acc: Any, old_counts: jax.Array):
    """Fold the delta edges' contribution into the old accumulators.

    ``old_acc``/``old_counts`` are donated: they are gathered fresh per
    refresh and alias the ``acc``/``counts`` outputs exactly, so XLA reuses
    the buffers instead of copying.
    """
    jitcache.count_trace("accumulator._accumulate")
    if reducer.kind in ("sum", "mean"):
        # signed contribution: deletions subtract (group inverse)
        signf = edges.sign.astype(jnp.float32)
        v2 = jax.tree.map(
            lambda a: (a * signf.reshape((-1,) + (1,) * (a.ndim - 1))
                       .astype(a.dtype)), edges.v2)
    else:
        v2 = edges.v2   # insert-only (checked by caller)

    local = jnp.searchsorted(affected_keys, edges.k2).astype(jnp.int32)
    in_set = jnp.take(affected_keys, jnp.clip(local, 0, key_cap - 1)) == edges.k2
    ok = edges.valid & in_set
    acc_d, _ = segment_reduce(reducer, local, v2, ok, key_cap,
                              backend=backend)
    # signed count delta: sum of ±1 signs per affected key
    cnt_d, _ = segment_reduce("sum", local, edges.sign.astype(jnp.int32),
                              ok, key_cap, backend=backend)

    if reducer.kind in ("sum", "mean"):
        acc = jax.tree.map(lambda o, d: o + d.astype(o.dtype), old_acc, acc_d)
    elif reducer.kind == "min":
        acc = jax.tree.map(
            lambda o, d: jnp.where(old_counts.reshape(
                (-1,) + (1,) * (o.ndim - 1)) > 0, jnp.minimum(o, d), d),
            old_acc, acc_d)
    else:  # max
        acc = jax.tree.map(
            lambda o, d: jnp.where(old_counts.reshape(
                (-1,) + (1,) * (o.ndim - 1)) > 0, jnp.maximum(o, d), d),
            old_acc, acc_d)
    counts = old_counts + cnt_d
    values = finalize_reduce(reducer, affected_keys, acc, counts)
    return acc, counts, values


class AccumulatorJob:
    """Incremental job that preserves only <K3,V3> (no MRBGraph).

    Keeps *raw* accumulators host-side (partial sums for mean) so that
    subsequent deltas can be folded in; ``view`` always holds finalized
    values.
    """

    def __init__(self, spec: JobSpec, backend=None):
        if not (spec.reducer.invertible or spec.reducer.kind in
                ("min", "max", "sum", "mean")):
            raise ValueError("reducer is not accumulative")
        self.spec = spec
        self.backend = backend
        self.raw_acc: Dict[str, np.ndarray] = {}
        self.view: ResultView = None  # type: ignore

    def initial_run(self, inp: KV) -> ResultView:
        from repro.core.engine import run_onestep
        # run once, but capture raw accumulators (pre-finalize)
        spec = self.spec

        edges = _delta_map_acc(
            (spec.map_fn,),
            DeltaKV(inp.keys, inp.keys, inp.values, inp.valid,
                    jnp.ones(inp.capacity, jnp.int8)))
        acc, counts = segment_reduce(spec.reducer, edges.k2, edges.v2,
                                     edges.valid, spec.num_keys,
                                     backend=self.backend)
        keys = jnp.arange(spec.num_keys, dtype=jnp.int32)
        values = finalize_reduce(spec.reducer, keys, acc, counts)
        self.raw_acc = {n: np.array(a) for n, a in acc.items()}
        counts_h = np.array(counts)
        self.view = ResultView(
            spec.num_keys, {n: np.array(a) for n, a in values.items()},
            counts_h > 0, counts_h)
        return self.view

    def incremental_run(self, delta: DeltaKV) -> ResultView:
        red = self.spec.reducer
        if red.kind in ("min", "max"):
            if bool(np.any(np.asarray(delta.sign)[np.asarray(delta.valid)] < 0)):
                raise ValueError(
                    f"accumulator path for '{red.kind}' requires insert-only "
                    "deltas (paper §3.5); use the MRBGraph engine instead")
        edges = _delta_map_acc((self.spec.map_fn,), delta)
        eh = edges_to_host(edges)
        affected = np.unique(eh["k2"])
        if affected.size == 0:
            return self.view
        key_cap = next_bucket(affected.size, 64)
        keys_pad = np.full(key_cap, np.int32(2**31 - 1), np.int32)
        keys_pad[:affected.size] = affected.astype(np.int32)
        idx = np.minimum(keys_pad, self.spec.num_keys - 1)

        edge_cap = next_bucket(max(int(eh["k2"].shape[0]), 1), 64)
        v2 = eh["v2"] if isinstance(eh["v2"], dict) else {"v": eh["v2"]}
        dev_edges = _pad_edges(eh["k2"], eh["mk"], v2, eh["sign"], edge_cap)

        old_acc = {n: jnp.asarray(a[idx]) for n, a in self.raw_acc.items()}
        old_counts = jnp.asarray(self.view.counts[idx].astype(np.int32))
        acc, counts, values = _accumulate(red, key_cap,
                                          ops.resolve_backend(self.backend),
                                          dev_edges, jnp.asarray(keys_pad),
                                          old_acc, old_counts)
        sel = slice(0, affected.size)
        for n, a in acc.items():
            self.raw_acc[n][affected] = np.asarray(a)[sel]
        self.view.patch(affected,
                        {n: np.asarray(a)[sel] for n, a in values.items()},
                        np.asarray(counts)[sel])
        return self.view
