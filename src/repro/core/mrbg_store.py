"""MRBG-Store: preservation + retrieval of fine-grain MRBGraph states.

Faithful port of Section 3.4 / 5.2 of the paper, adapted to the TPU node
memory hierarchy:

  Hadoop                         this implementation
  ---------------------------    ------------------------------------------
  local-disk MRBGraph file       host-memory numpy batches ("disk")
  chunk (all edges of one K2)    contiguous record slice within a batch
  in-memory hash chunk index     dense numpy (batch, start, len) arrays
  read cache + dynamic window    simulated windows + bulk numpy reads
  append buffer + offline        append-only batch list + ``compact()``
  compaction

The store is deliberately a *host-side* object: Hadoop's MRBG file lives on
local disk outside the task JVM, and here the preserved states live outside
the jitted computation, feeding padded device buffers to the jitted
merge+reduce (see ``repro.core.incremental``).

All four retrieval policies of Table 4 are implemented (index-only,
single-fix-window, multi-fix-window, multi-dynamic-window) with exact
#read / bytes-read accounting, and the reads are *actually performed* through
a cache buffer so that wall-clock time tracks the simulated I/O.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

# Default knobs (paper: T = 100KB; cache sized like Hadoop's io.sort.mb scale)
DEFAULT_GAP_T = 100 * 1024
DEFAULT_CACHE = 4 * 1024 * 1024
DEFAULT_FIX_WINDOW = 1024 * 1024

POLICIES = ("index-only", "single-fix-window", "multi-fix-window",
            "multi-dynamic-window")


@dataclasses.dataclass
class IOStats:
    n_reads: int = 0
    bytes_read: int = 0
    bytes_useful: int = 0
    cache_hits: int = 0

    def add(self, other: "IOStats") -> None:
        self.n_reads += other.n_reads
        self.bytes_read += other.bytes_read
        self.bytes_useful += other.bytes_useful
        self.cache_hits += other.cache_hits


class _Batch:
    """One sorted segment of chunks, the unit produced by a merge pass."""

    __slots__ = ("k2", "mk", "v2", "sign", "offset")

    def __init__(self, k2, mk, v2, sign, offset: int):
        self.k2 = k2          # [E] int32, sorted
        self.mk = mk          # [E] int32
        self.v2 = v2          # dict name -> [E, ...] array
        self.sign = sign      # [E] int8 (always +1 inside the store)
        self.offset = offset  # global file offset in records

    @property
    def size(self) -> int:
        return int(self.k2.shape[0])


class MRBGStore:
    """Append-only chunk store with a dense per-key index.

    ``num_keys`` is the dense K2 key-space size (one potential chunk per key).
    """

    def __init__(self, num_keys: int, value_bytes: int,
                 policy: str = "multi-dynamic-window",
                 gap_threshold: int = DEFAULT_GAP_T,
                 cache_bytes: int = DEFAULT_CACHE,
                 fix_window_bytes: int = DEFAULT_FIX_WINDOW):
        assert policy in POLICIES, policy
        self.num_keys = num_keys
        self.record_bytes = 8 + value_bytes        # k2 + mk + payload
        self.policy = policy
        self.gap_threshold = gap_threshold
        self.cache_bytes = cache_bytes
        self.fix_window_bytes = fix_window_bytes

        self.batches: List[_Batch] = []
        # chunk index: latest version of each key's chunk
        self.idx_batch = np.full(num_keys, -1, np.int32)
        self.idx_start = np.zeros(num_keys, np.int32)
        self.idx_len = np.zeros(num_keys, np.int32)
        self.stats = IOStats()
        self.file_records = 0                      # includes obsolete chunks
        self.live_records = 0

    # -- helpers ----------------------------------------------------------
    def _rec(self, nbytes: int) -> int:
        """Convert a byte budget to whole records (>=1)."""
        return max(1, nbytes // self.record_bytes)

    def reset_stats(self) -> None:
        self.stats = IOStats()

    def clone(self, policy: Optional[str] = None) -> "MRBGStore":
        s = MRBGStore(self.num_keys, self.record_bytes - 8,
                      policy or self.policy, self.gap_threshold,
                      self.cache_bytes, self.fix_window_bytes)
        s.batches = list(self.batches)
        s.idx_batch = self.idx_batch.copy()
        s.idx_start = self.idx_start.copy()
        s.idx_len = self.idx_len.copy()
        s.file_records = self.file_records
        s.live_records = self.live_records
        return s

    def clear(self) -> None:
        """Drop every batch and index entry in place.

        The serving tier spills a cold tenant's store to disk
        (:func:`store_blobs`/:func:`store_meta`), clears it to release the
        memory, and later repopulates the *same* object with
        :func:`load_store_state` — a bit-for-bit round trip.
        """
        self.batches = []
        self.idx_batch[:] = -1
        self.idx_start[:] = 0
        self.idx_len[:] = 0
        self.file_records = 0
        self.live_records = 0

    # -- ingestion --------------------------------------------------------
    def append(self, k2: np.ndarray, mk: np.ndarray, v2: Dict[str, np.ndarray],
               sign: Optional[np.ndarray] = None) -> None:
        """Append a merge pass's output chunks as a new sorted batch and
        repoint the index (old chunk versions become obsolete in place,
        Section 3.4 'Incremental Storage of MRBGraph Changes')."""
        k2 = np.asarray(k2, np.int32)
        if k2.size == 0:
            return
        mk = np.asarray(mk, np.int32)
        if sign is None:
            sign = np.ones(k2.shape[0], np.int8)
        batch = _Batch(k2, mk, {n: np.asarray(a) for n, a in v2.items()},
                       np.asarray(sign, np.int8), self.file_records)
        bid = len(self.batches)
        self.batches.append(batch)
        self.file_records += batch.size

        # chunk boundaries within the sorted batch
        keys, starts, lens = _chunk_spans(k2)
        self.live_records -= int(self.idx_len[keys].sum())
        self.idx_batch[keys] = bid
        self.idx_start[keys] = starts
        self.idx_len[keys] = lens
        self.live_records += int(lens.sum())

    def mark_deleted(self, keys: np.ndarray) -> None:
        """Drop keys whose chunks became empty after a merge."""
        keys = np.asarray(keys, np.int32)
        if keys.size == 0:
            return
        self.live_records -= int(self.idx_len[keys].sum())
        self.idx_batch[keys] = -1
        self.idx_len[keys] = 0

    # -- retrieval --------------------------------------------------------
    def query(self, keys_sorted: np.ndarray):
        """Retrieve the latest chunks for ``keys_sorted`` (ascending).

        Returns (k2, mk, v2 dict, per_key_len) concatenated in key order.
        I/O is simulated per the configured policy and accounted in
        ``self.stats``; data physically flows through read-cache buffers so
        that wall time follows bytes_read + n_reads.
        """
        keys = np.asarray(keys_sorted, np.int64)
        present = keys[(keys >= 0) & (keys < self.num_keys)]
        present = present[self.idx_batch[present] >= 0]
        per_key_len = np.zeros(keys.shape[0], np.int32)
        mask = (keys >= 0) & (keys < self.num_keys)
        valid_keys = keys[mask]
        lens = np.where(self.idx_batch[valid_keys] >= 0,
                        self.idx_len[valid_keys], 0)
        per_key_len[mask] = lens

        if present.size == 0:
            empty_v2 = None
            return (np.zeros(0, np.int32), np.zeros(0, np.int32), empty_v2,
                    per_key_len)

        plan = self._plan_reads(present)
        out_k2, out_mk, out_v2 = self._execute_reads(present, plan)
        return out_k2, out_mk, out_v2, per_key_len

    # The read planner implements Algorithm 1 (+ the Section 5.2
    # multi-dynamic-window extension).  It returns, for each requested key,
    # which simulated read supplies it; reads are (batch, start, length).
    def _plan_reads(self, keys: np.ndarray):
        bids = self.idx_batch[keys]
        starts = self.idx_start[keys]
        lens = self.idx_len[keys]
        n = keys.shape[0]
        reads: List[tuple] = []          # (batch, start_rec, len_rec)
        src = np.zeros(n, np.int32)      # read id serving key i

        cache_rec = self._rec(self.cache_bytes)
        gap_rec = self._rec(self.gap_threshold)
        fix_rec = self._rec(self.fix_window_bytes)

        if self.policy == "index-only":
            for i in range(n):
                src[i] = len(reads)
                reads.append((bids[i], starts[i], lens[i]))
            self.stats.n_reads += n
            rb = int(lens.sum()) * self.record_bytes
            self.stats.bytes_read += rb
            self.stats.bytes_useful += rb
            return reads, src

        if self.policy == "single-fix-window":
            # One window over the global file; chunk positions jump between
            # batches, defeating the window (Table 4's pathological case).
            win = (0, -1, -1)  # global [lo, hi) in records, serving read id
            for i in range(n):
                batch = self.batches[bids[i]]
                gpos = batch.offset + starts[i]
                if win[0] <= gpos and gpos + lens[i] <= win[1]:
                    self.stats.cache_hits += 1
                    src[i] = win[2]
                else:
                    w = max(fix_rec, int(lens[i]))
                    rid = len(reads)
                    # data past the batch end is useless for chunk hits:
                    # clamp the *hit* range (stats still count w bytes).
                    hit_end = min(gpos + w, batch.offset + batch.size)
                    win = (gpos, hit_end, rid)
                    reads.append((int(bids[i]), int(starts[i]), w))
                    self.stats.n_reads += 1
                    self.stats.bytes_read += w * self.record_bytes
                    src[i] = rid
            self.stats.bytes_useful += int(lens.sum()) * self.record_bytes
            return reads, src

        # multi-window policies: one window per batch (Section 5.2)
        windows: Dict[int, tuple] = {}
        for i in range(n):
            b, s, l = int(bids[i]), int(starts[i]), int(lens[i])
            win = windows.get(b)
            if win is not None and win[0] <= s and s + l <= win[1]:
                self.stats.cache_hits += 1
                src[i] = win[2]
                continue
            if self.policy == "multi-fix-window":
                w = max(fix_rec, l)
            else:  # multi-dynamic-window: Algorithm 1 over same-batch keys
                w = l
                j = i
                last_end = s + l
                while True:
                    j = _next_in_batch(bids, j, b)
                    if j < 0:
                        break
                    nxt_start, nxt_len = int(starts[j]), int(lens[j])
                    gap = nxt_start - last_end
                    if gap < 0:   # already covered / out of order guard
                        break
                    if gap >= gap_rec:
                        break
                    if (w + gap + nxt_len) > cache_rec:
                        break
                    w = w + gap + nxt_len
                    last_end = nxt_start + nxt_len
                w = min(w, max(cache_rec, l))
            rid = len(reads)
            reads.append((b, s, w))
            windows[b] = (s, s + w, rid)
            src[i] = rid
            self.stats.n_reads += 1
            self.stats.bytes_read += w * self.record_bytes
        self.stats.bytes_useful += int(lens.sum()) * self.record_bytes
        return reads, src

    def _execute_reads(self, keys: np.ndarray, plan):
        reads, src = plan
        # 1) physically perform each simulated read into a cache buffer
        caches = []
        for (b, s, w) in reads:
            batch = self.batches[b]
            end = min(s + w, batch.size)
            caches.append((batch, int(s),
                           {"k2": batch.k2[s:end].copy(),
                            "mk": batch.mk[s:end].copy(),
                            "v2": {n: a[s:end].copy()
                                   for n, a in batch.v2.items()}}))
        # 2) slice every requested chunk out of its cache
        k2_parts, mk_parts = [], []
        v2_parts: Dict[str, list] = {}
        for i in range(keys.shape[0]):
            k = int(keys[i])
            b, s, l = (int(self.idx_batch[k]), int(self.idx_start[k]),
                       int(self.idx_len[k]))
            batch, cstart, cache = caches[src[i]]
            lo = s - cstart
            k2_parts.append(cache["k2"][lo:lo + l])
            mk_parts.append(cache["mk"][lo:lo + l])
            for nme, arr in cache["v2"].items():
                v2_parts.setdefault(nme, []).append(arr[lo:lo + l])
        out_k2 = np.concatenate(k2_parts) if k2_parts else np.zeros(0, np.int32)
        out_mk = np.concatenate(mk_parts) if mk_parts else np.zeros(0, np.int32)
        out_v2 = {n: np.concatenate(p) for n, p in v2_parts.items()}
        return out_k2, out_mk, out_v2

    # -- maintenance ------------------------------------------------------
    def compact(self) -> int:
        """Offline reconstruction (paper: 'the MRBGraph file is reconstructed
        off-line when the worker is idle'): rewrite a single batch holding
        only the latest version of every chunk.  Returns the file bytes
        reclaimed (the multi-tenant server's budget enforcement unit)."""
        before = self.file_bytes()
        live = np.nonzero(self.idx_batch >= 0)[0]
        if live.size == 0:
            self.batches = []
            self.file_records = 0
            return before
        k2, mk, v2, _ = self.query(live)
        self.batches = []
        self.file_records = 0
        self.idx_batch[:] = -1
        self.idx_len[:] = 0
        self.live_records = 0
        self.append(k2, mk, v2)
        return before - self.file_bytes()

    @property
    def n_batches(self) -> int:
        return len(self.batches)

    def file_bytes(self) -> int:
        return self.file_records * self.record_bytes

    def live_bytes(self) -> int:
        return self.live_records * self.record_bytes

    def obsolete_bytes(self) -> int:
        """Bytes held by superseded chunk versions (reclaimable)."""
        return (self.file_records - self.live_records) * self.record_bytes


# ---------------------------------------------------------------------------
# Store (de)serialization: the one batch/index npz layout, shared by the
# per-iteration engine checkpoints (repro.core.ft) and the Session
# checkpoints (repro.api.ckpt)
# ---------------------------------------------------------------------------

def store_blobs(store: "MRBGStore") -> Dict[str, np.ndarray]:
    """Every array of the store, keyed for one flat ``np.savez``."""
    blobs = {"idx_batch": store.idx_batch, "idx_start": store.idx_start,
             "idx_len": store.idx_len}
    for i, b in enumerate(store.batches):
        blobs[f"b{i}_k2"] = b.k2
        blobs[f"b{i}_mk"] = b.mk
        blobs[f"b{i}_sign"] = b.sign
        for n, a in b.v2.items():
            blobs[f"b{i}_v2_{n}"] = a
    return blobs


def store_meta(store: "MRBGStore") -> Dict[str, Any]:
    """The non-array state needed to rebuild the store around the blobs."""
    return {"offsets": [b.offset for b in store.batches],
            "v2_names": sorted({n for b in store.batches for n in b.v2}),
            "file_records": store.file_records,
            "live_records": store.live_records,
            "value_bytes": store.record_bytes - 8,
            "policy": store.policy}


def load_store_state(store: "MRBGStore", npz, meta: Dict[str, Any]) -> None:
    """Populate a freshly constructed store from store_blobs/store_meta."""
    names = meta["v2_names"]
    for i, off in enumerate(meta["offsets"]):
        v2 = {n: npz[f"b{i}_v2_{n}"] for n in names
              if f"b{i}_v2_{n}" in npz.files}
        store.batches.append(_Batch(npz[f"b{i}_k2"], npz[f"b{i}_mk"], v2,
                                    npz[f"b{i}_sign"], off))
    store.idx_batch = npz["idx_batch"].copy()
    store.idx_start = npz["idx_start"].copy()
    store.idx_len = npz["idx_len"].copy()
    store.file_records = meta["file_records"]
    store.live_records = meta["live_records"]


def _chunk_spans(sorted_k2: np.ndarray):
    """Return (unique keys, start offsets, lengths) of each chunk."""
    keys, starts = np.unique(sorted_k2, return_index=True)
    lens = np.diff(np.append(starts, sorted_k2.shape[0])).astype(np.int32)
    return keys.astype(np.int64), starts.astype(np.int32), lens


def _next_in_batch(bids: np.ndarray, j: int, b: int) -> int:
    """Index of the next requested key that lives in batch ``b`` after j."""
    for k in range(j + 1, bids.shape[0]):
        if bids[k] == b:
            return k
    return -1
