"""Fault tolerance for the incremental iterative engine (paper Section 6).

i²MapReduce checkpoints the prime-Reduce output state *and* the MRBGraph
file every iteration; on failure the interdependent prime Map/Reduce pair is
rescheduled together and resumes from the checkpoint.  Here:

  * ``checkpoint_job`` snapshots (state values, CPC accumulators, MRBG-Store
    batches + chunk index, structure mirror) atomically per iteration;
  * ``restore_job`` rebuilds an ``IncrIterJob`` byte-identically — tests
    prove a killed-and-restored job produces the same refresh results;
  * ``FailureInjector`` deterministically raises at a chosen iteration to
    exercise the recovery path (the Fig. 13 experiment);
  * ``SkewMonitor`` implements the straggler/load-balance hook (§6.2, the
    paper leaves it as future work): it watches per-partition edge counts
    and emits a re-partition plan (splitting the heaviest partitions) that
    ``partition_struct`` can apply — beyond-paper but in the paper's spirit.
"""
from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Dict, Optional

import numpy as np

from repro.core.incr_iter import IncrIterJob
from repro.core.iterative import IterSpec, State
from repro.core.mrbg_store import (
    MRBGStore, load_store_state, store_blobs, store_meta,
)

import jax.numpy as jnp


def checkpoint_job(job: IncrIterJob, root: str, iteration: int) -> Path:
    rootp = Path(root)
    rootp.mkdir(parents=True, exist_ok=True)
    tmp = rootp / f"it_{iteration:06d}.tmp"
    final = rootp / f"it_{iteration:06d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()

    np.savez(tmp / "state.npz",
             **{f"sv_{k}": np.asarray(v) for k, v in job.state.values.items()},
             cpc=job.cpc_accum,
             **{f"ev_{k}": np.asarray(v)
                for k, v in job.emitted_values.items()},
             struct_valid=job.struct_valid, struct_keys=job.struct_keys,
             **{f"st_{k}": v for k, v in job.struct_values.items()})
    # MRBG-Store: batches + index (the paper's per-iteration MRBG checkpoint)
    store = job.store
    np.savez(tmp / "mrbg.npz", **store_blobs(store))
    meta = {"iteration": iteration, "n_batches": store.n_batches,
            "mrbg_on": job.mrbg_on, **store_meta(store)}
    (tmp / "meta.json").write_text(json.dumps(meta))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_job(spec: IterSpec, root: str,
                iteration: Optional[int] = None) -> IncrIterJob:
    rootp = Path(root)
    its = sorted(rootp.glob("it_??????"))
    assert its, "no checkpoints"
    d = its[-1] if iteration is None else rootp / f"it_{iteration:06d}"
    meta = json.loads((d / "meta.json").read_text())
    st = np.load(d / "state.npz")
    from repro.core.kvstore import KV, make_kv

    struct_vals = {k[3:]: st[k] for k in st.files if k.startswith("st_")}
    struct = make_kv(st["struct_keys"],
                     {k: jnp.asarray(v) for k, v in struct_vals.items()},
                     st["struct_valid"])
    job = IncrIterJob(spec, struct, value_bytes=meta["value_bytes"],
                      policy=meta["policy"])
    sv = {k[3:]: jnp.asarray(st[k]) for k in st.files if k.startswith("sv_")}
    ev = {k[3:]: jnp.asarray(st[k]) for k in st.files if k.startswith("ev_")}
    job.state = State(sv, jnp.ones(spec.num_state, jnp.bool_))
    job.emitted_values = ev
    job.cpc_accum = st["cpc"].copy()
    job.mrbg_on = meta["mrbg_on"]

    load_store_state(job.store, np.load(d / "mrbg.npz"), meta)
    return job


class FailureInjector:
    """Deterministically fail at iteration k (Fig. 13 experiment)."""

    def __init__(self, fail_at: int):
        self.fail_at = fail_at
        self.fired = False

    def __call__(self, iteration: int):
        if iteration == self.fail_at and not self.fired:
            self.fired = True
            raise RuntimeError(f"injected worker failure @ it {iteration}")


class SkewMonitor:
    """Straggler detection + re-partition planning (beyond-paper §6.2).

    Tracks per-partition work (edge counts / elapsed time); when the max
    exceeds ``ratio`` x median, proposes moving records from the heaviest
    partitions to the lightest (preserving order, as SkewTune does, so the
    output can be reconstructed by concatenation).
    """

    def __init__(self, ratio: float = 1.5):
        self.ratio = ratio
        self.history = []

    def observe(self, per_partition_work: np.ndarray):
        self.history.append(np.asarray(per_partition_work))

    def plan(self) -> Optional[Dict[int, int]]:
        if not self.history:
            return None
        w = self.history[-1].astype(np.float64)
        med = max(np.median(w), 1e-9)
        if w.max() <= self.ratio * med:
            return None
        heavy = int(np.argmax(w))
        light = int(np.argmin(w))
        move = int((w[heavy] - med) / max(w[heavy], 1) *
                   100)  # % of heavy partition's records to migrate
        return {"from": heavy, "to": light, "percent": max(1, min(50, move))}
