"""Fine-grain incremental one-step processing (paper Section 3.3).

Pipeline for a delta input ΔD against a preserved job A:

  1. *Incremental Map*: invoke the Map function only on the changed records;
     edges emitted by '-' records become tombstones (sign = -1).
  2. *Shuffle*: sort the delta MRBGraph by (K2, MK).
  3. *State retrieval*: the affected K2 set is queried against the MRBG-Store
     (host side, read-window policies of Section 3.4/5.2).
  4. *Merge*: preserved chunks + delta edges are joined with a stable sort;
     for each (K2, MK) the **last** version wins and tombstones delete
     (an update arrives as '-' then '+', exactly as in the paper).
  5. *Incremental Reduce*: segment-reduce only the affected K2 groups and
     patch the dense result view.
  6. *State preservation*: merged chunks are appended to the MRBG-Store and
     the chunk index repointed (obsolete chunks compacted offline).

Everything on-device is jitted with power-of-two bucketed capacities so that
the work (and the number of distinct XLA programs) scales with |Δ|, not |D|.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import JobSpec, run_onestep
from repro.core.kvstore import (
    INVALID_KEY, KV, Edges, Reducer, edges_to_host, finalize_reduce, make_kv,
    next_bucket, sort_edges,
)
from repro.core.mrbg_store import MRBGStore
from repro.kernels import jitcache, ops


class DeltaKV(NamedTuple):
    """A delta input: kv-pairs marked '+' (insert) or '-' (delete).

    An update is encoded as a deletion followed by an insertion of the same
    key (paper Section 3.1); both rows carry the same record id so the
    replayed Map instance overwrites its previous edges.
    """

    keys: jax.Array          # [N] int32 (K1; semantic only, not used by engine)
    record_ids: jax.Array    # [N] int32 Map-instance identity (drives MK)
    values: Any              # pytree of [N, ...]
    valid: jax.Array         # [N] bool
    sign: jax.Array          # [N] int8 (+1 / -1)

    @property
    def capacity(self) -> int:
        return self.keys.shape[0]


def make_delta(record_ids, values, sign, *, keys=None,
               valid=None) -> DeltaKV:
    """Build a :class:`DeltaKV`.

    ``keys`` (the semantic K1) defaults to ``record_ids`` — for every engine
    app the Map-instance identity *is* the record key, so the historical
    ``make_delta(rid, rid, ...)`` spelling is no longer needed (and the
    pre-``repro.api`` positional order is no longer accepted: ``keys`` and
    ``valid`` are keyword-only).
    """
    record_ids = jnp.asarray(record_ids, jnp.int32)
    if keys is None:
        keys = record_ids
    keys = jnp.asarray(keys, jnp.int32)
    if valid is None:
        valid = jnp.ones(keys.shape[0], jnp.bool_)
    return DeltaKV(keys, record_ids,
                   jax.tree.map(jnp.asarray, values),
                   jnp.asarray(valid, jnp.bool_), jnp.asarray(sign, jnp.int8))


def pad_delta(delta: DeltaKV, capacity: int) -> DeltaKV:
    """Pad a delta to a bucketed row capacity (padding rows are invalid).

    Every consumer of a :class:`DeltaKV` masks on ``valid``, so padding is
    semantically inert; what it buys is *shape discipline*: deltas whose
    row counts land in the same bucket share one traced/compiled refresh
    program instead of retracing per distinct row count.
    """
    n = delta.capacity
    if capacity < n:
        raise ValueError(f"pad_delta capacity {capacity} < delta rows {n}")
    if capacity == n:
        return delta

    def ext(a):
        pad = jnp.zeros((capacity - n,) + a.shape[1:], a.dtype)
        return jnp.concatenate([a, pad])

    return DeltaKV(ext(delta.keys), ext(delta.record_ids),
                   jax.tree.map(ext, delta.values),
                   ext(delta.valid), ext(delta.sign))


def apply_delta_host(keys: np.ndarray, values: Dict[str, np.ndarray],
                     valid: np.ndarray, delta: DeltaKV) -> None:
    """Apply a signed delta to a host-side record mirror, in place.

    The mirror plays the role of the partitioned input file on HDFS: '-'
    rows invalidate a record slot, '+' rows (re)write it.
    """
    rid = np.asarray(delta.record_ids)
    sgn = np.asarray(delta.sign)
    dvalid = np.asarray(delta.valid)
    dkeys = np.asarray(delta.keys)
    for i in np.nonzero(dvalid)[0]:
        r = int(rid[i])
        if sgn[i] < 0:
            valid[r] = False
        else:
            valid[r] = True
            keys[r] = int(dkeys[i])
            for n, a in values.items():
                a[r] = np.asarray(delta.values[n])[i]


class ResultView:
    """Host-side dense view of the job's current output <K3,V3> (K3 == K2).

    Plays the role of the job's output file on HDFS: incremental runs patch
    only the affected keys.
    """

    def __init__(self, num_keys: int, values: Dict[str, np.ndarray],
                 valid: np.ndarray, counts: np.ndarray):
        self.num_keys = num_keys
        self.values = values
        self.valid = valid
        self.counts = counts

    @classmethod
    def from_job(cls, num_keys: int, results, counts) -> "ResultView":
        values = {n: np.array(a) for n, a in results.values.items()}
        return cls(num_keys, values, np.array(results.valid),
                   np.array(counts))

    def patch(self, keys: np.ndarray, values: Dict[str, np.ndarray],
              counts: np.ndarray) -> None:
        keys = np.asarray(keys)
        sel = keys < self.num_keys
        k = keys[sel]
        for name, arr in values.items():
            self.values[name][k] = np.asarray(arr)[sel]
        self.counts[k] = np.asarray(counts)[sel]
        self.valid[k] = self.counts[k] > 0

    def as_dict(self) -> Dict[str, np.ndarray]:
        return {n: np.where(
            self.valid.reshape((-1,) + (1,) * (a.ndim - 1)), a, 0)
            for n, a in self.values.items()}


class IncrementalJob:
    """Owns the preserved MRBGraph + result view of one MapReduce job."""

    def __init__(self, spec: JobSpec, value_bytes: int = 8,
                 policy: str = "multi-dynamic-window",
                 backend: Optional[str] = None):
        self.spec = spec
        self.backend = backend
        self.store = MRBGStore(spec.num_keys, value_bytes, policy=policy)
        self.view: Optional[ResultView] = None

    # -- initial run -------------------------------------------------------
    def initial_run(self, inp: KV) -> ResultView:
        res = run_onestep(self.spec, inp, preserve=True,
                          backend=self.backend)
        host = edges_to_host(res.edges)
        self.store.append(host["k2"], host["mk"], _v2_dict(host["v2"]))
        self.view = ResultView.from_job(self.spec.num_keys, res.results,
                                        res.counts)
        return self.view

    # -- incremental run ---------------------------------------------------
    def incremental_run(self, delta: DeltaKV) -> ResultView:
        assert self.view is not None, "initial_run first"
        stats = incremental_onestep(self.spec, delta, self.store, self.view,
                                    backend=self.backend)
        return self.view

    def refresh_stats(self) -> Dict[str, Any]:
        return {"store_batches": self.store.n_batches,
                "store_bytes": self.store.file_bytes(),
                "live_bytes": self.store.live_bytes(),
                "io": self.store.stats}


def _v2_dict(v2) -> Dict[str, np.ndarray]:
    if isinstance(v2, dict):
        return v2
    return {"v": v2}


def _v2_tree(v2_dict, template):
    if isinstance(template, dict):
        return v2_dict
    return v2_dict["v"]


# ---------------------------------------------------------------------------
# The jitted incremental kernel: delta map -> merge -> incremental reduce
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(0,))
def _delta_map(spec_static, delta: DeltaKV) -> Edges:
    jitcache.count_trace("incremental._delta_map")
    map_fn, backend = spec_static
    kv = KV(delta.keys, delta.values, delta.valid)
    edges = map_fn(kv, delta.sign)
    return sort_edges(edges, backend=backend)


@functools.partial(jax.jit, static_argnums=(0, 1, 2), donate_argnums=(3,))
def _merge_reduce(reducer: Reducer, key_cap: int, backend: Optional[str],
                  combined: Edges, affected_keys: jax.Array):
    """Join preserved chunks with delta edges; reduce affected groups.

    ``combined`` holds preserved rows first, then delta rows (so that the
    stable shuffle sort leaves equal-(k2,mk) delta rows *after* the
    preserved version and last-writer-wins overrides).  It is donated:
    the buffers are built fresh per refresh and the sorted merge aliases
    them in place instead of paying another O(capacity) copy.
    ``affected_keys`` is sorted ascending, padded with INVALID_KEY.
    Returns (merged edges [sorted, valid-masked], values pytree [key_cap],
    counts [key_cap]).
    """
    jitcache.count_trace("incremental._merge_reduce")
    # the whole sort -> last-writer-wins -> segment-reduce chain lives in
    # ops.shuffle_reduce (fused into one kernel on the pallas backend)
    sr = ops.shuffle_reduce(reducer, combined.k2, combined.mk, combined.v2,
                            combined.valid, combined.sign, affected_keys,
                            backend=backend)
    n = sr.k2.shape[0]
    merged = Edges(sr.k2, sr.mk, sr.values, sr.live, jnp.ones(n, jnp.int8))
    values = finalize_reduce(reducer, affected_keys, sr.acc, sr.counts)
    return merged, values, sr.counts


def incremental_onestep(spec: JobSpec, delta: DeltaKV, store: MRBGStore,
                        view: ResultView,
                        backend: Optional[str] = None) -> Dict[str, Any]:
    """One incremental refresh; patches ``view`` and ``store`` in place."""
    bk = ops.resolve_backend(backend)
    # 1-2) incremental Map + shuffle of the delta MRBGraph
    delta_edges = _delta_map((spec.map_fn, bk), delta)
    dh = edges_to_host(delta_edges, sorted_valid_first=True)

    # 3) affected keys, queried against the store in sorted order
    affected = np.unique(dh["k2"])
    if affected.size == 0:
        return {"affected": 0, "merged": 0}
    pk2, pmk, pv2, _plen = store.query(affected)
    if pv2 is None:
        pv2 = {n: np.zeros((0,) + a.shape[1:], a.dtype)
               for n, a in _v2_dict(dh["v2"]).items()}

    # 4-5) pad to buckets and run the jitted merge+reduce
    key_cap = next_bucket(affected.size, 64)
    dsign = np.asarray(dh["sign"], np.int8)
    combined = _combine_edges(pk2, pmk, pv2,
                              dh["k2"], dh["mk"], _v2_dict(dh["v2"]), dsign)
    keys_pad = np.full(key_cap, np.int32(2**31 - 1), np.int32)
    keys_pad[:affected.size] = affected.astype(np.int32)

    merged, values, counts = _merge_reduce(spec.reducer, key_cap, bk,
                                           combined, jnp.asarray(keys_pad))

    # 6) preserve merged chunks + patch results
    mh = edges_to_host(merged)
    store.append(mh["k2"], mh["mk"], _v2_dict(mh["v2"]))
    counts_h = np.asarray(counts)[:affected.size]
    gone = affected[counts_h == 0]
    store.mark_deleted(gone)
    vals_h = {n: np.asarray(a)[:affected.size]
              for n, a in _v2_dict(values).items()}
    view.patch(affected, vals_h, counts_h)
    return {"affected": int(affected.size), "merged": int(mh["k2"].shape[0]),
            "deleted_keys": int(gone.size)}


def _pad_edges(k2: np.ndarray, mk: np.ndarray, v2: Dict[str, np.ndarray],
               sign: np.ndarray, cap: int) -> Edges:
    n = int(k2.shape[0])
    ik = np.int32(2**31 - 1)
    out_k2 = np.full(cap, ik, np.int32); out_k2[:n] = k2
    out_mk = np.full(cap, ik, np.int32); out_mk[:n] = mk
    out_sign = np.zeros(cap, np.int8); out_sign[:n] = sign
    valid = np.zeros(cap, bool); valid[:n] = True
    out_v2 = {}
    for name, a in v2.items():
        buf = np.zeros((cap,) + a.shape[1:], a.dtype)
        buf[:n] = a
        out_v2[name] = buf
    return Edges(jnp.asarray(out_k2), jnp.asarray(out_mk),
                 jax.tree.map(jnp.asarray, out_v2),
                 jnp.asarray(valid), jnp.asarray(out_sign))


def _combine_edges(pk2: np.ndarray, pmk: np.ndarray,
                   pv2: Dict[str, np.ndarray],
                   dk2: np.ndarray, dmk: np.ndarray,
                   dv2: Dict[str, np.ndarray], dsign: np.ndarray,
                   minimum: int = 64) -> Edges:
    """One bucketed host buffer: preserved rows first, then delta rows.

    Feeding :func:`_merge_reduce` a single pre-concatenated buffer (instead
    of two separately padded ones concatenated on device) keeps the shape
    space one-dimensional — one bucket per *total* edge count — and lets
    the jit donate the buffer to the in-place shuffle sort.
    """
    n_p, n_d = int(pk2.shape[0]), int(dk2.shape[0])
    cap = next_bucket(max(n_p + n_d, 1), minimum)
    ik = np.int32(2**31 - 1)
    out_k2 = np.full(cap, ik, np.int32)
    out_k2[:n_p] = pk2; out_k2[n_p:n_p + n_d] = dk2
    out_mk = np.full(cap, ik, np.int32)
    out_mk[:n_p] = pmk; out_mk[n_p:n_p + n_d] = dmk
    out_sign = np.zeros(cap, np.int8)
    out_sign[:n_p] = 1; out_sign[n_p:n_p + n_d] = dsign
    valid = np.zeros(cap, bool); valid[:n_p + n_d] = True
    out_v2 = {}
    for name, a in dv2.items():
        buf = np.zeros((cap,) + a.shape[1:], a.dtype)
        buf[:n_p] = pv2[name]; buf[n_p:n_p + n_d] = a
        out_v2[name] = buf
    return Edges(jnp.asarray(out_k2), jnp.asarray(out_mk),
                 jax.tree.map(jnp.asarray, out_v2),
                 jnp.asarray(valid), jnp.asarray(out_sign))
