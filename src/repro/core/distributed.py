"""Distributed MapReduce shuffle on a device mesh (shard_map + all_to_all).

Maps the paper's Hadoop runtime onto a TPU pod:

  * partitions: one per device along the ``data`` axis (or the flattened
    ("pod", "data") axes multi-pod) — the paper's n Map/Reduce task pairs.
  * dependency-aware partitioning (Section 4.3): structure records are
    placed by ``hash(project(SK))`` and state kv-pairs by ``hash(DK)``
    with the *same* hash, so the interdependent pairs are co-located and
    the prime-Reduce output lands on its prime-Map consumer with **zero
    backward transfer** — the co-location scheduling of Fig. 6.
  * shuffle: each shard buckets its intermediate edges by destination
    partition (owner = K2 mod P — a perfect hash for dense int keys) into
    fixed-capacity send buffers, and one ``jax.lax.all_to_all`` realizes the
    exchange.  Multi-pod runs flatten ("pod", "data") into a single exchange
    axis (XLA schedules the intra- vs cross-pod legs); a two-stage
    hierarchical exchange that combines same-destination edges intra-pod
    before crossing pods is the natural next optimization for skewed keys.
  * reduce: an MXU-friendly segment reduction over the locally owned dense
    key range (local key = K2 // P).

Static capacities make the exchange shape-stable; overflowing edges are
counted (and surfaced) rather than silently dropped.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.kvstore import (
    INVALID_KEY, KV, Edges, Reducer, finalize_reduce, segment_reduce,
)
from repro.core.iterative import IterSpec, State
from repro.kernels import ops


def partition_of(keys: jax.Array, n: int) -> jax.Array:
    """Equation (1)/(2): the shared partition hash (dense int keys)."""
    return jnp.mod(keys.astype(jnp.uint32), jnp.uint32(n)).astype(jnp.int32)


def partition_struct(spec: IterSpec, struct_keys: np.ndarray,
                     struct_values: Dict[str, np.ndarray],
                     valid: np.ndarray, n_parts: int, cap: int):
    """Host-side pre-partitioning of structure data (Equation 2)."""
    import jax as _jax
    dks = np.asarray(_jax.jit(spec.project)(jnp.asarray(struct_keys)))
    pid = (dks.astype(np.uint32) % n_parts).astype(np.int32)
    out_keys = np.full((n_parts, cap), 2**31 - 1, np.int32)
    out_vals = {n: np.zeros((n_parts, cap) + a.shape[1:], a.dtype)
                for n, a in struct_values.items()}
    out_valid = np.zeros((n_parts, cap), bool)
    for p in range(n_parts):
        sel = np.nonzero(valid & (pid == p))[0]
        assert sel.size <= cap, f"partition {p} overflow ({sel.size}>{cap})"
        out_keys[p, :sel.size] = struct_keys[sel]
        for n, a in struct_values.items():
            out_vals[n][p, :sel.size] = a[sel]
        out_valid[p, :sel.size] = True
    return out_keys, out_vals, out_valid


def partition_state(state_values: Dict[str, np.ndarray], num_state: int,
                    n_parts: int):
    """Equation (1): state kv-pair DK lives on shard DK mod P at local row
    DK // P (dense layout)."""
    rows = (num_state + n_parts - 1) // n_parts
    out = {}
    for n, a in state_values.items():
        buf = np.zeros((n_parts, rows) + a.shape[1:], a.dtype)
        for p in range(n_parts):
            ids = np.arange(p, num_state, n_parts)
            buf[p, :ids.size] = a[ids]
        out[n] = buf
    return out


def unpartition_state(parts: Dict[str, np.ndarray], num_state: int):
    out = {}
    for n, a in parts.items():
        n_parts, rows = a.shape[:2]
        flat = np.zeros((num_state,) + a.shape[2:], a.dtype)
        for p in range(n_parts):
            ids = np.arange(p, num_state, n_parts)
            flat[ids] = a[p, :ids.size]
        out[n] = flat
    return out


# ---------------------------------------------------------------------------
# The distributed iteration (one prime Map -> shuffle -> prime Reduce)
# ---------------------------------------------------------------------------

def make_distributed_step(spec: IterSpec, mesh: Mesh, axis: str,
                          shuffle_cap: int, *, hierarchical: bool = False,
                          pod_axis: Optional[str] = None,
                          backend: Optional[str] = None):
    """Build the jitted SPMD iteration over ``axis`` (+ optional pod axis).

    shuffle_cap: per (src, dst) shard edge capacity for the all_to_all.
    ``backend`` selects the shard-local shuffle/reduce implementation
    (resolved here, outside the jit, so rebuilding the step retraces).
    """
    bk = ops.resolve_backend(backend)
    n_parts = mesh.shape[axis] * (mesh.shape[pod_axis] if pod_axis else 1)
    axes = (pod_axis, axis) if pod_axis else (axis,)
    num_state = spec.num_state
    rows = (num_state + n_parts - 1) // n_parts

    def local_iter(struct_keys, struct_vals, struct_valid, state_vals):
        """Runs per shard.  struct_* [1, cap, ...]; state [1, rows, ...]."""
        struct_keys = struct_keys[0]
        struct_vals = jax.tree.map(lambda a: a[0], struct_vals)
        struct_valid = struct_valid[0]
        state_local = jax.tree.map(lambda a: a[0], state_vals)

        # prime Map: gather interdependent state (co-located by Eq. 1+2)
        if spec.replicate_state:
            dv = state_local
        else:
            dks = spec.project(struct_keys)
            dv = jax.tree.map(
                lambda a: jnp.take(a, dks // n_parts, axis=0), state_local)
        sign = jnp.ones(struct_keys.shape[0], jnp.int8)
        edges = spec.map_fn(KV(struct_keys, struct_vals, struct_valid),
                            dv, sign)

        # shuffle: bucket by destination partition
        dest = partition_of(edges.k2, n_parts)
        dest = jnp.where(edges.valid, dest, n_parts)
        # stable sort by dest (via the backend dispatcher), then rank
        # within dest
        sorted_dest = ops.sort_pairs(dest, None, num_keys=1, backend=bk)
        sdest = sorted_dest.k2
        order = sorted_dest.perm
        rank = jnp.arange(sdest.shape[0]) - jnp.searchsorted(
            sdest, sdest, side="left")
        send_k2 = jnp.full((n_parts, shuffle_cap), INVALID_KEY, jnp.int32)
        send_mk = jnp.full((n_parts, shuffle_cap), INVALID_KEY, jnp.int32)
        send_valid = jnp.zeros((n_parts, shuffle_cap), jnp.bool_)
        ok = (sdest < n_parts) & (rank < shuffle_cap)
        src_idx = order
        drop = jnp.sum((rank >= shuffle_cap) & (sdest < n_parts))

        def scat(buf, vals):
            return buf.at[jnp.where(ok, sdest, n_parts - 1),
                          jnp.where(ok, rank, 0)].set(
                jnp.where(_bshape(ok, vals), vals, buf.dtype.type(0)),
                mode="drop")

        g = lambda a: jnp.take(a, src_idx, axis=0)
        sk2 = g(edges.k2)
        smk = g(edges.mk)
        sval = g(edges.valid)
        send_k2 = send_k2.at[sdest, rank].set(
            jnp.where(ok & sval, sk2, INVALID_KEY), mode="drop")
        send_mk = send_mk.at[sdest, rank].set(
            jnp.where(ok & sval, smk, INVALID_KEY), mode="drop")
        send_valid = send_valid.at[sdest, rank].set(ok & sval, mode="drop")
        send_v2 = {}
        for name, leaf in edges.v2.items():
            sl = g(leaf)
            buf = jnp.zeros((n_parts, shuffle_cap) + sl.shape[1:], sl.dtype)
            m = (ok & sval).reshape((-1,) + (1,) * (sl.ndim - 1))
            send_v2[name] = buf.at[sdest, rank].set(
                jnp.where(m, sl, 0), mode="drop")

        # the exchange: one all_to_all over the partition axis (flattened
        # across pods), or hierarchical intra-pod -> cross-pod
        a2a = functools.partial(jax.lax.all_to_all, axis_name=axes,
                                split_axis=0, concat_axis=0, tiled=False)
        recv_k2 = a2a(send_k2)
        recv_mk = a2a(send_mk)
        recv_valid = a2a(send_valid)
        recv_v2 = {n: a2a(v) for n, v in send_v2.items()}

        # prime Reduce over the local dense key range (local = k2 // P)
        rk2 = recv_k2.reshape(-1)
        rvalid = recv_valid.reshape(-1)
        local_ids = rk2 // n_parts
        rv2 = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), recv_v2)
        acc, counts = segment_reduce(spec.reducer,
                                     jnp.where(rvalid, local_ids, rows),
                                     rv2, rvalid, rows, backend=bk)
        my = jax.lax.axis_index(axes[-1])
        if pod_axis:
            my = my + jax.lax.axis_index(pod_axis) * mesh.shape[axis]
        keys = jnp.arange(rows, dtype=jnp.int32) * n_parts + my
        new_vals = finalize_reduce(spec.reducer, keys, acc, counts)
        # zero backward transfer: output stays on this shard (Fig. 6)
        return (jax.tree.map(lambda a: a[None], new_vals),
                counts[None], drop[None])

    pspec_struct = P(axes)
    pspec_state = P(axes)
    shmap = shard_map(
        local_iter, mesh=mesh,
        in_specs=(pspec_struct, pspec_struct, pspec_struct, pspec_state),
        out_specs=(pspec_state, pspec_state, P(axes)),
        check_rep=False)
    return jax.jit(shmap)


def _bshape(mask, vals):
    return mask.reshape((-1,) + (1,) * (vals.ndim - 1))


def run_distributed(spec: IterSpec, mesh: Mesh, struct_parts, state_parts,
                    *, axis: str = "data", pod_axis: Optional[str] = None,
                    shuffle_cap: int = 4096, max_iters: int = 50,
                    tol: float = 1e-6, backend: Optional[str] = None):
    """Drive the distributed prime loop to convergence.

    Engine-internal: user code drives this through ``repro.api.Session``
    with ``RunConfig(mesh=...)``.
    """
    step = make_distributed_step(spec, mesh, axis, shuffle_cap,
                                 pod_axis=pod_axis, backend=backend)
    skeys, svals, svalid = struct_parts
    state = state_parts
    diff_fn = spec.difference
    history = {"iters": 0, "max_change": [], "dropped": 0}
    for it in range(max_iters):
        new_vals, counts, drop = step(jnp.asarray(skeys),
                                      jax.tree.map(jnp.asarray, svals),
                                      jnp.asarray(svalid),
                                      jax.tree.map(jnp.asarray, state))
        nd = int(jnp.sum(drop))
        if nd:
            raise RuntimeError(
                f"shuffle capacity overflow: {nd} edges dropped; raise "
                f"shuffle_cap")
        flat_new = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), new_vals)
        flat_old = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), state)
        change = float(jnp.max(diff_fn(flat_new, flat_old)))
        state = new_vals
        history["iters"] = it + 1
        history["max_change"].append(change)
        if change < tol:
            break
    return state, history
