"""Distributed MapReduce shuffle on a device mesh (shard_map + all_to_all).

Maps the paper's Hadoop runtime onto a TPU pod:

  * partitions: one per device along the ``data`` axis (or the flattened
    ("pod", "data") axes multi-pod) — the paper's n Map/Reduce task pairs.
  * dependency-aware partitioning (Section 4.3): structure records are
    placed by ``hash(project(SK))`` and state kv-pairs by ``hash(DK)``
    with the *same* hash, so the interdependent pairs are co-located and
    the prime-Reduce output lands on its prime-Map consumer with **zero
    backward transfer** — the co-location scheduling of Fig. 6.
  * shuffle: each shard buckets its intermediate edges by destination
    partition (owner = K2 mod P — a perfect hash for dense int keys) into
    fixed-capacity send buffers, and one ``jax.lax.all_to_all`` realizes the
    exchange.  Multi-pod runs flatten ("pod", "data") into a single exchange
    axis (XLA schedules the intra- vs cross-pod legs); a two-stage
    hierarchical exchange that combines same-destination edges intra-pod
    before crossing pods is the natural next optimization for skewed keys.
  * reduce: an MXU-friendly segment reduction over the locally owned dense
    key range (local key = K2 // P).

Static capacities make the exchange shape-stable; overflowing edges are
counted and the converge loop regrows the capacity up the bucket ladder
(never silently dropped).

Fine-grain refresh (kv-pair level, §3.3/§5 on the mesh) splits each epoch
into two phases so the MRBG-Store can stay host-side:

  1. *delta exchange* (:func:`make_delta_exchange_step`): delta rows are
     partitioned by ``hash(project(SK))`` (Eq. 2) host-side, each shard
     re-Maps its rows against its **local** state slice (co-located by
     Eq. 1), and one ``all_to_all`` routes the emitted delta edges to their
     owner shards.  Send capacity is the full per-shard edge capacity, so
     the delta path can never drop edges.
  2. *per-shard merge* (:func:`merge_shard_delta`): each shard's received
     edges are merged against its local MRBG slice with the same bucketed
     ``_combine_edges``/``_merge_reduce`` kernels the single-device
     incremental path uses — which is what makes distributed refresh
     bit-for-bit comparable with the single-device result.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.kvstore import (
    INVALID_KEY, KV, Edges, Reducer, edges_to_host, finalize_reduce,
    next_bucket, segment_reduce, sort_edges,
)
from repro.core.incremental import _combine_edges, _merge_reduce, _v2_dict
from repro.core.iterative import IterSpec, State
from repro.core.mrbg_store import MRBGStore
from repro.kernels import jitcache, ops

_IK = np.int32(2**31 - 1)


def partition_of(keys: jax.Array, n: int) -> jax.Array:
    """Equation (1)/(2): the shared partition hash (dense int keys)."""
    return jnp.mod(keys.astype(jnp.uint32), jnp.uint32(n)).astype(jnp.int32)


def partition_struct(spec: IterSpec, struct_keys: np.ndarray,
                     struct_values: Dict[str, np.ndarray],
                     valid: np.ndarray, n_parts: int, cap: int):
    """Host-side pre-partitioning of structure data (Equation 2)."""
    import jax as _jax
    dks = np.asarray(_jax.jit(spec.project)(jnp.asarray(struct_keys)))
    pid = (dks.astype(np.uint32) % n_parts).astype(np.int32)
    out_keys = np.full((n_parts, cap), 2**31 - 1, np.int32)
    out_vals = {n: np.zeros((n_parts, cap) + a.shape[1:], a.dtype)
                for n, a in struct_values.items()}
    out_valid = np.zeros((n_parts, cap), bool)
    for p in range(n_parts):
        sel = np.nonzero(valid & (pid == p))[0]
        assert sel.size <= cap, f"partition {p} overflow ({sel.size}>{cap})"
        out_keys[p, :sel.size] = struct_keys[sel]
        for n, a in struct_values.items():
            out_vals[n][p, :sel.size] = a[sel]
        out_valid[p, :sel.size] = True
    return out_keys, out_vals, out_valid


def partition_state(state_values: Dict[str, np.ndarray], num_state: int,
                    n_parts: int):
    """Equation (1): state kv-pair DK lives on shard DK mod P at local row
    DK // P (dense layout)."""
    rows = (num_state + n_parts - 1) // n_parts
    out = {}
    for n, a in state_values.items():
        buf = np.zeros((n_parts, rows) + a.shape[1:], a.dtype)
        for p in range(n_parts):
            ids = np.arange(p, num_state, n_parts)
            buf[p, :ids.size] = a[ids]
        out[n] = buf
    return out


def unpartition_state(parts: Dict[str, np.ndarray], num_state: int):
    out = {}
    for n, a in parts.items():
        n_parts, rows = a.shape[:2]
        flat = np.zeros((num_state,) + a.shape[2:], a.dtype)
        for p in range(n_parts):
            ids = np.arange(p, num_state, n_parts)
            flat[ids] = a[p, :ids.size]
        out[n] = flat
    return out


# ---------------------------------------------------------------------------
# The exchange: bucket edges by owner partition + one all_to_all
# ---------------------------------------------------------------------------

def _exchange(edges: Edges, n_parts: int, cap: int, axes, bk: Optional[str],
              mesh_shape=None):
    """Shard-local half of the shuffle: bucket ``edges`` by destination
    partition (owner = K2 mod P) into ``[n_parts, cap]`` send buffers and
    run one ``all_to_all`` over the (flattened) partition axes.

    Returns ``(recv Edges [n_parts*cap] flat, sent, drop)`` where ``sent``
    counts this shard's valid edges that crossed the wire and ``drop``
    counts valid edges beyond ``cap`` for some destination (the caller
    either sizes ``cap`` so drops are impossible — the delta path — or
    regrows and retries — the converge loop).
    """
    dest = partition_of(edges.k2, n_parts)
    dest = jnp.where(edges.valid, dest, n_parts)
    # stable sort by dest (via the backend dispatcher), then rank within
    # dest; stability keeps same-(k2,mk) edges in emission order, which
    # last-writer-wins merging downstream depends on
    sorted_dest = ops.sort_pairs(dest, None, num_keys=1, backend=bk)
    sdest = sorted_dest.k2
    order = sorted_dest.perm
    rank = jnp.arange(sdest.shape[0]) - jnp.searchsorted(
        sdest, sdest, side="left")
    ok = (sdest < n_parts) & (rank < cap)
    drop = jnp.sum((rank >= cap) & (sdest < n_parts))

    g = lambda a: jnp.take(a, order, axis=0)
    sk2, smk = g(edges.k2), g(edges.mk)
    sval, ssgn = g(edges.valid), g(edges.sign)
    okv = ok & sval
    sent = jnp.sum(okv)
    send_k2 = jnp.full((n_parts, cap), INVALID_KEY, jnp.int32).at[
        sdest, rank].set(jnp.where(okv, sk2, INVALID_KEY), mode="drop")
    send_mk = jnp.full((n_parts, cap), INVALID_KEY, jnp.int32).at[
        sdest, rank].set(jnp.where(okv, smk, INVALID_KEY), mode="drop")
    send_valid = jnp.zeros((n_parts, cap), jnp.bool_).at[
        sdest, rank].set(okv, mode="drop")
    send_sign = jnp.zeros((n_parts, cap), jnp.int8).at[
        sdest, rank].set(jnp.where(okv, ssgn, 0), mode="drop")
    send_v2 = {}
    for name, leaf in edges.v2.items():
        sl = g(leaf)
        buf = jnp.zeros((n_parts, cap) + sl.shape[1:], sl.dtype)
        m = okv.reshape((-1,) + (1,) * (sl.ndim - 1))
        send_v2[name] = buf.at[sdest, rank].set(
            jnp.where(m, sl, 0), mode="drop")

    # one all_to_all over the partition axis (flattened across pods)
    a2a = functools.partial(jax.lax.all_to_all, axis_name=axes,
                            split_axis=0, concat_axis=0, tiled=False)
    flat = lambda a: a2a(a).reshape((-1,) + a.shape[2:])
    recv = Edges(flat(send_k2), flat(send_mk),
                 {n: flat(v) for n, v in send_v2.items()},
                 flat(send_valid), flat(send_sign))
    return recv, sent, drop


# ---------------------------------------------------------------------------
# The distributed iteration (one prime Map -> shuffle -> prime Reduce)
# ---------------------------------------------------------------------------

def make_distributed_step(spec: IterSpec, mesh: Mesh, axis: str,
                          shuffle_cap: int, *, hierarchical: bool = False,
                          pod_axis: Optional[str] = None,
                          backend: Optional[str] = None,
                          preserve: bool = False):
    """Build the jitted SPMD iteration over ``axis`` (+ optional pod axis).

    shuffle_cap: per (src, dst) shard edge capacity for the all_to_all.
    ``backend`` selects the shard-local shuffle/reduce implementation
    (resolved here, outside the jit, so rebuilding the step retraces).
    ``preserve=True`` additionally returns each shard's received edges
    sorted by (K2, MK) — exactly that shard's MRBG slice for the iteration
    (what seeds the per-shard MRBG-Stores of fine-grain refresh).
    """
    bk = ops.resolve_backend(backend)
    n_parts = mesh.shape[axis] * (mesh.shape[pod_axis] if pod_axis else 1)
    axes = (pod_axis, axis) if pod_axis else (axis,)
    num_state = spec.num_state
    rows = (num_state + n_parts - 1) // n_parts

    def local_iter(struct_keys, struct_vals, struct_valid, state_vals):
        """Runs per shard.  struct_* [1, cap, ...]; state [1, rows, ...]."""
        struct_keys = struct_keys[0]
        struct_vals = jax.tree.map(lambda a: a[0], struct_vals)
        struct_valid = struct_valid[0]
        state_local = jax.tree.map(lambda a: a[0], state_vals)

        # prime Map: gather interdependent state (co-located by Eq. 1+2)
        if spec.replicate_state:
            dv = state_local
        else:
            dks = spec.project(struct_keys)
            dv = jax.tree.map(
                lambda a: jnp.take(a, dks // n_parts, axis=0), state_local)
        sign = jnp.ones(struct_keys.shape[0], jnp.int8)
        edges = spec.map_fn(KV(struct_keys, struct_vals, struct_valid),
                            dv, sign)

        recv, sent, drop = _exchange(edges, n_parts, shuffle_cap, axes, bk)
        # sort by (K2, MK) before reducing: per-key accumulation order then
        # matches the single-device shuffle exactly (bit-for-bit state), and
        # the sorted buffer doubles as the shard's preserved MRBG slice
        recv = sort_edges(recv, num_keys=2, backend=bk)

        # prime Reduce over the local dense key range (local = k2 // P)
        local_ids = recv.k2 // n_parts
        acc, counts = segment_reduce(spec.reducer,
                                     jnp.where(recv.valid, local_ids, rows),
                                     recv.v2, recv.valid, rows, backend=bk)
        my = jax.lax.axis_index(axes[-1])
        if pod_axis:
            my = my + jax.lax.axis_index(pod_axis) * mesh.shape[axis]
        keys = jnp.arange(rows, dtype=jnp.int32) * n_parts + my
        new_vals = finalize_reduce(spec.reducer, keys, acc, counts)
        # zero backward transfer: output stays on this shard (Fig. 6)
        lead = lambda a: a[None]
        outs = (jax.tree.map(lead, new_vals),
                counts[None], drop[None], sent[None])
        if preserve:
            outs += (recv.k2[None], recv.mk[None],
                     jax.tree.map(lead, recv.v2), recv.valid[None])
        return outs

    pspec = P(axes)
    n_out = 8 if preserve else 4
    shmap = shard_map(
        local_iter, mesh=mesh,
        in_specs=(pspec, pspec, pspec, pspec),
        out_specs=(pspec,) * n_out,
        check_rep=False)

    def step(*args):
        jitcache.count_trace("distributed.step")
        return shmap(*args)

    return jax.jit(step)


def _edge_capacity(spec: IterSpec, skeys, svals, state, rows: int) -> int:
    """Static per-shard edge capacity of the prime Map, via ``eval_shape``
    (no device work).  This bounds how far the shuffle capacity can ever
    usefully regrow: one shard holds at most this many valid edges total."""
    cap = skeys.shape[1]

    def sd(a, lead):
        a = np.asarray(a)
        return jax.ShapeDtypeStruct((lead,) + a.shape[2:], a.dtype)

    kv = KV(jax.ShapeDtypeStruct((cap,), jnp.int32),
            jax.tree.map(lambda a: sd(a, cap), svals),
            jax.ShapeDtypeStruct((cap,), jnp.bool_))
    lead = rows if spec.replicate_state else cap
    dv = jax.tree.map(lambda a: sd(a, lead), state)
    sign = jax.ShapeDtypeStruct((cap,), jnp.int8)
    edges = jax.eval_shape(spec.map_fn, kv, dv, sign)
    return int(edges.k2.shape[0])


def _preserved_to_host(pk2, pmk, pv2, pvalid):
    """Split preserved recv edges [P, R, ...] into per-shard host dicts."""
    k2, mk = np.asarray(pk2), np.asarray(pmk)
    valid = np.asarray(pvalid)
    v2 = jax.tree.map(np.asarray, pv2)
    out = []
    for p in range(k2.shape[0]):
        idx = np.nonzero(valid[p])[0]
        out.append({"k2": k2[p][idx], "mk": mk[p][idx],
                    "v2": jax.tree.map(lambda a: a[p][idx], v2)})
    return out


def run_distributed(spec: IterSpec, mesh: Mesh, struct_parts, state_parts,
                    *, axis: str = "data", pod_axis: Optional[str] = None,
                    shuffle_cap: int = 4096, max_iters: int = 50,
                    tol: float = 1e-6, backend: Optional[str] = None,
                    auto_grow: bool = True, preserve_last: bool = False,
                    step_cache: Optional[dict] = None):
    """Drive the distributed prime loop to convergence.

    Overflowing the per-(src, dst) shuffle capacity regrows the capacity up
    the power-of-two ladder and redoes the iteration (``auto_grow=True``),
    bounded by the static per-shard edge capacity; with ``auto_grow=False``
    (or at the bound) it raises instead.  Either way ``state_parts`` is
    never mutated and no partially-updated state escapes: the failed
    iteration's output is discarded, so callers can keep their pre-call
    state on error.

    ``preserve_last=True`` keeps the final iteration's per-shard received
    edges in ``history["last_edges"]`` (one host dict per shard, sorted by
    (K2, MK)) — by construction ``reduce(last_edges[p]) == state[p]``,
    which seeds the per-shard MRBG-Stores of fine-grain refresh.

    ``step_cache`` (a caller-owned dict) reuses jitted steps across calls,
    keeping repeated warm re-converges retrace-free.

    Engine-internal: user code drives this through ``repro.api.Session``
    with ``RunConfig(mesh=MeshConfig(...))``.
    """
    import time as _time

    skeys, svals, svalid = struct_parts
    state = state_parts
    diff_fn = spec.difference
    rows = next(iter(state.values())).shape[1]
    cap_ceiling = next_bucket(
        _edge_capacity(spec, skeys, svals, state, rows), 1)
    cap = int(shuffle_cap)
    cache = step_cache if step_cache is not None else {}

    def get_step(c):
        key = ("step", c, bool(preserve_last), axis, pod_axis)
        if key not in cache:
            cache[key] = make_distributed_step(
                spec, mesh, axis, c, pod_axis=pod_axis, backend=backend,
                preserve=preserve_last)
        return cache[key]

    history = {"iters": 0, "max_change": [], "dropped": 0, "sent": 0,
               "exchange_seconds": [], "shuffle_cap": cap, "regrows": 0,
               "last_edges": None}
    jskeys = jnp.asarray(skeys)
    jsvals = jax.tree.map(jnp.asarray, svals)
    jsvalid = jnp.asarray(svalid)
    last_pres = None
    for it in range(max_iters):
        while True:
            t0 = _time.perf_counter()
            outs = get_step(cap)(jskeys, jsvals, jsvalid,
                                 jax.tree.map(jnp.asarray, state))
            new_vals, counts, drop, sent = outs[:4]
            nd = int(jnp.sum(drop))
            if nd == 0:
                history["exchange_seconds"].append(
                    _time.perf_counter() - t0)
                break
            history["dropped"] += nd
            if not auto_grow or cap >= cap_ceiling:
                raise RuntimeError(
                    f"shuffle capacity overflow: {nd} edges dropped; raise "
                    f"shuffle_cap")
            cap = min(next_bucket(cap + 1, 1), cap_ceiling)
            history["regrows"] += 1
            history["shuffle_cap"] = cap
        history["sent"] += int(jnp.sum(sent))
        if preserve_last:
            last_pres = outs[4:8]
        flat_new = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), new_vals)
        flat_old = jax.tree.map(
            lambda a: a.reshape((-1,) + a.shape[2:]), state)
        change = float(jnp.max(diff_fn(flat_new, flat_old)))
        state = new_vals
        history["iters"] = it + 1
        history["max_change"].append(change)
        if change < tol:
            break
    if last_pres is not None:
        history["last_edges"] = _preserved_to_host(*last_pres)
    return state, history


# ---------------------------------------------------------------------------
# Fine-grain refresh, phase 1: the delta exchange (device)
# ---------------------------------------------------------------------------

def partition_delta(delta, n_parts: int, cap: int, project=None):
    """Host-side partitioning of delta rows by ``hash(project(SK))``
    (Eq. 2; ``project=None`` — the one-step flavor — partitions by the
    record key itself).

    Submission order is preserved within each shard, so an update's '-'
    row stays ahead of its '+' row and last-writer-wins merging resolves
    it correctly.  This relies on the two rows landing on the *same*
    shard, i.e. updates keep ``project(SK)`` stable — true of every
    engine app, where the record key is the Map-instance identity.

    Returns (keys, values, valid, sign), each ``[n_parts, cap, ...]``.
    """
    keys = np.asarray(delta.keys)
    valid = np.asarray(delta.valid)
    sign = np.asarray(delta.sign)
    if project is not None:
        dks = np.asarray(jax.jit(project)(jnp.asarray(keys)))
    else:
        dks = keys
    pid = (dks.astype(np.uint32) % np.uint32(n_parts)).astype(np.int32)
    vleaves, vdef = jax.tree.flatten(
        jax.tree.map(np.asarray, delta.values))
    out_keys = np.full((n_parts, cap), _IK, np.int32)
    out_valid = np.zeros((n_parts, cap), bool)
    out_sign = np.zeros((n_parts, cap), np.int8)
    out_leaves = [np.zeros((n_parts, cap) + a.shape[1:], a.dtype)
                  for a in vleaves]
    for p in range(n_parts):
        sel = np.nonzero(valid & (pid == p))[0]
        if sel.size > cap:
            raise ValueError(
                f"delta partition {p} overflow ({sel.size} > {cap})")
        out_keys[p, :sel.size] = keys[sel]
        out_valid[p, :sel.size] = True
        out_sign[p, :sel.size] = sign[sel]
        for buf, a in zip(out_leaves, vleaves):
            buf[p, :sel.size] = a[sel]
    return (out_keys, jax.tree.unflatten(vdef, out_leaves),
            out_valid, out_sign)


def make_delta_exchange_step(spec, mesh: Mesh, axis: str, *,
                             pod_axis: Optional[str] = None,
                             backend: Optional[str] = None):
    """Build the jitted phase-1 step of fine-grain distributed refresh.

    Each shard re-Maps its partition of the delta rows (gathering its
    *local* state slice when ``spec`` is iterative — co-located by Eq. 1,
    so the gather never leaves the shard) and one ``all_to_all`` routes
    the emitted delta edges to their owner shards.  The send capacity is
    the full per-shard edge capacity, so the delta path can never drop
    an edge — no regrow loop, one executable per delta-row bucket.

    Outputs per shard (sorted by (K2, MK), keys global):
    ``(k2, mk, v2, valid, sign, sent, drop)``.
    """
    bk = ops.resolve_backend(backend)
    n_parts = mesh.shape[axis] * (mesh.shape[pod_axis] if pod_axis else 1)
    axes = (pod_axis, axis) if pod_axis else (axis,)
    iterative = hasattr(spec, "project")

    def body(dkeys, dvals, dvalid, dsign, state_vals=None):
        dkeys = dkeys[0]
        dvals = jax.tree.map(lambda a: a[0], dvals)
        dvalid, dsign = dvalid[0], dsign[0]
        kv = KV(dkeys, dvals, dvalid)
        if iterative:
            state_local = jax.tree.map(lambda a: a[0], state_vals)
            if spec.replicate_state:
                dv = state_local
            else:
                dks = spec.project(dkeys)
                dv = jax.tree.map(
                    lambda a: jnp.take(a, dks // n_parts, axis=0),
                    state_local)
            edges = spec.map_fn(kv, dv, dsign)
        else:
            edges = spec.map_fn(kv, dsign)
        recv, sent, drop = _exchange(edges, n_parts, edges.capacity,
                                     axes, bk)
        pres = sort_edges(recv, num_keys=2, backend=bk)
        lead = lambda a: a[None]
        return (pres.k2[None], pres.mk[None], jax.tree.map(lead, pres.v2),
                pres.valid[None], pres.sign[None], sent[None], drop[None])

    pspec = P(axes)
    n_in = 5 if iterative else 4
    shmap = shard_map(body, mesh=mesh, in_specs=(pspec,) * n_in,
                      out_specs=(pspec,) * 7, check_rep=False)

    def step(*args):
        jitcache.count_trace("distributed.delta_exchange")
        return shmap(*args)

    return jax.jit(step)


def delta_exchange_to_host(outs):
    """Pull a delta-exchange step's outputs to per-shard host dicts.

    Returns ``(shards, sent, dropped)`` where each shard dict carries the
    valid received delta edges (global keys, (K2, MK)-sorted, sign kept).
    """
    k2, mk, v2, valid, sign, sent, drop = outs
    k2, mk = np.asarray(k2), np.asarray(mk)
    valid, sign = np.asarray(valid), np.asarray(sign)
    v2 = jax.tree.map(np.asarray, v2)
    shards = []
    for p in range(k2.shape[0]):
        idx = np.nonzero(valid[p])[0]
        shards.append({"k2": k2[p][idx], "mk": mk[p][idx],
                       "v2": jax.tree.map(lambda a: a[p][idx], v2),
                       "sign": sign[p][idx]})
    return shards, int(np.sum(sent)), int(np.sum(drop))


# ---------------------------------------------------------------------------
# Fine-grain refresh, phase 2: the per-shard MRBG merge (host + jit kernels)
# ---------------------------------------------------------------------------

def merge_shard_delta(reducer: Reducer, store: MRBGStore, shard: int,
                      n_parts: int, dk2, dmk, dv2, dsign, *,
                      backend: Optional[str] = None):
    """Merge one shard's received delta edges into its local MRBG slice.

    ``dk2`` arrives in *global* keys ((K2, MK)-sorted); the store is keyed
    by local ids (K2 // P — Eq. 1's dense per-shard layout), while the
    merge itself runs in global keys so ``finalize_reduce`` sees true K2s.
    Reuses the exact ``_combine_edges``/``_merge_reduce`` kernels of the
    single-device incremental path — preserved rows first, stable sort,
    last-writer-wins, tombstones — which is what makes distributed refresh
    bit-for-bit comparable with the single-device result.

    Returns (affected global keys, values dict, counts), each sized to the
    affected set, for the caller to patch the dense view and state slice.
    """
    bk = ops.resolve_backend(backend)
    dk2 = np.asarray(dk2, np.int32)
    affected = np.unique(dk2)
    if affected.size == 0:
        return affected.astype(np.int32), {}, np.zeros(0, np.int32)
    local = ((affected.astype(np.int64) - shard) // n_parts).astype(np.int32)
    dv2 = _v2_dict(dv2)
    pk2l, pmk, pv2, _plen = store.query(local)
    if pv2 is None:
        pv2 = {n: np.zeros((0,) + a.shape[1:], a.dtype)
               for n, a in dv2.items()}
    pk2g = (pk2l.astype(np.int64) * n_parts + shard).astype(np.int32)

    key_cap = next_bucket(affected.size, 64)
    combined = _combine_edges(pk2g, pmk, pv2, dk2, np.asarray(dmk, np.int32),
                              dv2, np.asarray(dsign, np.int8))
    keys_pad = np.full(key_cap, _IK, np.int32)
    keys_pad[:affected.size] = affected.astype(np.int32)
    merged, values, counts = _merge_reduce(reducer, key_cap, bk,
                                           combined, jnp.asarray(keys_pad))

    mh = edges_to_host(merged)
    mlocal = ((mh["k2"].astype(np.int64) - shard) // n_parts).astype(np.int32)
    store.append(mlocal, mh["mk"], _v2_dict(mh["v2"]))
    counts_h = np.asarray(counts)[:affected.size]
    gone = affected[counts_h == 0]
    store.mark_deleted(
        ((gone.astype(np.int64) - shard) // n_parts).astype(np.int32))
    vals_h = {n: np.asarray(a)[:affected.size]
              for n, a in _v2_dict(values).items()}
    return affected.astype(np.int32), vals_h, counts_h


def merge_shards_parallel(reducer: Reducer, stores, n_parts: int, shards,
                          *, backend: Optional[str] = None,
                          workers: int = 0):
    """Run :func:`merge_shard_delta` for every non-empty shard, threaded.

    Each shard merges against its own :class:`MRBGStore` and a disjoint
    global key set, so the host-side merges are embarrassingly parallel;
    jit dispatch is thread-safe and the per-shard kernels share the
    bucketed executable cache.  ``workers=0`` sizes the pool automatically
    (``min(8, cpus, jobs)``); ``workers=1`` keeps the historical
    sequential loop.  Returns ``[(p, affected, vals, counts), ...]`` in
    shard order either way, so callers can apply CPC/state/view updates
    deterministically.
    """
    jobs = [(p, sh) for p, sh in enumerate(shards) if sh["k2"].size]
    if not jobs:
        return []

    def _one(job):
        p, sh = job
        aff, vals, counts = merge_shard_delta(
            reducer, stores[p], p, n_parts, sh["k2"], sh["mk"], sh["v2"],
            sh["sign"], backend=backend)
        return p, aff, vals, counts

    if workers == 0:
        workers = min(8, os.cpu_count() or 1, len(jobs))
    if workers <= 1 or len(jobs) == 1:
        return [_one(j) for j in jobs]
    from concurrent.futures import ThreadPoolExecutor
    with ThreadPoolExecutor(max_workers=workers) as ex:
        return list(ex.map(_one, jobs))       # ex.map preserves order
