"""Incremental iterative processing (paper Section 5).

A refresh job A_i starts from job A_{i-1}'s *converged* state D_{i-1} and the
preserved MRBGraph of A_{i-1}'s final iteration (Section 5.1):

  * iteration 1's delta input is the **delta structure data**: changed
    records are re-Mapped ('-' rows reproduce the old edges as tombstones —
    Map is pure and the state is still the converged one, so the replay is
    exact), merged against the preserved MRBGraph, and only affected Reduce
    instances re-run;
  * iteration j>=2's delta input is the **delta state data**: the reverse
    dependency index (DK -> structure records, from Project) selects the Map
    instances affected by emitted state changes.

**Change propagation control** (Section 5.3): per-DK changes accumulate; a DK
is emitted to the next iteration only when its accumulated change exceeds the
filter threshold (so starved keys eventually fire), trading bounded error for
sharply less propagation.

**Auto MRBG-off** (Section 5.2): when the emitted fraction P_Δ exceeds
``pdelta_threshold`` (default 0.5), maintaining fine-grain state costs more
than it saves; the job falls back to plain iterative recomputation from the
current state (iterMR mode) and rebuilds the MRBGraph in one preserving pass
after convergence so the *next* refresh job can be incremental again.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.incremental import (
    DeltaKV, _combine_edges, _merge_reduce, apply_delta_host,
)
from repro.core.iterative import (
    IterSpec, State, run_iterative,
)
from repro.core.kvstore import (
    INVALID_KEY, KV, Edges, edges_to_host, next_bucket, sort_edges,
)
from repro.core.mrbg_store import MRBGStore
from repro.kernels import jitcache, ops

_IK = np.int32(2**31 - 1)


def build_reverse_index(project, struct_keys: np.ndarray,
                        struct_valid: np.ndarray, num_state: int):
    """CSR reverse image of Project: DK -> structure record ids.

    Shared by the single-device refresh job and the distributed fine-grain
    driver (which selects iteration >= 2's re-Mapped records from it).
    Returns ``(indptr [num_state+1], record_ids, dks_host)``.
    """
    dks = np.asarray(jax.jit(project)(jnp.asarray(struct_keys)))
    dks = np.where(struct_valid, dks, num_state)
    order = np.argsort(dks, kind="stable")
    sorted_dks = dks[order]
    counts = np.bincount(sorted_dks, minlength=num_state + 1)
    indptr = np.concatenate(
        [[0], np.cumsum(counts[:num_state])]).astype(np.int64)
    ids = order[:indptr[-1]].astype(np.int32)
    return indptr, ids, dks.astype(np.int32)


def records_of_dks(indptr: np.ndarray, ids: np.ndarray,
                   dks: np.ndarray) -> np.ndarray:
    """The unique structure records whose Map instances read any of
    ``dks`` (the delta state data's reverse dependency set)."""
    parts = [ids[indptr[d]:indptr[d + 1]] for d in dks]
    if not parts:
        return np.zeros(0, np.int32)
    return np.unique(np.concatenate(parts)).astype(np.int32)


@dataclass
class IterationLog:
    iteration: int
    n_input_changes: int        # delta records (it 1) or changed DKs (it>=2)
    n_affected_dks: int         # reduce instances re-run ("propagated kv-pairs")
    n_emitted: int              # survived CPC filter
    mrbg_on: bool
    seconds: float
    io_reads: int = 0
    io_bytes: int = 0


class IncrIterJob:
    """Owns structure data, converged state, MRBGraph store, CPC accumulators."""

    def __init__(self, spec: IterSpec, struct: KV, *, value_bytes: int = 8,
                 policy: str = "multi-dynamic-window",
                 cpc_threshold: float = 0.0,
                 pdelta_threshold: float = 0.5,
                 backend: Optional[str] = None,
                 store_kw: Optional[Dict[str, Any]] = None):
        self.spec = spec
        self.backend = backend
        self.cpc_threshold = cpc_threshold
        self.pdelta_threshold = pdelta_threshold
        self._store_kw = dict(store_kw or {})
        self.store = MRBGStore(spec.num_state, value_bytes, policy=policy,
                               **self._store_kw)
        self.mrbg_on = True

        # host mirror of the structure data (the partitioned structure file)
        self.struct_values = {n: np.array(a) for n, a in struct.values.items()}
        self.struct_valid = np.array(struct.valid)
        self.struct_keys = np.array(struct.keys)
        self.capacity = struct.capacity
        self._rebuild_reverse_index()

        self.state: Optional[State] = None
        # state values as of each DK's last emission (what the preserved
        # edges were computed from) -- needed to replay '-' for
        # topology-changing Maps
        self.emitted_values: Optional[Dict[str, jax.Array]] = None
        self.cpc_accum = np.zeros(spec.num_state, np.float32)
        self.logs: List[IterationLog] = []
        self._last_max_change = np.inf

    # ------------------------------------------------------------------
    def _rebuild_reverse_index(self) -> None:
        """CSR: DK -> structure record ids (Project's reverse image)."""
        self.rev_indptr, self.rev_ids, self.dks_host = build_reverse_index(
            self.spec.project, self.struct_keys, self.struct_valid,
            self.spec.num_state)

    def grow_records(self, capacity: int) -> None:
        """Extend the structure mirror with invalid rows (streams inserting
        brand-new record ids past the seed capacity) and rebuild the
        reverse dependency index.  Shrinking is never performed."""
        capacity = int(capacity)
        n = self.struct_keys.shape[0]
        if capacity <= n:
            return
        pad = capacity - n
        self.struct_keys = np.concatenate(
            [self.struct_keys,
             np.zeros((pad,) + self.struct_keys.shape[1:],
                      self.struct_keys.dtype)])
        self.struct_values = {
            name: np.concatenate(
                [a, np.zeros((pad,) + a.shape[1:], a.dtype)])
            for name, a in self.struct_values.items()}
        self.struct_valid = np.concatenate(
            [self.struct_valid, np.zeros(pad, bool)])
        self.capacity = capacity
        self._rebuild_reverse_index()

    def _records_of_dks(self, dks: np.ndarray) -> np.ndarray:
        if self.spec.replicate_state:
            return np.nonzero(self.struct_valid)[0].astype(np.int32)
        return records_of_dks(self.rev_indptr, self.rev_ids, dks)

    def _struct_kv(self) -> KV:
        return KV(jnp.asarray(self.struct_keys),
                  {n: jnp.asarray(a) for n, a in self.struct_values.items()},
                  jnp.asarray(self.struct_valid))

    # ------------------------------------------------------------------
    def initial_converge(self, *, max_iters: int = 100, tol: float = 1e-4):
        """Job A_0: full iterative run; preserve final-iteration MRBGraph."""
        state, hist = run_iterative(self.spec, self._struct_kv(), None,
                                    max_iters=max_iters, tol=tol,
                                    preserve_last=True,
                                    backend=self.backend)
        self.state = state
        self.emitted_values = dict(state.values)
        self._preserve(hist["last_edges"])
        return state, hist

    def _preserve(self, edges: Edges) -> None:
        host = edges_to_host(edges)
        v2 = host["v2"] if isinstance(host["v2"], dict) else {"v": host["v2"]}
        self.store.append(host["k2"], host["mk"], v2)

    # ------------------------------------------------------------------
    def refresh(self, delta_struct: DeltaKV, *, max_iters: int = 100,
                tol: float = 1e-6,
                cpc_threshold: Optional[float] = None):
        """Job A_i: incremental refresh after a structure delta."""
        assert self.state is not None, "initial_converge first"
        thresh = self.cpc_threshold if cpc_threshold is None else cpc_threshold
        spec = self.spec
        self.logs = []
        self._last_max_change = np.inf

        # -- apply the delta to the structure mirror ----------------------
        rid = np.asarray(delta_struct.record_ids)
        dvalid = np.asarray(delta_struct.valid)
        apply_delta_host(self.struct_keys, self.struct_values,
                         self.struct_valid, delta_struct)
        self._rebuild_reverse_index()

        if spec.replicate_state or not self.mrbg_on:
            # Kmeans-style: fine-grain state is pointless (P_Δ = 100%);
            # iterate from the previously converged state (iterMR mode).
            return self._fallback_iterate(max_iters, tol)

        # -- iteration 1: delta input = delta structure data --------------
        t0 = time.perf_counter()
        self.store.reset_stats()
        sel_dks = jax.jit(spec.project)(delta_struct.keys)
        changed = self._incr_iteration(
            kv=KV(delta_struct.keys, delta_struct.values, delta_struct.valid),
            record_ids=rid, sign=delta_struct.sign, sel_dks=sel_dks,
            thresh=thresh, iteration=1,
            n_input=int(dvalid.sum()), t0=t0)
        if changed is None:          # P_Δ blew past the threshold
            return self._fallback_iterate(max_iters, tol)

        # -- iterations >= 2: delta input = delta state data ---------------
        for it in range(2, max_iters + 1):
            if changed.size == 0 or self._last_max_change < tol:
                break
            t0 = time.perf_counter()
            self.store.reset_stats()
            recs = self._records_of_dks(changed)
            if recs.size == 0:
                break
            cap = next_bucket(recs.size, 64)
            sel = np.full(cap, 0, np.int32)
            sel[:recs.size] = recs
            ok = np.zeros(cap, bool)
            ok[:recs.size] = True
            kv = KV(jnp.asarray(self.struct_keys[sel]),
                    {n: jnp.asarray(a[sel])
                     for n, a in self.struct_values.items()},
                    jnp.asarray(ok & self.struct_valid[sel]))
            changed = self._incr_iteration(
                kv=kv, record_ids=sel, sign=jnp.ones(cap, jnp.int8),
                sel_dks=jnp.asarray(self.dks_host[sel]), thresh=thresh,
                iteration=it, n_input=int(changed.size), t0=t0)
            if changed is None:
                return self._fallback_iterate(max_iters - it, tol)

        return self.state, {"iters": len(self.logs), "logs": self.logs,
                            "mode": "i2"}

    # ------------------------------------------------------------------
    def _incr_iteration(self, kv: KV, record_ids, sign, sel_dks, thresh,
                        iteration: int, n_input: int, t0: float):
        """One incremental iteration; returns emitted DKs (or None => P_Δ
        exceeded, caller should fall back)."""
        spec = self.spec
        state_vals = self.state.values
        bk = ops.resolve_backend(self.backend)

        if spec.stable_topology:
            edges = _delta_map_iter(
                (spec.map_fn, spec.replicate_state, bk), kv,
                jnp.asarray(record_ids), jnp.asarray(sign, jnp.int8),
                jnp.asarray(sel_dks), state_vals)
        else:
            # topology may change: tombstone-replay with the last-emitted
            # state, then insert with the current state
            old_edges = _delta_map_iter(
                (spec.map_fn, spec.replicate_state, bk), kv,
                jnp.asarray(record_ids),
                -jnp.abs(jnp.asarray(sign, jnp.int8)),
                jnp.asarray(sel_dks), self.emitted_values)
            new_edges = _delta_map_iter(
                (spec.map_fn, spec.replicate_state, bk), kv,
                jnp.asarray(record_ids), jnp.asarray(sign, jnp.int8),
                jnp.asarray(sel_dks), state_vals)
            edges = _concat_edges(old_edges, new_edges, backend=bk)

        dh = edges_to_host(edges, sorted_valid_first=True)
        affected = np.unique(dh["k2"])
        if affected.size == 0:
            self.logs.append(IterationLog(iteration, n_input, 0, 0, True,
                                          time.perf_counter() - t0))
            return np.zeros(0, np.int64)

        pk2, pmk, pv2, _ = self.store.query(affected)
        v2_t = dh["v2"] if isinstance(dh["v2"], dict) else {"v": dh["v2"]}
        if pv2 is None or pk2.shape[0] == 0:
            pv2 = {n: np.zeros((0,) + a.shape[1:], a.dtype)
                   for n, a in v2_t.items()}
            pk2 = np.zeros(0, np.int32)
            pmk = np.zeros(0, np.int32)

        key_cap = next_bucket(affected.size, 64)
        combined = _combine_edges(pk2, pmk, pv2, dh["k2"], dh["mk"], v2_t,
                                  np.asarray(dh["sign"], np.int8))
        keys_pad = np.full(key_cap, _IK, np.int32)
        keys_pad[:affected.size] = affected.astype(np.int32)

        merged, values, counts = _merge_reduce(spec.reducer, key_cap, bk,
                                               combined,
                                               jnp.asarray(keys_pad))

        # preserve merged chunks
        mh = edges_to_host(merged)
        mv2 = mh["v2"] if isinstance(mh["v2"], dict) else {"v": mh["v2"]}
        self.store.append(mh["k2"], mh["mk"], mv2)
        counts_h = np.asarray(counts)[:affected.size]
        self.store.mark_deleted(affected[counts_h == 0])

        # CPC: accumulate per-DK change; emit above-threshold keys
        diff_fn = spec.difference
        aff_idx = jnp.asarray(affected.astype(np.int32))
        old_vals = {n: jnp.take(a, aff_idx, axis=0)
                    for n, a in state_vals.items()}
        new_vals = {n: jnp.asarray(np.asarray(v)[:affected.size])
                    for n, v in values.items()}
        change = np.asarray(diff_fn(new_vals, old_vals))
        self._last_max_change = float(change.max()) if change.size else 0.0
        self.cpc_accum[affected] += change
        emit_mask = self.cpc_accum[affected] > thresh
        emitted = affected[emit_mask]
        self.cpc_accum[emitted] = 0.0

        # always record the refreshed values (deferred emission only)
        sv = dict(state_vals)
        for n in sv:
            arr = np.array(sv[n])
            arr[affected] = np.asarray(new_vals[n])
            sv[n] = jnp.asarray(arr)
        self.state = State(sv, self.state.valid)
        ev = dict(self.emitted_values)
        for n in ev:
            arr = np.array(ev[n])
            arr[emitted] = np.asarray(new_vals[n])[emit_mask]
            ev[n] = jnp.asarray(arr)
        self.emitted_values = ev

        st = self.store.stats
        self.logs.append(IterationLog(
            iteration, n_input, int(affected.size), int(emitted.size), True,
            time.perf_counter() - t0, st.n_reads, st.bytes_read))

        # P_Δ detection (Section 5.2): the *delta state data* |ΔD_i| is what
        # drives the next iteration's recomputation.
        p_delta = emitted.size / max(self.spec.num_state, 1)
        if p_delta > self.pdelta_threshold:
            self.mrbg_on = False
            return None
        return emitted

    # ------------------------------------------------------------------
    def _fallback_iterate(self, max_iters: int, tol: float):
        """iterMR mode from the current state; rebuild MRBGraph at the end."""
        t0 = time.perf_counter()
        state, hist = run_iterative(self.spec, self._struct_kv(),
                                    self.state, max_iters=max_iters,
                                    tol=tol, preserve_last=True,
                                    backend=self.backend)
        self.state = state
        self.emitted_values = dict(state.values)
        self.store = MRBGStore(self.spec.num_state,
                               self.store.record_bytes - 8,
                               policy=self.store.policy, **self._store_kw)
        if hist["last_edges"] is not None:
            self._preserve(hist["last_edges"])
        self.mrbg_on = True
        self.cpc_accum[:] = 0.0
        self.logs.append(IterationLog(-1, 0, self.spec.num_state,
                                      self.spec.num_state, False,
                                      time.perf_counter() - t0))
        return self.state, {"iters": hist["iters"], "logs": self.logs,
                            "mode": "iterMR-fallback"}


# ---------------------------------------------------------------------------

import functools


@functools.partial(jax.jit, static_argnums=(0,))
def _delta_map_iter(spec_static, kv: KV, record_ids, sign, sel_dks,
                    state_values):
    """Prime Map over a selected subset of structure records."""
    jitcache.count_trace("incr_iter._delta_map_iter")
    map_fn, replicate, backend = spec_static
    if replicate:
        dv = state_values
    else:
        dv = jax.tree.map(lambda a: jnp.take(a, sel_dks, axis=0),
                          state_values)
    edges = map_fn(KV(kv.keys, kv.values, kv.valid), dv, sign)
    return sort_edges(edges, backend=backend)


@functools.partial(jax.jit, static_argnames=("backend",))
def _concat_edges(a: Edges, b: Edges, backend: Optional[str] = None) -> Edges:
    jitcache.count_trace("incr_iter._concat_edges")
    return sort_edges(Edges(
        jnp.concatenate([a.k2, b.k2]), jnp.concatenate([a.mk, b.mk]),
        jax.tree.map(lambda x, y: jnp.concatenate([x, y]), a.v2, b.v2),
        jnp.concatenate([a.valid, b.valid]),
        jnp.concatenate([a.sign, b.sign])), backend=backend)
