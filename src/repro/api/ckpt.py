"""Session checkpoint/restore: one fault-tolerance surface for every mode.

Folds ``repro.core.ft`` (which snapshots the incremental-iterative engine)
into the Session API and extends the same discipline to the one-step MRBG
path, the accumulator path, the plainMR baseline, and distributed sessions:

  <root>/session.json          what kind of driver the snapshot belongs to
  <root>/it_NNNNNN/            incr-iter epochs (repro.core.ft layout)
  <root>/ep_NNNNNN/            every other driver's epochs (atomic rename)

``Session.restore`` rebuilds the newest epoch; the next ``update(delta)``
continues exactly where the snapshot left off.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.api.config import RunConfig
from repro.core.incremental import ResultView
from repro.core.iterative import State
from repro.core.mrbg_store import (
    MRBGStore, load_store_state, store_blobs, store_meta,
)


# ---------------------------------------------------------------------------
# MRBG-Store blobs (one layout, shared with repro.core.ft via
# repro.core.mrbg_store.{store_blobs,store_meta,load_store_state})
# ---------------------------------------------------------------------------

def _store_to_npz(store: MRBGStore, path: Path) -> Dict:
    np.savez(path, **store_blobs(store))
    return store_meta(store)


def _store_from_npz(num_keys: int, path: Path, meta: Dict,
                    cfg: RunConfig) -> MRBGStore:
    store = MRBGStore(num_keys, meta["value_bytes"], policy=meta["policy"],
                      **cfg.store_kw())
    load_store_state(store, np.load(path), meta)
    return store


def _save_shard_stores(drv, tmp: Path) -> None:
    """Per-shard MRBG slices of a distributed driver (local-key space, so
    only a mesh of the same part count can reuse them)."""
    metas = None
    if drv.stores is not None:
        metas = [_store_to_npz(s, tmp / f"mrbg_{p:03d}.npz")
                 for p, s in enumerate(drv.stores)]
    (tmp / "shards.json").write_text(json.dumps(
        {"n_parts": drv.n_parts, "mrbg_on": drv.mrbg_on, "stores": metas}))


def _load_shard_stores(drv, d: Path, cfg: RunConfig) -> bool:
    """Rebuild ``drv.stores`` from a snapshot; False when the snapshot was
    taken with a different part count (local keys don't transfer)."""
    sj = d / "shards.json"
    if not sj.exists():
        return False
    meta = json.loads(sj.read_text())
    if meta["stores"] is None or meta["n_parts"] != drv.n_parts:
        return False
    drv.stores = [
        _store_from_npz(drv.rows, d / f"mrbg_{p:03d}.npz", m, cfg)
        for p, m in enumerate(meta["stores"])]
    drv.mrbg_on = meta["mrbg_on"]
    return True


def _atomic_epoch_dir(root: Path, epoch: int):
    tmp = root / f"ep_{epoch:06d}.tmp"
    final = root / f"ep_{epoch:06d}"
    old = root / f"ep_{epoch:06d}.old"
    if tmp.exists():
        shutil.rmtree(tmp)
    if old.exists():
        shutil.rmtree(old)
    tmp.mkdir(parents=True)

    def commit() -> Path:
        # never leave a window with no snapshot for this epoch: displace
        # the previous version, promote the new one, then drop the old
        if final.exists():
            os.rename(final, old)
        os.rename(tmp, final)
        if old.exists():
            shutil.rmtree(old)
        return final

    return tmp, commit


def _atomic_write_text(path: Path, text: str) -> None:
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_text(text)
    os.replace(tmp, path)


def _latest_valid(root: Path, pattern: str) -> list:
    """Committed snapshot dirs only (ignore .tmp/.old leftovers)."""
    return sorted(d for d in root.glob(pattern) if d.is_dir())


def _latest_epoch_dir(root: Path) -> Path:
    eps = _latest_valid(root, "ep_??????")
    assert eps, f"no session checkpoints under {root}"
    return eps[-1]


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def save_session(session, root: str) -> Path:
    rootp = Path(root)
    rootp.mkdir(parents=True, exist_ok=True)
    drv = session._driver
    if session.epoch < 0:
        raise RuntimeError("nothing to checkpoint before run()")

    if drv.kind == "incr-iter":
        from repro.core.ft import checkpoint_job
        out = checkpoint_job(drv.job, root, session.epoch)
    elif drv.kind == "onestep-mrbg":
        tmp, commit = _atomic_epoch_dir(rootp, session.epoch)
        view = drv.view
        np.savez(tmp / "view.npz", valid=view.valid, counts=view.counts,
                 **{f"v_{n}": a for n, a in view.values.items()})
        meta = _store_to_npz(drv.store, tmp / "mrbg.npz")
        (tmp / "meta.json").write_text(json.dumps(meta))
        out = commit()
    elif drv.kind == "onestep-accumulator":
        tmp, commit = _atomic_epoch_dir(rootp, session.epoch)
        view = drv.job.view
        np.savez(tmp / "acc.npz", valid=view.valid, counts=view.counts,
                 **{f"v_{n}": a for n, a in view.values.items()},
                 **{f"a_{n}": a for n, a in drv.job.raw_acc.items()})
        out = commit()
    elif drv.kind in ("plain-iter", "distributed"):
        tmp, commit = _atomic_epoch_dir(rootp, session.epoch)
        state = drv.result()
        extra = ({"cpc": drv.cpc_accum} if drv.kind == "distributed" else {})
        np.savez(tmp / "state.npz",
                 struct_keys=drv._keys, struct_valid=drv._valid,
                 **{f"sv_{n}": a for n, a in state.items()},
                 **{f"st_{n}": a for n, a in drv._values.items()},
                 **extra)
        if drv.kind == "distributed":
            _save_shard_stores(drv, tmp)
        out = commit()
    elif drv.kind == "distributed-onestep":
        tmp, commit = _atomic_epoch_dir(rootp, session.epoch)
        view = drv.view
        np.savez(tmp / "view.npz", valid=view.valid, counts=view.counts,
                 **{f"v_{n}": a for n, a in view.values.items()})
        _save_shard_stores(drv, tmp)
        out = commit()
    elif drv.kind == "query":
        tmp, commit = _atomic_epoch_dir(rootp, session.epoch)
        metas = []
        for i, st in enumerate(drv.stages):
            view = st.view
            np.savez(tmp / f"stage{i:02d}_view.npz", valid=view.valid,
                     counts=view.counts,
                     **{f"v_{n}": a for n, a in view.values.items()})
            metas.append(_store_to_npz(st.store, tmp / f"stage{i:02d}.npz"))
        (tmp / "query.json").write_text(json.dumps(
            {"n_stages": len(drv.stages), "stores": metas,
             "affected": drv._affected,
             "schemas": [st.schemas for st in drv.stages]}))
        out = commit()
    else:                                 # pragma: no cover
        raise ValueError(f"unknown driver kind {drv.kind!r}")

    _atomic_write_text(rootp / "session.json", json.dumps(
        {"kind": drv.kind, "epoch": session.epoch, "mode": drv.mode,
         "name": session.spec.name}))
    return out


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------

def load_session(cls, spec, root: str, config: Optional[RunConfig]):
    rootp = Path(root)
    meta = json.loads((rootp / "session.json").read_text())
    cfg = config or RunConfig()
    kind = meta["kind"]

    # the driver is chosen by config; pin the config to the snapshot's kind
    if kind == "onestep-mrbg":
        cfg = cfg.replace(onestep_path="mrbg")
    elif kind == "onestep-accumulator":
        cfg = cfg.replace(onestep_path="accumulator")
    elif kind == "plain-iter":
        cfg = cfg.replace(plain_shuffle=True, mesh=None)
    elif kind == "incr-iter":
        cfg = cfg.replace(plain_shuffle=False, mesh=None)
    elif kind in ("distributed", "distributed-onestep"):
        if cfg.mesh is None:
            raise ValueError("restoring a distributed session requires "
                             "RunConfig(mesh=...) — meshes are not "
                             "serializable")

    session = cls(spec, cfg)
    drv = session._driver
    session.epoch = meta["epoch"]
    drv.mode = meta["mode"]

    if kind == "incr-iter":
        from repro.core.ft import restore_job
        job = restore_job(spec, root)
        # re-apply the session's config on the restored engine objects
        job.backend = cfg.backend
        job.cpc_threshold = cfg.cpc_threshold
        job.pdelta_threshold = cfg.pdelta_threshold
        job._store_kw = cfg.store_kw()
        for k, v in cfg.store_kw().items():
            setattr(job.store, k, v)
        drv.job = job
    elif kind == "onestep-mrbg":
        d = _latest_epoch_dir(rootp)
        m = json.loads((d / "meta.json").read_text())
        vz = np.load(d / "view.npz")
        values = {k[2:]: vz[k].copy() for k in vz.files if k.startswith("v_")}
        drv.view = ResultView(spec.num_keys, values, vz["valid"].copy(),
                              vz["counts"].copy())
        drv.store = _store_from_npz(spec.num_keys, d / "mrbg.npz", m, cfg)
        drv._counts = drv.view.counts
    elif kind == "onestep-accumulator":
        d = _latest_epoch_dir(rootp)
        az = np.load(d / "acc.npz")
        values = {k[2:]: az[k].copy() for k in az.files if k.startswith("v_")}
        drv.job.view = ResultView(spec.num_keys, values, az["valid"].copy(),
                                  az["counts"].copy())
        drv.job.raw_acc = {k[2:]: az[k].copy() for k in az.files
                           if k.startswith("a_")}
    elif kind in ("plain-iter", "distributed"):
        d = _latest_epoch_dir(rootp)
        sz = np.load(d / "state.npz")
        drv._keys = sz["struct_keys"].copy()
        drv._valid = sz["struct_valid"].copy()
        drv._values = {k[3:]: sz[k].copy() for k in sz.files
                       if k.startswith("st_")}
        state = {k[3:]: sz[k] for k in sz.files if k.startswith("sv_")}
        if kind == "distributed":
            from repro.core.distributed import partition_state
            drv.state_parts = {
                n: np.array(a) for n, a in partition_state(
                    state, spec.num_state, drv.n_parts).items()}
            if "cpc" in sz.files:
                drv.cpc_accum = sz["cpc"].copy()
            drv._rebuild_rev()
            # per-shard MRBG slices transfer only onto an equal part count;
            # otherwise the next update() warm-converges and re-seeds them
            if not _load_shard_stores(drv, d, cfg):
                drv.stores = None
        else:
            drv.state = State(
                {n: jnp.asarray(a) for n, a in state.items()},
                jnp.ones(spec.num_state, jnp.bool_))
    elif kind == "distributed-onestep":
        d = _latest_epoch_dir(rootp)
        vz = np.load(d / "view.npz")
        values = {k[2:]: vz[k].copy() for k in vz.files if k.startswith("v_")}
        drv.view = ResultView(spec.num_keys, values, vz["valid"].copy(),
                              vz["counts"].copy())
        if not _load_shard_stores(drv, d, cfg):
            raise ValueError(
                "distributed one-step snapshots store per-shard MRBG slices "
                "in local-key space; restore with a mesh of the same part "
                "count as the one that wrote the checkpoint")
    elif kind == "query":
        from repro.dql.driver import RecordingView
        d = _latest_epoch_dir(rootp)
        qmeta = json.loads((d / "query.json").read_text())
        if qmeta["n_stages"] != len(drv.stages):
            raise ValueError(
                f"snapshot has {qmeta['n_stages']} stages but the spec "
                f"lowered to {len(drv.stages)}; restore with the same plan")
        for i, st in enumerate(drv.stages):
            vz = np.load(d / f"stage{i:02d}_view.npz")
            values = {k[2:]: vz[k].copy() for k in vz.files
                      if k.startswith("v_")}
            st.view = RecordingView(st.plan.num_keys, values,
                                    vz["valid"].copy(), vz["counts"].copy())
            st.store = _store_from_npz(st.plan.num_keys,
                                       d / f"stage{i:02d}.npz",
                                       qmeta["stores"][i], cfg)
            # json turns the (shape, dtype) tuples into lists — restore them
            st.schemas = [
                None if sch is None else
                {c: (tuple(shape), dt) for c, (shape, dt) in sch.items()}
                for sch in qmeta["schemas"][i]]
        drv._affected = qmeta.get("affected", -1)
    return session
