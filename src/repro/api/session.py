"""Session: the single supported way to drive the i2MapReduce engine.

A job is declared once (a :class:`JobSpec` or :class:`IterSpec`) together
with one :class:`RunConfig`; the session then transparently routes

  * ``run(data)``     -> full one-step execution, or prime-loop convergence,
  * ``update(delta)`` -> fine-grain incremental refresh (§3.3), the
                         accumulator fast path (§3.5), incremental iterative
                         refresh with CPC + auto MRBG-off (§5), or a
                         distributed re-converge,
  * ``result`` / ``report()`` -> one uniform output surface,
  * ``checkpoint()`` / ``restore()`` -> fault tolerance (§6),

exactly as the paper presents i2MapReduce: one system, with the engine —
not the caller — choosing between incremental refresh, iterative
recomputation, and fallback re-computation.  Distributed execution is not a
different API: ``RunConfig(mesh=...)`` turns the same spec into the
shard_map + all_to_all engine of §4.3.

The historical entry points (``run_onestep``, ``IncrementalJob``,
``run_iterative``/``run_plain``, ``IncrIterJob``, ``run_distributed``,
``AccumulatorJob``, ``checkpoint_job``/``restore_job``) are the internal
implementation that the Session drives; they carry no API stability promise.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import RunConfig
from repro.api.report import RunReport, ShuffleStats
from repro.core.engine import JobSpec, run_onestep
from repro.core.incr_iter import IterationLog
from repro.core.incremental import (
    DeltaKV, ResultView, _v2_dict, apply_delta_host, incremental_onestep,
    pad_delta,
)
from repro.core.iterative import IterSpec, State, run_iterative, run_plain
from repro.core.kvstore import KV, edges_to_host, next_bucket
from repro.core.mrbg_store import IOStats, MRBGStore
from repro.kernels import jitcache

Spec = Union[JobSpec, IterSpec]


class Session:
    """Owns one declared job and all of its preserved state across epochs."""

    def __init__(self, spec: Spec, config: Optional[RunConfig] = None):
        self.spec = spec
        self.config = config or RunConfig()
        if self.config.compilation_cache_dir is not None:
            jitcache.enable_persistent_cache(self.config.compilation_cache_dir)
        self.epoch = -1                     # becomes 0 on run()
        self._last: Optional[RunReport] = None
        # bounded RunReport history (oldest first) — the raw material for
        # online refresh-cost models (repro.stream.RefreshScheduler)
        self.history: list = []
        self._driver = self._make_driver()

    def _make_driver(self):
        spec, config = self.spec, self.config
        if isinstance(spec, JobSpec):
            if config.mesh is not None:
                return _DistOneStep(spec, config)
            path = config.onestep_path
            if path == "auto":
                path = ("accumulator" if spec.reducer.invertible else "mrbg")
            return (_OneStepAccumulator(spec, config)
                    if path == "accumulator" else _OneStepMRBG(spec, config))
        elif isinstance(spec, IterSpec):
            if config.mesh is not None:
                return _Distributed(spec, config)
            elif config.plain_shuffle:
                return _PlainIter(spec, config)
            return _IncrIter(spec, config)
        # deferred import: repro.dql lowers *to* this layer, so the api
        # package must not import it at module load
        from repro.dql.driver import _QueryDriver
        from repro.dql.lower import QuerySpec
        if isinstance(spec, QuerySpec):
            return _QueryDriver(spec, config)
        raise TypeError(f"spec must be JobSpec, IterSpec or QuerySpec, "
                        f"got {type(spec).__name__}")

    # -- lifecycle ---------------------------------------------------------
    def run(self, data: KV) -> RunReport:
        """Initial job: one-step run or iterative convergence."""
        if self.epoch >= 0:
            raise RuntimeError("run() already executed for this session; "
                               "apply changes with update(delta)")
        t0 = time.perf_counter()
        self._driver.run(data)
        self.epoch = 0
        return self._finish(t0)

    def update(self, delta: DeltaKV) -> RunReport:
        """Refresh the preserved job against a signed delta input."""
        if self.epoch < 0:
            raise RuntimeError("update() before run(); execute the initial "
                               "job first")
        t0 = time.perf_counter()
        # bucket the delta's row capacity so the jitted refresh path traces
        # once per power-of-two bucket, not once per distinct row count
        # (multi-source query deltas arrive as {source: DeltaKV}; the query
        # driver buckets each encoded feed itself)
        if isinstance(delta, DeltaKV):
            cap = next_bucket(delta.capacity, self.config.delta_bucket_min)
            if cap != delta.capacity:
                delta = pad_delta(delta, cap)
        self._driver.update(delta)
        self.epoch += 1
        return self._finish(t0)

    def rerun(self, data: KV) -> RunReport:
        """Full re-computation refresh: drop every preserved structure and
        recompute from scratch on the (fully updated) input, as one more
        epoch of this session.

        This is the scheduler's alternative to ``update(delta)`` once |Δ|
        grows past the paper's Fig. 8 crossover — the same decision the
        engine takes internally for iterative jobs (§5.2 MRBG-off), exposed
        at the session level so a serving layer can take it per micro-batch.
        """
        if self.epoch < 0:
            raise RuntimeError("rerun() before run(); execute the initial "
                               "job first")
        t0 = time.perf_counter()
        self._driver = self._make_driver()   # fresh preserved state
        self._driver.run(data)
        self.epoch += 1
        return self._finish(t0)

    def grow_records(self, capacity: int) -> None:
        """Extend the record-id address space to ``capacity`` rows.

        Streaming sources may insert brand-new record ids past the seed
        data's capacity; drivers that mirror the structure file
        (iterative / plain / distributed-iterative) extend their mirrors
        with invalid rows and rebuild derived indexes.  One-step drivers
        keep no per-record structure — record ids only feed the MK lane —
        so this is a no-op for them.  Shrinking is never performed.
        """
        hook = getattr(self._driver, "grow_records", None)
        if hook is not None:
            hook(int(capacity))

    def absorb_refresh(self, seconds: float) -> RunReport:
        """Account one refresh epoch executed *outside* ``update()``.

        The serving tier's batched cross-tenant refresh drives several
        sessions' preserved state through one shared kernel launch; each
        participant then calls this with its share of the batch wall-clock
        so ``epoch``/``history``/auto-checkpointing stay consistent with
        the per-tenant path.
        """
        if self.epoch < 0:
            raise RuntimeError("absorb_refresh() before run(); execute the "
                               "initial job first")
        self.epoch += 1
        return self._finish(time.perf_counter() - seconds)

    def _finish(self, t0: float) -> RunReport:
        # skip the dense result copy here: each epoch would otherwise pay
        # an O(|D|) device->host transfer even when nobody reads it
        rep = self.report(include_result=False)
        rep.seconds = time.perf_counter() - t0
        self._last = rep
        self.history.append(rep)
        if len(self.history) > self.config.report_history:
            del self.history[:-self.config.report_history]
        cfg = self.config
        if (cfg.checkpoint_dir is not None and cfg.checkpoint_every > 0
                and self.epoch % cfg.checkpoint_every == 0):
            self.checkpoint(cfg.checkpoint_dir)
        return rep

    # -- uniform outputs ---------------------------------------------------
    @property
    def result(self) -> Dict[str, np.ndarray]:
        """Dense host view of the job's current output values."""
        if self.epoch < 0:
            raise RuntimeError("no result before run()")
        return self._driver.result()

    def report(self, include_result: bool = True) -> RunReport:
        """Uniform report of the session's current state / last epoch.

        ``include_result=False`` skips materializing the dense host copy
        of the output (``session.result`` fetches it on demand).
        """
        if self.epoch < 0:
            raise RuntimeError("no report before run()")
        rep = RunReport(name=self.spec.name, mode=self._driver.mode,
                        epoch=self.epoch, backend=self._driver.backend(),
                        result=self._driver.result() if include_result
                        else {})
        self._driver.fill(rep)
        if self._last is not None and self._last.epoch == self.epoch:
            rep.seconds = self._last.seconds
        return rep

    # -- fault tolerance ---------------------------------------------------
    def checkpoint(self, path: Optional[str] = None) -> Path:
        """Atomically snapshot all preserved state (view/state, MRBG-Store,
        CPC accumulators, structure mirror) under ``path``."""
        from repro.api.ckpt import save_session
        target = path or self.config.checkpoint_dir
        if target is None:
            raise ValueError("no checkpoint path: pass one or set "
                             "RunConfig(checkpoint_dir=...)")
        return save_session(self, str(target))

    @classmethod
    def restore(cls, spec: Spec, path: str,
                config: Optional[RunConfig] = None) -> "Session":
        """Rebuild a session from :meth:`checkpoint` output; the next
        ``update(delta)`` resumes exactly where the snapshot left off."""
        from repro.api.ckpt import load_session
        return load_session(cls, spec, str(path), config)

    # -- escape hatches (engine internals, read-only use) ------------------
    @property
    def view(self) -> Optional[ResultView]:
        return getattr(self._driver, "view", None)

    @property
    def state(self) -> Optional[State]:
        return getattr(self._driver, "state", None)

    # -- preserved-state accounting (serving-layer hooks) ------------------
    @property
    def store(self) -> Optional[MRBGStore]:
        """The driver's MRBG-Store, if this execution path preserves one.

        Distributed sessions preserve one store *per shard* — use
        :attr:`stores` / the aggregate byte accessors there; this stays
        ``None`` for them.
        """
        drv = self._driver
        st = getattr(drv, "store", None)
        if st is None:
            st = getattr(getattr(drv, "job", None), "store", None)
        return st

    @property
    def stores(self) -> list:
        """Every MRBG-Store this session preserves: the per-shard slices of
        a distributed session, or ``[store]`` / ``[]`` otherwise."""
        sts = getattr(self._driver, "stores", None)
        if sts:
            return list(sts)
        st = self.store
        return [st] if st is not None else []

    def store_bytes(self) -> int:
        """MRBG file size including obsolete chunks, summed over shards
        (0 if nothing is preserved)."""
        return sum(s.file_bytes() for s in self.stores)

    def store_live_bytes(self) -> int:
        """Live chunk bytes, summed over shards."""
        return sum(s.live_bytes() for s in self.stores)

    def store_obsolete_bytes(self) -> int:
        """Obsolete (compactable) chunk bytes, summed over shards."""
        return sum(s.obsolete_bytes() for s in self.stores)

    def compact_store(self) -> int:
        """Offline MRBG compaction; returns the bytes reclaimed.  The
        multi-tenant server calls this on the fattest session when the
        shared store budget is exceeded."""
        return sum(s.compact() for s in self.stores)


# ---------------------------------------------------------------------------
# Drivers: one per engine path; each owns the preserved state
# ---------------------------------------------------------------------------

def _grow_mirror(drv, capacity: int) -> None:
    """Extend a driver's host structure mirror (``_keys``/``_values``/
    ``_valid``) with invalid rows up to ``capacity``."""
    capacity = int(capacity)
    n = drv._keys.shape[0]
    if capacity <= n:
        return
    pad = capacity - n
    drv._keys = np.concatenate(
        [drv._keys, np.zeros((pad,) + drv._keys.shape[1:], drv._keys.dtype)])
    drv._values = {
        name: np.concatenate([a, np.zeros((pad,) + a.shape[1:], a.dtype)])
        for name, a in drv._values.items()}
    drv._valid = np.concatenate([drv._valid, np.zeros(pad, bool)])

class _OneStepMRBG:
    """run_onestep + MRBG-Store + incremental_onestep (§3.3/§3.4)."""

    kind = "onestep-mrbg"

    def __init__(self, spec: JobSpec, cfg: RunConfig):
        self.spec = spec
        self.cfg = cfg
        self.store = MRBGStore(spec.num_keys, cfg.value_bytes,
                               policy=cfg.store_policy, **cfg.store_kw())
        self.view: Optional[ResultView] = None
        self.mode = "onestep"
        self._counts: Optional[np.ndarray] = None
        self._affected = -1

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def run(self, inp: KV) -> None:
        res = run_onestep(self.spec, inp, preserve=True,
                          backend=self.cfg.backend)
        host = edges_to_host(res.edges)
        self.store.append(host["k2"], host["mk"], _v2_dict(host["v2"]))
        self.view = ResultView.from_job(self.spec.num_keys, res.results,
                                        res.counts)
        self._counts = np.asarray(res.counts)
        self.mode = "onestep"

    def update(self, delta: DeltaKV) -> None:
        self.store.reset_stats()
        stats = incremental_onestep(self.spec, delta, self.store, self.view,
                                    backend=self.cfg.backend)
        self._affected = int(stats.get("affected", 0))
        self._counts = self.view.counts
        self.mode = "incremental"

    def result(self) -> Dict[str, np.ndarray]:
        return self.view.as_dict()

    def fill(self, rep: RunReport) -> None:
        rep.counts = self._counts
        rep.affected_keys = self._affected
        rep.io = self.store.stats
        rep.store_bytes = self.store.file_bytes()
        rep.live_bytes = self.store.live_bytes()
        rep.store_batches = self.store.n_batches


class _OneStepAccumulator:
    """Accumulator-Reduce fast path: preserves only <K3,V3> (§3.5)."""

    kind = "onestep-accumulator"

    def __init__(self, spec: JobSpec, cfg: RunConfig):
        from repro.core.accumulator import AccumulatorJob
        self.spec = spec
        self.cfg = cfg
        self.job = AccumulatorJob(spec, backend=cfg.backend)
        self.mode = "onestep"

    @property
    def view(self) -> Optional[ResultView]:
        return self.job.view

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def run(self, inp: KV) -> None:
        self.job.initial_run(inp)
        self.mode = "onestep"

    def update(self, delta: DeltaKV) -> None:
        self.job.incremental_run(delta)
        self.mode = "accumulator"

    def result(self) -> Dict[str, np.ndarray]:
        return self.job.view.as_dict()

    def fill(self, rep: RunReport) -> None:
        rep.counts = self.job.view.counts
        rep.mrbg_on = False               # nothing preserved beyond <K3,V3>


class _IncrIter:
    """IncrIterJob: converge once, then fine-grain refresh (§5)."""

    kind = "incr-iter"

    def __init__(self, spec: IterSpec, cfg: RunConfig):
        self.spec = spec
        self.cfg = cfg
        self.job = None                   # built on run() (needs struct)
        self.mode = "iterative"
        self._iters = 0
        self._max_change: list = []
        self._logs: list = []

    @property
    def state(self) -> Optional[State]:
        return self.job.state if self.job is not None else None

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def _make_job(self, struct: KV):
        from repro.core.incr_iter import IncrIterJob
        return IncrIterJob(
            struct=struct, spec=self.spec,
            value_bytes=self.cfg.value_bytes,
            policy=self.cfg.store_policy,
            cpc_threshold=self.cfg.cpc_threshold,
            pdelta_threshold=self.cfg.pdelta_threshold,
            backend=self.cfg.backend, store_kw=self.cfg.store_kw())

    def run(self, struct: KV) -> None:
        self.job = self._make_job(struct)
        _, hist = self.job.initial_converge(max_iters=self.cfg.max_iters,
                                            tol=self.cfg.tol)
        self.mode = "iterative"
        self._iters = hist["iters"]
        self._max_change = hist["max_change"]
        self._logs = []

    def update(self, delta: DeltaKV) -> None:
        _, hist = self.job.refresh(delta,
                                   max_iters=self.cfg.refresh_iters_,
                                   tol=self.cfg.refresh_tol_)
        self.mode = hist["mode"]
        self._iters = hist["iters"]
        self._logs = hist.get("logs", [])
        self._max_change = []

    def grow_records(self, capacity: int) -> None:
        if self.job is not None:
            self.job.grow_records(capacity)

    def result(self) -> Dict[str, np.ndarray]:
        return self.job.state.to_host()

    def fill(self, rep: RunReport) -> None:
        rep.iters = self._iters
        rep.max_change = list(self._max_change)
        rep.logs = list(self._logs)
        if self._logs:
            rep.affected_keys = sum(l.n_affected_dks for l in self._logs)
            rep.io = IOStats(n_reads=sum(l.io_reads for l in self._logs),
                             bytes_read=sum(l.io_bytes for l in self._logs))
        rep.store_bytes = self.job.store.file_bytes()
        rep.live_bytes = self.job.store.live_bytes()
        rep.store_batches = self.job.store.n_batches
        rep.mrbg_on = self.job.mrbg_on


class _PlainIter:
    """plainMR recomp baseline: re-shuffles structure data every iteration
    and recomputes every epoch from scratch (Algorithm 5 cost model)."""

    kind = "plain-iter"

    def __init__(self, spec: IterSpec, cfg: RunConfig):
        self.spec = spec
        self.cfg = cfg
        self.state: Optional[State] = None
        self.mode = "plainMR"
        self._iters = 0
        self._max_change: list = []

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def run(self, struct: KV) -> None:
        self._keys = np.array(struct.keys)
        self._values = {n: np.array(a) for n, a in struct.values.items()}
        self._valid = np.array(struct.valid)
        self._converge(self.cfg.max_iters, self.cfg.tol)

    def _struct_kv(self) -> KV:
        return KV(jnp.asarray(self._keys),
                  {n: jnp.asarray(a) for n, a in self._values.items()},
                  jnp.asarray(self._valid))

    def _converge(self, max_iters: int, tol: float) -> None:
        self.state, hist = run_plain(self.spec, self._struct_kv(), None,
                                     max_iters=max_iters, tol=tol,
                                     backend=self.cfg.backend)
        self._iters = hist["iters"]
        self._max_change = hist["max_change"]

    def update(self, delta: DeltaKV) -> None:
        apply_delta_host(self._keys, self._values, self._valid, delta)
        # vanilla MR: recompute everything (under the refresh budget)
        self._converge(self.cfg.refresh_iters_, self.cfg.refresh_tol_)

    def grow_records(self, capacity: int) -> None:
        _grow_mirror(self, capacity)

    def result(self) -> Dict[str, np.ndarray]:
        return self.state.to_host()

    def fill(self, rep: RunReport) -> None:
        rep.iters = self._iters
        rep.max_change = list(self._max_change)
        rep.mrbg_on = False


class _Distributed:
    """shard_map + all_to_all prime loop over a MeshConfig (§4.3).

    ``update`` is kv-pair-level by default (``MeshConfig(refresh="fine")``):
    delta rows are partitioned by ``hash(project(SK))`` (Eq. 2), one
    ``all_to_all`` routes the re-Mapped delta edges to their owner shards,
    and each shard merges them against its **local** MRBG slice with the
    same kernels the single-device incremental path uses — no host-mirror
    repartition, no re-converge.  CPC filtering and the §5.2 auto MRBG-off
    fallback run globally over the per-shard results.

    ``MeshConfig(refresh="warm")`` — or an unstable map topology, or a
    tripped MRBG-off — re-partitions the mirror and re-converges warm from
    the current co-located state: the pre-MeshConfig behavior and the
    Fig. 8 rerun-side baseline.
    """

    kind = "distributed"

    def __init__(self, spec: IterSpec, cfg: RunConfig):
        if spec.replicate_state:
            raise ValueError(
                "replicate_state (all-to-one) specs broadcast their state; "
                "the co-partitioned distributed engine does not support "
                "them — run without a mesh (auto iterMR mode)")
        self.spec = spec
        self.cfg = cfg
        self.mc = cfg.mesh
        self.n_parts = self.mc.n_parts
        self.rows = (spec.num_state + self.n_parts - 1) // self.n_parts
        self.state_parts: Optional[Dict[str, np.ndarray]] = None
        # fine-grain preserved state: one MRBG slice per shard, keyed by
        # local ids (K2 // P); None until the first converge seeds them
        self.stores: Optional[list] = None
        self.cpc_accum = np.zeros(spec.num_state, np.float32)
        self.mrbg_on = True
        self.mode = "distributed"
        self._fine = (self.mc.refresh == "fine") and spec.stable_topology
        self._iters = 0
        self._max_change: list = []
        self._logs: list = []
        self._shuffle = ShuffleStats()
        self._step_cache: dict = {}       # converge steps, reused across epochs
        self._dx_step = None              # the delta-exchange jit, built once

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def _edge_bytes(self) -> int:
        # wire bytes per exchanged edge: K2 + MK (4+4), valid + sign (1+1),
        # plus the V2 payload
        return 10 + self.cfg.value_bytes

    def _rebuild_rev(self) -> None:
        from repro.core.incr_iter import build_reverse_index
        self.rev_indptr, self.rev_ids, self.dks_host = build_reverse_index(
            self.spec.project, self._keys, self._valid, self.spec.num_state)

    def run(self, struct: KV) -> None:
        self._keys = np.array(struct.keys)
        self._values = {n: np.array(a) for n, a in struct.values.items()}
        self._valid = np.array(struct.valid)
        self._rebuild_rev()
        if self.state_parts is None:      # may be pre-seeded by restore
            from repro.core.distributed import partition_state
            dks = jnp.arange(self.spec.num_state, dtype=jnp.int32)
            init = jax.tree.map(np.asarray, self.spec.init_state(dks))
            self.state_parts = partition_state(init, self.spec.num_state,
                                               self.n_parts)
        self._shuffle = ShuffleStats()
        self._logs = []
        self._converge(self.cfg.max_iters, self.cfg.tol)
        self.mode = "distributed"

    def _partition_cap(self) -> int:
        if self.mc.partition_cap is not None:
            return self.mc.partition_cap
        dks = np.asarray(jax.jit(self.spec.project)(jnp.asarray(self._keys)))
        pid = (dks.astype(np.uint32) % self.n_parts).astype(np.int32)
        load = np.bincount(pid[self._valid], minlength=self.n_parts)
        return next_bucket(max(int(load.max()), 1), 64)

    def _converge(self, max_iters: int, tol: float) -> None:
        from repro.core.distributed import partition_struct, run_distributed
        mc = self.mc
        parts = partition_struct(self.spec, self._keys, self._values,
                                 self._valid, self.n_parts,
                                 self._partition_cap())
        out, hist = run_distributed(
            self.spec, mc.mesh, parts, self.state_parts,
            axis=mc.axis, pod_axis=mc.pod_axis,
            shuffle_cap=mc.shuffle_cap, max_iters=max_iters,
            tol=tol, backend=self.cfg.backend, auto_grow=mc.auto_grow,
            preserve_last=self._fine, step_cache=self._step_cache)
        # np.array (not asarray): the fine path patches slices in place
        self.state_parts = {n: np.array(a) for n, a in out.items()}
        self._iters = hist["iters"]
        self._max_change = hist["max_change"]
        sh = self._shuffle
        sh.edges_exchanged += hist["sent"]
        sh.bytes_moved += hist["sent"] * self._edge_bytes()
        sh.exchange_seconds.extend(hist["exchange_seconds"])
        sh.shuffle_cap = hist["shuffle_cap"]
        sh.regrows += hist["regrows"]
        if self._fine:
            self._seed_stores(hist["last_edges"])

    def _seed_stores(self, last_edges) -> None:
        """Per-shard MRBG slices from the final iteration's received edges
        (``reduce(slice[p]) == state[p]`` by construction)."""
        cfg = self.cfg
        self.stores = [MRBGStore(self.rows, cfg.value_bytes,
                                 policy=cfg.store_policy, **cfg.store_kw())
                       for _ in range(self.n_parts)]
        for p, ed in enumerate(last_edges or []):
            if ed["k2"].size == 0:
                continue
            local = ((ed["k2"].astype(np.int64) - p)
                     // self.n_parts).astype(np.int32)
            self.stores[p].append(local, ed["mk"], _v2_dict(ed["v2"]))
        self.cpc_accum[:] = 0.0
        self.mrbg_on = True

    # -- refresh -----------------------------------------------------------
    def update(self, delta: DeltaKV) -> None:
        self._shuffle = ShuffleStats()
        self._logs = []
        snap = self._snapshot()
        try:
            if not (self._fine and self.mrbg_on and self.stores is not None):
                # warm re-converge: mirror repartition + prime loop (re-seeds
                # the per-shard slices when fine refresh is enabled, so
                # MRBG-off recovers exactly like §5.2's
                # rebuild-after-fallback)
                apply_delta_host(self._keys, self._values, self._valid,
                                 delta)
                self._rebuild_rev()
                self._converge(self.cfg.refresh_iters_, self.cfg.refresh_tol_)
                self.mode = "distributed-warm"
                return
            fell_back = self._fine_refresh(delta)
        except Exception:
            self._restore(snap)           # never leave the session diverged
            raise
        self.mode = "distributed-warm" if fell_back else "distributed-i2"

    def grow_records(self, capacity: int) -> None:
        n = self._keys.shape[0]
        _grow_mirror(self, capacity)
        if self._keys.shape[0] != n:
            self._rebuild_rev()

    def _snapshot(self):
        return (self._keys.copy(),
                {n: a.copy() for n, a in self._values.items()},
                self._valid.copy(),
                {n: a.copy() for n, a in self.state_parts.items()},
                self.cpc_accum.copy(),
                ([s.clone() for s in self.stores]
                 if self.stores is not None else None),
                self.mrbg_on)

    def _restore(self, snap) -> None:
        (self._keys, self._values, self._valid, self.state_parts,
         self.cpc_accum, self.stores, self.mrbg_on) = snap
        self._rebuild_rev()

    def _fine_refresh(self, delta: DeltaKV) -> bool:
        """Kv-pair-level refresh; returns True if it fell back to warm."""
        cfg = self.cfg
        apply_delta_host(self._keys, self._values, self._valid, delta)
        self._rebuild_rev()
        self._max_change = []
        max_iters, tol = cfg.refresh_iters_, cfg.refresh_tol_

        # iteration 1: delta input = delta structure data
        n_input = int(np.asarray(delta.valid).sum())
        changed = self._fine_iteration(delta, iteration=1, n_input=n_input)
        if changed is None:               # P_Δ blew past the threshold
            self._fallback_converge(max_iters, tol)
            return True

        # iterations >= 2: delta input = delta state data (reverse index)
        from repro.core.incr_iter import records_of_dks
        for it in range(2, max_iters + 1):
            if changed.size == 0 or (self._max_change
                                     and self._max_change[-1] < tol):
                break
            recs = records_of_dks(self.rev_indptr, self.rev_ids, changed)
            if recs.size == 0:
                break
            d2 = DeltaKV(self._keys[recs], recs,
                         {n: a[recs] for n, a in self._values.items()},
                         self._valid[recs], np.ones(recs.size, np.int8))
            changed = self._fine_iteration(d2, iteration=it,
                                           n_input=int(changed.size))
            if changed is None:
                self._fallback_converge(max_iters - it, tol)
                return True
        self._iters = len(self._logs)
        return False

    def _fallback_converge(self, max_iters: int, tol: float) -> None:
        """§5.2 MRBG-off recovery: warm re-converge + store re-seed (the
        distributed analogue of IncrIterJob._fallback_iterate)."""
        t0 = time.perf_counter()
        self._converge(max_iters, tol)
        self._logs.append(IterationLog(
            -1, 0, self.spec.num_state, self.spec.num_state, False,
            time.perf_counter() - t0))

    def _fine_iteration(self, delta, iteration: int, n_input: int):
        """One fine-grain iteration: delta exchange (device) + per-shard
        merges (host).  Returns emitted DKs, or None => fall back."""
        from repro.core.distributed import (
            delta_exchange_to_host, make_delta_exchange_step,
            merge_shards_parallel, partition_delta)
        spec, cfg, n_parts = self.spec, self.cfg, self.n_parts
        t0 = time.perf_counter()
        for s in self.stores:
            s.reset_stats()

        # phase 1: partition the delta rows by hash(project(SK)) (Eq. 2)
        # and exchange the re-Mapped edges; per-shard row capacity is
        # bucketed so the step traces once per bucket, not per row count
        keys = np.asarray(delta.keys)
        valid = np.asarray(delta.valid).astype(bool)
        dks = np.asarray(jax.jit(spec.project)(jnp.asarray(keys)))
        pid = (dks.astype(np.uint32) % np.uint32(n_parts)).astype(np.int32)
        load = np.bincount(pid[valid], minlength=n_parts)
        cap = next_bucket(max(int(load.max(initial=0)), 1),
                          cfg.delta_bucket_min)
        pk, pv, pvalid, psign = partition_delta(delta, n_parts, cap,
                                                project=spec.project)
        if self._dx_step is None:
            self._dx_step = make_delta_exchange_step(
                spec, self.mc.mesh, self.mc.axis,
                pod_axis=self.mc.pod_axis, backend=cfg.backend)
        tx = time.perf_counter()
        outs = self._dx_step(jnp.asarray(pk), jax.tree.map(jnp.asarray, pv),
                             jnp.asarray(pvalid), jnp.asarray(psign),
                             jax.tree.map(jnp.asarray, self.state_parts))
        shards, sent, _dropped = delta_exchange_to_host(outs)
        sh = self._shuffle
        sh.exchange_seconds.append(time.perf_counter() - tx)
        sh.edges_exchanged += sent
        sh.bytes_moved += sent * self._edge_bytes()
        sh.shuffle_cap = int(np.asarray(outs[0]).shape[1]) // n_parts

        # phase 2: per-shard MRBG merges (disjoint global key sets),
        # threaded across shards; CPC/state updates apply in shard order
        diff_fn = spec.difference
        affected_total = 0
        max_change = 0.0
        affected_parts = []
        merged = merge_shards_parallel(
            spec.reducer, self.stores, n_parts, shards,
            backend=cfg.backend, workers=self.mc.merge_workers)
        for p, aff, vals, _counts in merged:
            if aff.size == 0:
                continue
            affected_total += int(aff.size)
            local = (aff.astype(np.int64) // n_parts)
            old = {n: jnp.asarray(self.state_parts[n][p, local])
                   for n in self.state_parts}
            change = np.asarray(diff_fn(
                {n: jnp.asarray(a) for n, a in vals.items()}, old))
            if change.size:
                max_change = max(max_change, float(change.max()))
            self.cpc_accum[aff] += change
            for n, a in vals.items():
                self.state_parts[n][p, local] = a
            affected_parts.append(aff)

        if affected_total == 0:
            self._max_change.append(0.0)
            self._logs.append(IterationLog(
                iteration, n_input, 0, 0, True,
                time.perf_counter() - t0))
            return np.zeros(0, np.int64)
        self._max_change.append(max_change)

        # CPC (§5.3), global across shards: emit only above-threshold DKs
        affected_all = np.concatenate(affected_parts)
        emit_mask = self.cpc_accum[affected_all] > cfg.cpc_threshold
        emitted = np.sort(affected_all[emit_mask]).astype(np.int64)
        self.cpc_accum[emitted] = 0.0
        self._logs.append(IterationLog(
            iteration, n_input, affected_total, int(emitted.size), True,
            time.perf_counter() - t0,
            sum(s.stats.n_reads for s in self.stores),
            sum(s.stats.bytes_read for s in self.stores)))

        # auto MRBG-off (§5.2): fine-grain state stops paying off
        p_delta = emitted.size / max(spec.num_state, 1)
        if p_delta > cfg.pdelta_threshold:
            self.mrbg_on = False
            return None
        return emitted

    def result(self) -> Dict[str, np.ndarray]:
        from repro.core.distributed import unpartition_state
        return unpartition_state(self.state_parts, self.spec.num_state)

    def fill(self, rep: RunReport) -> None:
        rep.iters = self._iters
        rep.max_change = list(self._max_change)
        rep.logs = list(self._logs)
        if self._logs:
            rep.affected_keys = sum(l.n_affected_dks for l in self._logs)
            rep.io = IOStats(n_reads=sum(l.io_reads for l in self._logs),
                             bytes_read=sum(l.io_bytes for l in self._logs))
        if self.stores:
            rep.store_bytes = sum(s.file_bytes() for s in self.stores)
            rep.live_bytes = sum(s.live_bytes() for s in self.stores)
            rep.store_batches = sum(s.n_batches for s in self.stores)
        rep.mrbg_on = bool(self.stores) and self.mrbg_on
        rep.shuffle = self._shuffle


class _DistOneStep:
    """Per-shard one-step job on a mesh: `_OneStepMRBG`'s semantics, with
    the MRBGraph sliced across shards by the Eq. 1 hash.

    The initial run reuses the refresh machinery — every input record is an
    all-'+' delta against empty per-shard stores — so there is exactly one
    device program (the delta exchange) and one merge path, warm from
    epoch 0 onward.
    """

    kind = "distributed-onestep"

    def __init__(self, spec: JobSpec, cfg: RunConfig):
        self.spec = spec
        self.cfg = cfg
        self.mc = cfg.mesh
        self.n_parts = self.mc.n_parts
        self.rows = (spec.num_keys + self.n_parts - 1) // self.n_parts
        self.stores: Optional[list] = None
        self.view: Optional[ResultView] = None
        self.mode = "distributed"
        self.mrbg_on = True
        self._affected = -1
        self._shuffle = ShuffleStats()
        self._dx_step = None

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def _edge_bytes(self) -> int:
        return 10 + self.cfg.value_bytes

    def _fresh_stores(self) -> list:
        cfg = self.cfg
        return [MRBGStore(self.rows, cfg.value_bytes,
                          policy=cfg.store_policy, **cfg.store_kw())
                for _ in range(self.n_parts)]

    def run(self, inp: KV) -> None:
        self._shuffle = ShuffleStats()
        self.stores = self._fresh_stores()
        self.view = None
        delta = DeltaKV(np.asarray(inp.keys), np.asarray(inp.keys),
                        jax.tree.map(np.asarray, inp.values),
                        np.asarray(inp.valid),
                        np.ones(inp.capacity, np.int8))
        self._refresh(delta)
        self.mode = "distributed"

    def update(self, delta: DeltaKV) -> None:
        self._shuffle = ShuffleStats()
        snap = ([s.clone() for s in self.stores],
                ResultView(self.view.num_keys,
                           {n: a.copy() for n, a in self.view.values.items()},
                           self.view.valid.copy(), self.view.counts.copy()))
        try:
            self._refresh(delta)
        except Exception:
            self.stores, self.view = snap
            raise
        self.mode = "distributed-incr"

    def _refresh(self, delta: DeltaKV) -> None:
        from repro.core.distributed import (
            delta_exchange_to_host, make_delta_exchange_step,
            merge_shards_parallel, partition_delta)
        spec, cfg, n_parts = self.spec, self.cfg, self.n_parts
        for s in self.stores:
            s.reset_stats()

        keys = np.asarray(delta.keys)
        valid = np.asarray(delta.valid).astype(bool)
        pid = (keys.astype(np.uint32) % np.uint32(n_parts)).astype(np.int32)
        load = np.bincount(pid[valid], minlength=n_parts)
        cap = next_bucket(max(int(load.max(initial=0)), 1),
                          cfg.delta_bucket_min)
        pk, pv, pvalid, psign = partition_delta(delta, n_parts, cap)
        if self._dx_step is None:
            self._dx_step = make_delta_exchange_step(
                spec, self.mc.mesh, self.mc.axis,
                pod_axis=self.mc.pod_axis, backend=cfg.backend)
        tx = time.perf_counter()
        outs = self._dx_step(jnp.asarray(pk), jax.tree.map(jnp.asarray, pv),
                             jnp.asarray(pvalid), jnp.asarray(psign))
        shards, sent, _dropped = delta_exchange_to_host(outs)
        sh = self._shuffle
        sh.exchange_seconds.append(time.perf_counter() - tx)
        sh.edges_exchanged += sent
        sh.bytes_moved += sent * self._edge_bytes()
        sh.shuffle_cap = int(np.asarray(outs[0]).shape[1]) // n_parts

        affected_total = 0
        merged = merge_shards_parallel(
            spec.reducer, self.stores, n_parts, shards,
            backend=cfg.backend, workers=self.mc.merge_workers)
        for p, aff, vals, counts in merged:
            if aff.size == 0:
                continue
            affected_total += int(aff.size)
            if self.view is None:
                self.view = ResultView(
                    spec.num_keys,
                    {n: np.zeros((spec.num_keys,) + a.shape[1:], a.dtype)
                     for n, a in vals.items()},
                    np.zeros(spec.num_keys, bool),
                    np.zeros(spec.num_keys, np.int32))
            self.view.patch(aff, vals, counts)
        self._affected = affected_total

    def result(self) -> Dict[str, np.ndarray]:
        return self.view.as_dict() if self.view is not None else {}

    def fill(self, rep: RunReport) -> None:
        rep.affected_keys = self._affected
        if self.view is not None:
            rep.counts = self.view.counts
        if self.stores:
            rep.store_bytes = sum(s.file_bytes() for s in self.stores)
            rep.live_bytes = sum(s.live_bytes() for s in self.stores)
            rep.store_batches = sum(s.n_batches for s in self.stores)
            rep.io = IOStats(
                n_reads=sum(s.stats.n_reads for s in self.stores),
                bytes_read=sum(s.stats.bytes_read for s in self.stores))
        rep.shuffle = self._shuffle
