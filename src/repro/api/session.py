"""Session: the single supported way to drive the i2MapReduce engine.

A job is declared once (a :class:`JobSpec` or :class:`IterSpec`) together
with one :class:`RunConfig`; the session then transparently routes

  * ``run(data)``     -> full one-step execution, or prime-loop convergence,
  * ``update(delta)`` -> fine-grain incremental refresh (§3.3), the
                         accumulator fast path (§3.5), incremental iterative
                         refresh with CPC + auto MRBG-off (§5), or a
                         distributed re-converge,
  * ``result`` / ``report()`` -> one uniform output surface,
  * ``checkpoint()`` / ``restore()`` -> fault tolerance (§6),

exactly as the paper presents i2MapReduce: one system, with the engine —
not the caller — choosing between incremental refresh, iterative
recomputation, and fallback re-computation.  Distributed execution is not a
different API: ``RunConfig(mesh=...)`` turns the same spec into the
shard_map + all_to_all engine of §4.3.

The historical entry points (``run_onestep``, ``IncrementalJob``,
``run_iterative``/``run_plain``, ``IncrIterJob``, ``run_distributed``,
``AccumulatorJob``, ``checkpoint_job``/``restore_job``) are the internal
implementation that the Session drives; they carry no API stability promise.
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.config import RunConfig
from repro.api.report import RunReport
from repro.core.engine import JobSpec, run_onestep
from repro.core.incremental import (
    DeltaKV, ResultView, _v2_dict, apply_delta_host, incremental_onestep,
    pad_delta,
)
from repro.core.iterative import IterSpec, State, run_iterative, run_plain
from repro.core.kvstore import KV, edges_to_host, next_bucket
from repro.core.mrbg_store import IOStats, MRBGStore
from repro.kernels import jitcache

Spec = Union[JobSpec, IterSpec]


class Session:
    """Owns one declared job and all of its preserved state across epochs."""

    def __init__(self, spec: Spec, config: Optional[RunConfig] = None):
        self.spec = spec
        self.config = config or RunConfig()
        if self.config.compilation_cache_dir is not None:
            jitcache.enable_persistent_cache(self.config.compilation_cache_dir)
        self.epoch = -1                     # becomes 0 on run()
        self._last: Optional[RunReport] = None
        # bounded RunReport history (oldest first) — the raw material for
        # online refresh-cost models (repro.stream.RefreshScheduler)
        self.history: list = []
        self._driver = self._make_driver()

    def _make_driver(self):
        spec, config = self.spec, self.config
        if isinstance(spec, JobSpec):
            if config.mesh is not None:
                raise ValueError(
                    "distributed execution currently requires an IterSpec "
                    "(one-step jobs have no structure/state co-partitioning)")
            path = config.onestep_path
            if path == "auto":
                path = ("accumulator" if spec.reducer.invertible else "mrbg")
            return (_OneStepAccumulator(spec, config)
                    if path == "accumulator" else _OneStepMRBG(spec, config))
        elif isinstance(spec, IterSpec):
            if config.mesh is not None:
                return _Distributed(spec, config)
            elif config.plain_shuffle:
                return _PlainIter(spec, config)
            return _IncrIter(spec, config)
        raise TypeError(f"spec must be JobSpec or IterSpec, "
                        f"got {type(spec).__name__}")

    # -- lifecycle ---------------------------------------------------------
    def run(self, data: KV) -> RunReport:
        """Initial job: one-step run or iterative convergence."""
        if self.epoch >= 0:
            raise RuntimeError("run() already executed for this session; "
                               "apply changes with update(delta)")
        t0 = time.perf_counter()
        self._driver.run(data)
        self.epoch = 0
        return self._finish(t0)

    def update(self, delta: DeltaKV) -> RunReport:
        """Refresh the preserved job against a signed delta input."""
        if self.epoch < 0:
            raise RuntimeError("update() before run(); execute the initial "
                               "job first")
        t0 = time.perf_counter()
        # bucket the delta's row capacity so the jitted refresh path traces
        # once per power-of-two bucket, not once per distinct row count
        cap = next_bucket(delta.capacity, self.config.delta_bucket_min)
        if cap != delta.capacity:
            delta = pad_delta(delta, cap)
        self._driver.update(delta)
        self.epoch += 1
        return self._finish(t0)

    def rerun(self, data: KV) -> RunReport:
        """Full re-computation refresh: drop every preserved structure and
        recompute from scratch on the (fully updated) input, as one more
        epoch of this session.

        This is the scheduler's alternative to ``update(delta)`` once |Δ|
        grows past the paper's Fig. 8 crossover — the same decision the
        engine takes internally for iterative jobs (§5.2 MRBG-off), exposed
        at the session level so a serving layer can take it per micro-batch.
        """
        if self.epoch < 0:
            raise RuntimeError("rerun() before run(); execute the initial "
                               "job first")
        t0 = time.perf_counter()
        self._driver = self._make_driver()   # fresh preserved state
        self._driver.run(data)
        self.epoch += 1
        return self._finish(t0)

    def _finish(self, t0: float) -> RunReport:
        # skip the dense result copy here: each epoch would otherwise pay
        # an O(|D|) device->host transfer even when nobody reads it
        rep = self.report(include_result=False)
        rep.seconds = time.perf_counter() - t0
        self._last = rep
        self.history.append(rep)
        if len(self.history) > self.config.report_history:
            del self.history[:-self.config.report_history]
        cfg = self.config
        if (cfg.checkpoint_dir is not None and cfg.checkpoint_every > 0
                and self.epoch % cfg.checkpoint_every == 0):
            self.checkpoint(cfg.checkpoint_dir)
        return rep

    # -- uniform outputs ---------------------------------------------------
    @property
    def result(self) -> Dict[str, np.ndarray]:
        """Dense host view of the job's current output values."""
        if self.epoch < 0:
            raise RuntimeError("no result before run()")
        return self._driver.result()

    def report(self, include_result: bool = True) -> RunReport:
        """Uniform report of the session's current state / last epoch.

        ``include_result=False`` skips materializing the dense host copy
        of the output (``session.result`` fetches it on demand).
        """
        if self.epoch < 0:
            raise RuntimeError("no report before run()")
        rep = RunReport(name=self.spec.name, mode=self._driver.mode,
                        epoch=self.epoch, backend=self._driver.backend(),
                        result=self._driver.result() if include_result
                        else {})
        self._driver.fill(rep)
        if self._last is not None and self._last.epoch == self.epoch:
            rep.seconds = self._last.seconds
        return rep

    # -- fault tolerance ---------------------------------------------------
    def checkpoint(self, path: Optional[str] = None) -> Path:
        """Atomically snapshot all preserved state (view/state, MRBG-Store,
        CPC accumulators, structure mirror) under ``path``."""
        from repro.api.ckpt import save_session
        target = path or self.config.checkpoint_dir
        if target is None:
            raise ValueError("no checkpoint path: pass one or set "
                             "RunConfig(checkpoint_dir=...)")
        return save_session(self, str(target))

    @classmethod
    def restore(cls, spec: Spec, path: str,
                config: Optional[RunConfig] = None) -> "Session":
        """Rebuild a session from :meth:`checkpoint` output; the next
        ``update(delta)`` resumes exactly where the snapshot left off."""
        from repro.api.ckpt import load_session
        return load_session(cls, spec, str(path), config)

    # -- escape hatches (engine internals, read-only use) ------------------
    @property
    def view(self) -> Optional[ResultView]:
        return getattr(self._driver, "view", None)

    @property
    def state(self) -> Optional[State]:
        return getattr(self._driver, "state", None)

    # -- preserved-state accounting (serving-layer hooks) ------------------
    @property
    def store(self) -> Optional[MRBGStore]:
        """The driver's MRBG-Store, if this execution path preserves one."""
        drv = self._driver
        st = getattr(drv, "store", None)
        if st is None:
            st = getattr(getattr(drv, "job", None), "store", None)
        return st

    def store_bytes(self) -> int:
        """MRBG file size including obsolete chunks (0 if no store)."""
        st = self.store
        return st.file_bytes() if st is not None else 0

    def compact_store(self) -> int:
        """Offline MRBG compaction; returns the bytes reclaimed.  The
        multi-tenant server calls this on the fattest session when the
        shared store budget is exceeded."""
        st = self.store
        return st.compact() if st is not None else 0


# ---------------------------------------------------------------------------
# Drivers: one per engine path; each owns the preserved state
# ---------------------------------------------------------------------------

class _OneStepMRBG:
    """run_onestep + MRBG-Store + incremental_onestep (§3.3/§3.4)."""

    kind = "onestep-mrbg"

    def __init__(self, spec: JobSpec, cfg: RunConfig):
        self.spec = spec
        self.cfg = cfg
        self.store = MRBGStore(spec.num_keys, cfg.value_bytes,
                               policy=cfg.store_policy, **cfg.store_kw())
        self.view: Optional[ResultView] = None
        self.mode = "onestep"
        self._counts: Optional[np.ndarray] = None
        self._affected = -1

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def run(self, inp: KV) -> None:
        res = run_onestep(self.spec, inp, preserve=True,
                          backend=self.cfg.backend)
        host = edges_to_host(res.edges)
        self.store.append(host["k2"], host["mk"], _v2_dict(host["v2"]))
        self.view = ResultView.from_job(self.spec.num_keys, res.results,
                                        res.counts)
        self._counts = np.asarray(res.counts)
        self.mode = "onestep"

    def update(self, delta: DeltaKV) -> None:
        self.store.reset_stats()
        stats = incremental_onestep(self.spec, delta, self.store, self.view,
                                    backend=self.cfg.backend)
        self._affected = int(stats.get("affected", 0))
        self._counts = self.view.counts
        self.mode = "incremental"

    def result(self) -> Dict[str, np.ndarray]:
        return self.view.as_dict()

    def fill(self, rep: RunReport) -> None:
        rep.counts = self._counts
        rep.affected_keys = self._affected
        rep.io = self.store.stats
        rep.store_bytes = self.store.file_bytes()
        rep.live_bytes = self.store.live_bytes()
        rep.store_batches = self.store.n_batches


class _OneStepAccumulator:
    """Accumulator-Reduce fast path: preserves only <K3,V3> (§3.5)."""

    kind = "onestep-accumulator"

    def __init__(self, spec: JobSpec, cfg: RunConfig):
        from repro.core.accumulator import AccumulatorJob
        self.spec = spec
        self.cfg = cfg
        self.job = AccumulatorJob(spec, backend=cfg.backend)
        self.mode = "onestep"

    @property
    def view(self) -> Optional[ResultView]:
        return self.job.view

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def run(self, inp: KV) -> None:
        self.job.initial_run(inp)
        self.mode = "onestep"

    def update(self, delta: DeltaKV) -> None:
        self.job.incremental_run(delta)
        self.mode = "accumulator"

    def result(self) -> Dict[str, np.ndarray]:
        return self.job.view.as_dict()

    def fill(self, rep: RunReport) -> None:
        rep.counts = self.job.view.counts
        rep.mrbg_on = False               # nothing preserved beyond <K3,V3>


class _IncrIter:
    """IncrIterJob: converge once, then fine-grain refresh (§5)."""

    kind = "incr-iter"

    def __init__(self, spec: IterSpec, cfg: RunConfig):
        self.spec = spec
        self.cfg = cfg
        self.job = None                   # built on run() (needs struct)
        self.mode = "iterative"
        self._iters = 0
        self._max_change: list = []
        self._logs: list = []

    @property
    def state(self) -> Optional[State]:
        return self.job.state if self.job is not None else None

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def _make_job(self, struct: KV):
        from repro.core.incr_iter import IncrIterJob
        return IncrIterJob(
            struct=struct, spec=self.spec,
            value_bytes=self.cfg.value_bytes,
            policy=self.cfg.store_policy,
            cpc_threshold=self.cfg.cpc_threshold,
            pdelta_threshold=self.cfg.pdelta_threshold,
            backend=self.cfg.backend, store_kw=self.cfg.store_kw())

    def run(self, struct: KV) -> None:
        self.job = self._make_job(struct)
        _, hist = self.job.initial_converge(max_iters=self.cfg.max_iters,
                                            tol=self.cfg.tol)
        self.mode = "iterative"
        self._iters = hist["iters"]
        self._max_change = hist["max_change"]
        self._logs = []

    def update(self, delta: DeltaKV) -> None:
        _, hist = self.job.refresh(delta,
                                   max_iters=self.cfg.refresh_iters_,
                                   tol=self.cfg.refresh_tol_)
        self.mode = hist["mode"]
        self._iters = hist["iters"]
        self._logs = hist.get("logs", [])
        self._max_change = []

    def result(self) -> Dict[str, np.ndarray]:
        return self.job.state.to_host()

    def fill(self, rep: RunReport) -> None:
        rep.iters = self._iters
        rep.max_change = list(self._max_change)
        rep.logs = list(self._logs)
        if self._logs:
            rep.affected_keys = sum(l.n_affected_dks for l in self._logs)
            rep.io = IOStats(n_reads=sum(l.io_reads for l in self._logs),
                             bytes_read=sum(l.io_bytes for l in self._logs))
        rep.store_bytes = self.job.store.file_bytes()
        rep.live_bytes = self.job.store.live_bytes()
        rep.store_batches = self.job.store.n_batches
        rep.mrbg_on = self.job.mrbg_on


class _PlainIter:
    """plainMR recomp baseline: re-shuffles structure data every iteration
    and recomputes every epoch from scratch (Algorithm 5 cost model)."""

    kind = "plain-iter"

    def __init__(self, spec: IterSpec, cfg: RunConfig):
        self.spec = spec
        self.cfg = cfg
        self.state: Optional[State] = None
        self.mode = "plainMR"
        self._iters = 0
        self._max_change: list = []

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def run(self, struct: KV) -> None:
        self._keys = np.array(struct.keys)
        self._values = {n: np.array(a) for n, a in struct.values.items()}
        self._valid = np.array(struct.valid)
        self._converge(self.cfg.max_iters, self.cfg.tol)

    def _struct_kv(self) -> KV:
        return KV(jnp.asarray(self._keys),
                  {n: jnp.asarray(a) for n, a in self._values.items()},
                  jnp.asarray(self._valid))

    def _converge(self, max_iters: int, tol: float) -> None:
        self.state, hist = run_plain(self.spec, self._struct_kv(), None,
                                     max_iters=max_iters, tol=tol,
                                     backend=self.cfg.backend)
        self._iters = hist["iters"]
        self._max_change = hist["max_change"]

    def update(self, delta: DeltaKV) -> None:
        apply_delta_host(self._keys, self._values, self._valid, delta)
        # vanilla MR: recompute everything (under the refresh budget)
        self._converge(self.cfg.refresh_iters_, self.cfg.refresh_tol_)

    def result(self) -> Dict[str, np.ndarray]:
        return self.state.to_host()

    def fill(self, rep: RunReport) -> None:
        rep.iters = self._iters
        rep.max_change = list(self._max_change)
        rep.mrbg_on = False


class _Distributed:
    """shard_map + all_to_all prime loop over RunConfig.mesh (§4.3).

    ``update`` applies the delta to the host structure mirror, re-partitions
    (Eq. 2), and re-converges *warm* from the current co-located state —
    the distributed analogue of iterMR refresh.
    """

    kind = "distributed"

    def __init__(self, spec: IterSpec, cfg: RunConfig):
        if spec.replicate_state:
            raise ValueError(
                "replicate_state (all-to-one) specs broadcast their state; "
                "the co-partitioned distributed engine does not support "
                "them — run without a mesh (auto iterMR mode)")
        self.spec = spec
        self.cfg = cfg
        mesh = cfg.mesh
        self.n_parts = mesh.shape[cfg.mesh_axis] * (
            mesh.shape[cfg.pod_axis] if cfg.pod_axis else 1)
        self.state_parts: Optional[Dict[str, np.ndarray]] = None
        self.mode = "distributed"
        self._iters = 0
        self._max_change: list = []

    def backend(self) -> str:
        from repro.kernels import ops
        return ops.resolve_backend(self.cfg.backend)

    def run(self, struct: KV) -> None:
        self._keys = np.array(struct.keys)
        self._values = {n: np.array(a) for n, a in struct.values.items()}
        self._valid = np.array(struct.valid)
        if self.state_parts is None:      # may be pre-seeded by restore
            from repro.core.distributed import partition_state
            dks = jnp.arange(self.spec.num_state, dtype=jnp.int32)
            init = jax.tree.map(np.asarray, self.spec.init_state(dks))
            self.state_parts = partition_state(init, self.spec.num_state,
                                               self.n_parts)
        self._converge(self.cfg.max_iters, self.cfg.tol)

    def _partition_cap(self) -> int:
        if self.cfg.partition_cap is not None:
            return self.cfg.partition_cap
        dks = np.asarray(jax.jit(self.spec.project)(jnp.asarray(self._keys)))
        pid = (dks.astype(np.uint32) % self.n_parts).astype(np.int32)
        load = np.bincount(pid[self._valid], minlength=self.n_parts)
        return next_bucket(max(int(load.max()), 1), 64)

    def _converge(self, max_iters: int, tol: float) -> None:
        from repro.core.distributed import partition_struct, run_distributed
        parts = partition_struct(self.spec, self._keys, self._values,
                                 self._valid, self.n_parts,
                                 self._partition_cap())
        out, hist = run_distributed(
            self.spec, self.cfg.mesh, parts, self.state_parts,
            axis=self.cfg.mesh_axis, pod_axis=self.cfg.pod_axis,
            shuffle_cap=self.cfg.shuffle_cap, max_iters=max_iters,
            tol=tol, backend=self.cfg.backend)
        self.state_parts = {n: np.asarray(a) for n, a in out.items()}
        self._iters = hist["iters"]
        self._max_change = hist["max_change"]

    def update(self, delta: DeltaKV) -> None:
        apply_delta_host(self._keys, self._values, self._valid, delta)
        self._converge(self.cfg.refresh_iters_, self.cfg.refresh_tol_)

    def result(self) -> Dict[str, np.ndarray]:
        from repro.core.distributed import unpartition_state
        return unpartition_state(self.state_parts, self.spec.num_state)

    def fill(self, rep: RunReport) -> None:
        rep.iters = self._iters
        rep.max_change = list(self._max_change)
        rep.mrbg_on = False
