"""repro.api — the single supported way to drive the i2MapReduce engine.

    from repro.api import Session, RunConfig
    from repro.apps import wordcount as wc

    spec, data = wc.make_job(docs, vocab=60)
    session = Session(spec, RunConfig(backend="xla"))
    session.run(data)                       # full one-step / converge
    session.update(make_delta(rid, vals, sign))   # |Δ|-proportional refresh
    session.result                          # dense host output
    session.report()                        # uniform RunReport
    session.checkpoint("/tmp/ck")           # §6 fault tolerance
    Session.restore(spec, "/tmp/ck")

One ``Session`` drives all four paper modes — one-step, incremental
one-step, plain/iterative, incremental iterative — plus distributed
execution via ``RunConfig(mesh=...)``; the engine picks the refresh path
(fine-grain MRBGraph merge, accumulator fast path, CPC-filtered delta
propagation, or auto MRBG-off fallback recomputation) internally.
"""
from repro.api.config import MeshConfig, RunConfig, StreamConfig
from repro.api.report import MODES, RunReport, ShuffleStats
from repro.api.session import Session

# the declaration vocabulary, re-exported so callers need only repro.api
from repro.core.engine import JobSpec, emit_multi, emit_single
from repro.core.incremental import DeltaKV, make_delta
from repro.core.iterative import IterSpec, State, default_difference
from repro.core.kvstore import (
    KV, Edges, Reducer, make_edges, make_kv, max_reducer, mean_reducer,
    min_reducer, sum_reducer,
)

__all__ = [
    "Session", "RunConfig", "MeshConfig", "StreamConfig", "RunReport",
    "ShuffleStats", "MODES",
    "JobSpec", "IterSpec", "State", "default_difference",
    "DeltaKV", "make_delta",
    "KV", "Edges", "Reducer", "make_kv", "make_edges",
    "sum_reducer", "min_reducer", "max_reducer", "mean_reducer",
    "emit_single", "emit_multi",
]
