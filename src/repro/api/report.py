"""RunReport: the one result/telemetry surface for every engine mode.

Merges what the divergent entry points used to return piecemeal —
``JobResult`` (one-step), ``ResultView`` (incremental one-step), the
``history`` dict of ``run_iterative``, the ``IterationLog`` list of
``IncrIterJob.refresh``, and the MRBG-Store ``IOStats`` — into a single
dataclass every ``Session.run``/``Session.update`` returns.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.incr_iter import IterationLog
from repro.core.mrbg_store import IOStats

# engine paths a report can come from
MODES = (
    "onestep",            # full one-step run (JobSpec)
    "incremental",        # fine-grain one-step refresh (§3.3)
    "accumulator",        # accumulator-Reduce refresh (§3.5)
    "iterative",          # full prime-loop convergence (iterMR, §4)
    "plainMR",            # plain-shuffle cost-model baseline (Algorithm 5)
    "i2",                 # incremental iterative refresh (§5)
    "iterMR-fallback",    # auto MRBG-off recomputation (§5.2)
    "distributed",        # shard_map + all_to_all prime loop (§4.3)
    "distributed-incr",   # per-shard delta refresh, one-step (§3.3 on mesh)
    "distributed-i2",     # per-shard delta refresh, iterative CPC (§5 on mesh)
    "distributed-warm",   # mirror re-partition + warm re-converge fallback
    "query",              # full evaluation of a compiled delta query (dql)
    "query-incremental",  # per-stage preserved-state query refresh (dql)
)


@dataclass
class ShuffleStats:
    """Network-exchange telemetry of one epoch, uniform across modes.

    Single-device paths report zeros (nothing crossed a wire); distributed
    paths fill in the ``all_to_all`` traffic.  ``exchange_seconds`` is the
    wall-clock of each exchange-bearing device program (host-observed, so
    it upper-bounds the pure collective time).
    """

    edges_exchanged: int = 0       # valid edges through all_to_all this epoch
    bytes_moved: int = 0           # edges * per-edge record bytes
    dropped: int = 0               # edges lost to shuffle_cap (0 post-regrow)
    exchange_seconds: List[float] = field(default_factory=list)
    shuffle_cap: int = 0           # per (src,dst) capacity actually used
    regrows: int = 0               # times the cap auto-regrew this epoch


@dataclass
class RunReport:
    """Uniform report for one ``run``/``update`` epoch of a Session."""

    name: str                         # spec name
    mode: str                         # one of MODES
    epoch: int                        # 0 = initial run, then +1 per update
    backend: str                      # resolved shuffle/reduce backend
    iters: int = 1                    # engine iterations this epoch
    seconds: float = 0.0              # wall-clock of this epoch
    max_change: List[float] = field(default_factory=list)
    logs: List[IterationLog] = field(default_factory=list)
    affected_keys: int = -1           # keys re-reduced by a refresh (-1: n/a)
    counts: Optional[np.ndarray] = None   # per-key in-edge counts (one-step)
    io: Optional[IOStats] = None      # MRBG-Store reads for this epoch
    store_bytes: int = 0              # MRBG file size (incl. obsolete chunks)
    live_bytes: int = 0               # live chunk bytes
    store_batches: int = 0
    mrbg_on: bool = True              # False once §5.2 auto-off has tripped
    # network-exchange telemetry: always present, zeros when nothing
    # crossed a wire (single-device paths)
    shuffle: ShuffleStats = field(default_factory=ShuffleStats)
    # coalescer savings for the batch that produced this epoch, attached by
    # the stream layer (None outside streaming): n_in/n_out/n_records/
    # n_inserts/n_deletes/n_cancelled of the CoalesceResult
    coalesce: Optional[Dict[str, int]] = None
    # dense output values; {} when the producer skipped materialization
    # (run/update return reports without it — read session.result instead)
    result: Dict[str, np.ndarray] = field(default_factory=dict)

    def summary(self) -> str:
        parts = [f"{self.name}[{self.mode}] epoch={self.epoch}",
                 f"iters={self.iters}", f"backend={self.backend}",
                 f"{self.seconds * 1e3:.1f}ms"]
        if self.affected_keys >= 0:
            parts.append(f"affected={self.affected_keys}")
        if self.max_change:
            parts.append(f"max_change={self.max_change[-1]:.3g}")
        if self.store_bytes:
            parts.append(f"store={self.store_bytes}B "
                         f"(live {self.live_bytes}B)")
        if self.coalesce and self.coalesce.get("n_cancelled"):
            parts.append(f"coalesced=-{self.coalesce['n_cancelled']}rows")
        if self.shuffle.edges_exchanged or self.shuffle.dropped:
            parts.append(f"shuffle={self.shuffle.edges_exchanged}e/"
                         f"{self.shuffle.bytes_moved}B"
                         + (f" dropped={self.shuffle.dropped}"
                            if self.shuffle.dropped else ""))
        return " ".join(parts)
