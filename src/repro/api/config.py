"""RunConfig: every engine knob, declared once.

The paper presents i2MapReduce as a single system in which a job is declared
once and the runtime decides between fine-grain incremental refresh,
iterative recomputation, and fallback re-computation.  ``RunConfig``
collects what the reproduction historically scattered across five entry
points — backend selection, MRBG-Store policy and window sizes, the CPC
filter threshold, the MRBG auto-off threshold, convergence control, the
device mesh for distributed execution, and checkpointing — into one frozen
dataclass consumed by :class:`repro.api.Session`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

from jax.sharding import Mesh

from repro.core.mrbg_store import (
    DEFAULT_CACHE, DEFAULT_FIX_WINDOW, DEFAULT_GAP_T, POLICIES,
)

ONESTEP_PATHS = ("auto", "mrbg", "accumulator")


@dataclass(frozen=True)
class RunConfig:
    # -- shuffle/reduce backend (repro.kernels.ops): 'xla' | 'pallas' |
    #    'auto' | None (None defers to config/env/auto resolution)
    backend: Optional[str] = None

    # -- one-step path: 'mrbg' preserves the fine-grain MRBGraph (§3.3),
    #    'accumulator' keeps only <K3,V3> (§3.5), 'auto' picks the
    #    accumulator fast path when the reducer is an abelian group
    onestep_path: str = "auto"

    # -- MRBG-Store (§3.4 / §5.2)
    value_bytes: int = 8
    store_policy: str = "multi-dynamic-window"
    gap_threshold: int = DEFAULT_GAP_T
    cache_bytes: int = DEFAULT_CACHE
    fix_window_bytes: int = DEFAULT_FIX_WINDOW

    # -- convergence control (iterative specs)
    max_iters: int = 100
    tol: float = 1e-4
    refresh_max_iters: Optional[int] = None      # None -> max_iters
    refresh_tol: Optional[float] = None          # None -> tol

    # -- incremental iterative (§5.3 / §5.2)
    cpc_threshold: float = 0.0
    pdelta_threshold: float = 0.5

    # -- plainMR cost modeling (Algorithm 5 baseline): re-shuffle the
    #    structure data every iteration instead of keeping the loop warm
    plain_shuffle: bool = False

    # -- distributed execution: a mesh turns the same spec into the
    #    shard_map + all_to_all engine (§4.3); no separate entry point
    mesh: Optional[Mesh] = None
    mesh_axis: str = "data"
    pod_axis: Optional[str] = None
    shuffle_cap: int = 4096
    partition_cap: Optional[int] = None          # None -> sized from data

    # -- checkpointing (§6): directory + cadence in epochs (0 = manual via
    #    Session.checkpoint only)
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0

    # -- telemetry: RunReports kept on Session.history (the raw material
    #    for the streaming layer's online refresh-cost models)
    report_history: int = 64

    # -- latency tail control: deltas entering Session.update() are padded
    #    up to the next power-of-two bucket (>= delta_bucket_min rows) so
    #    the refresh path traces once per bucket, not once per row count;
    #    compilation_cache_dir points JAX's persistent executable cache at
    #    a directory so compiles survive process restarts
    delta_bucket_min: int = 64
    compilation_cache_dir: Optional[str] = None

    def __post_init__(self):
        if self.onestep_path not in ONESTEP_PATHS:
            raise ValueError(
                f"onestep_path must be one of {ONESTEP_PATHS}, "
                f"got {self.onestep_path!r}")
        if self.store_policy not in POLICIES:
            raise ValueError(
                f"store_policy must be one of {POLICIES}, "
                f"got {self.store_policy!r}")
        if self.report_history < 1:
            raise ValueError("report_history must be >= 1 (the trim in "
                             "Session._finish keeps the newest reports)")
        if self.delta_bucket_min < 1:
            raise ValueError("delta_bucket_min must be >= 1")

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)

    @property
    def refresh_iters_(self) -> int:
        return self.max_iters if self.refresh_max_iters is None \
            else self.refresh_max_iters

    @property
    def refresh_tol_(self) -> float:
        return self.tol if self.refresh_tol is None else self.refresh_tol

    def store_kw(self) -> dict:
        """MRBG-Store constructor knobs beyond (num_keys, value_bytes)."""
        return {"gap_threshold": self.gap_threshold,
                "cache_bytes": self.cache_bytes,
                "fix_window_bytes": self.fix_window_bytes}


STREAM_POLICIES = ("latency", "throughput", "paper")


@dataclass(frozen=True)
class StreamConfig:
    """Knobs of the ``repro.stream`` serving layer (one per StreamSession).

    Micro-batching trades refresh latency against per-record overhead; the
    scheduler policy decides, per micro-batch, between the fine-grain
    incremental refresh and full re-computation (the paper's Fig. 8
    crossover, applied online).
    """

    # -- micro-batching: a refresh fires when ``max_batch_records`` delta
    #    rows are buffered or ``max_batch_delay`` seconds elapsed since the
    #    first buffered row, whichever comes first
    max_batch_records: int = 4096
    max_batch_delay: float = 0.05

    # -- ingestion: bounded buffer between producers and the refresh
    #    driver; a full buffer blocks submit() (backpressure)
    queue_capacity: int = 64
    poll_interval: float = 0.002       # idle sleep between source polls

    # -- coalescer: merge/cancel opposing +/- rows per record before the
    #    engine sees them (False streams raw rows through)
    coalesce: bool = True

    # -- refresh scheduling
    policy: str = "paper"              # latency | throughput | paper
    crossover: float = 0.25            # |Δ|/|D| where full recompute wins
    cost_ema: float = 0.5              # EWMA factor of online cost estimates
    store_bloat: float = 4.0           # throughput: rerun when file/live > x

    # -- pre-warm: compile the delta bucket ladder (delta_bucket_min up to
    #    prewarm_rows, default max_batch_records) on start()/admission via
    #    no-op deltas, so the first real micro-batch hits warm executables.
    #    Off by default: each bucket costs one compile of the full refresh
    #    path, which a throughput-oriented tenant may not want to pay
    #    up-front.
    prewarm: bool = False
    prewarm_rows: Optional[int] = None

    def __post_init__(self):
        if self.policy not in STREAM_POLICIES:
            raise ValueError(
                f"policy must be one of {STREAM_POLICIES}, "
                f"got {self.policy!r}")
        if self.queue_capacity < 1 or self.max_batch_records < 1:
            raise ValueError("queue_capacity and max_batch_records must "
                             "be >= 1")

    def replace(self, **kw) -> "StreamConfig":
        return dataclasses.replace(self, **kw)
