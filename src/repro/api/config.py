"""RunConfig: every engine knob, declared once.

The paper presents i2MapReduce as a single system in which a job is declared
once and the runtime decides between fine-grain incremental refresh,
iterative recomputation, and fallback re-computation.  ``RunConfig``
collects what the reproduction historically scattered across five entry
points — backend selection, MRBG-Store policy and window sizes, the CPC
filter threshold, the MRBG auto-off threshold, convergence control, the
device mesh for distributed execution, and checkpointing — into one frozen
dataclass consumed by :class:`repro.api.Session`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

from repro.core.mrbg_store import (
    DEFAULT_CACHE, DEFAULT_FIX_WINDOW, DEFAULT_GAP_T, POLICIES,
)

ONESTEP_PATHS = ("auto", "mrbg", "accumulator")
REFRESH_MODES = ("fine", "warm")


@dataclass(frozen=True)
class MeshConfig:
    """Validated distributed-execution knobs (§4.3), one object per mesh.

    ``RunConfig(mesh=MeshConfig(mesh, ...))`` is the only spelling; the
    historical flat knobs (``mesh_axis``/``pod_axis``/``shuffle_cap``/
    ``partition_cap`` on RunConfig) were deprecated for one release and
    have been removed.
    """

    # the jax.sharding.Mesh; duck-typed (anything exposing .shape works,
    # which keeps unit tests mesh-free)
    mesh: Any

    # partition axis (+ optional pod axis flattened into one exchange axis)
    axis: str = "data"
    pod_axis: Optional[str] = None

    # per (src, dst) shard edge capacity of the converge-loop all_to_all;
    # overflow auto-regrows up the bucket ladder unless auto_grow=False
    shuffle_cap: int = 4096
    auto_grow: bool = True

    # host-side structure-partition row capacity (None -> sized from data)
    partition_cap: Optional[int] = None

    # update() semantics under the mesh:
    #   'fine' -> kv-pair-level delta refresh against per-shard MRBG slices
    #             (delta-only exchange; §3.3/§5 per shard)
    #   'warm' -> re-partition the host mirror and warm re-converge (the
    #             pre-MeshConfig behavior; the Fig. 8 rerun-side baseline)
    refresh: str = "fine"

    # host threads for the fine-grain phase-2 per-shard MRBG merges
    # (disjoint stores, so they parallelize safely): 0 = auto
    # (min(8, cpus, shards)), 1 = sequential, n = exactly n threads
    merge_workers: int = 0

    def __post_init__(self):
        shape = getattr(self.mesh, "shape", None)
        if shape is None:
            raise ValueError("MeshConfig.mesh must be a jax.sharding.Mesh "
                             "(or expose .shape like one)")
        if self.axis not in shape:
            raise ValueError(f"mesh has no axis {self.axis!r} "
                             f"(axes: {tuple(shape)})")
        if self.pod_axis is not None:
            if self.pod_axis not in shape:
                raise ValueError(f"mesh has no pod axis {self.pod_axis!r} "
                                 f"(axes: {tuple(shape)})")
            if self.pod_axis == self.axis:
                raise ValueError("pod_axis must differ from axis")
        if self.shuffle_cap < 1:
            raise ValueError("shuffle_cap must be >= 1")
        if self.partition_cap is not None and self.partition_cap < 1:
            raise ValueError("partition_cap must be >= 1")
        if self.refresh not in REFRESH_MODES:
            raise ValueError(f"refresh must be one of {REFRESH_MODES}, "
                             f"got {self.refresh!r}")
        if self.merge_workers < 0:
            raise ValueError("merge_workers must be >= 0 (0 = auto)")

    @property
    def n_parts(self) -> int:
        shape = self.mesh.shape
        return shape[self.axis] * (shape[self.pod_axis]
                                   if self.pod_axis else 1)

    def replace(self, **kw) -> "MeshConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class RunConfig:
    # -- shuffle/reduce backend (repro.kernels.ops): 'xla' | 'pallas' |
    #    'auto' | None (None defers to config/env/auto resolution)
    backend: Optional[str] = None

    # -- one-step path: 'mrbg' preserves the fine-grain MRBGraph (§3.3),
    #    'accumulator' keeps only <K3,V3> (§3.5), 'auto' picks the
    #    accumulator fast path when the reducer is an abelian group
    onestep_path: str = "auto"

    # -- MRBG-Store (§3.4 / §5.2)
    value_bytes: int = 8
    store_policy: str = "multi-dynamic-window"
    gap_threshold: int = DEFAULT_GAP_T
    cache_bytes: int = DEFAULT_CACHE
    fix_window_bytes: int = DEFAULT_FIX_WINDOW

    # -- convergence control (iterative specs)
    max_iters: int = 100
    tol: float = 1e-4
    refresh_max_iters: Optional[int] = None      # None -> max_iters
    refresh_tol: Optional[float] = None          # None -> tol

    # -- incremental iterative (§5.3 / §5.2)
    cpc_threshold: float = 0.0
    pdelta_threshold: float = 0.5

    # -- plainMR cost modeling (Algorithm 5 baseline): re-shuffle the
    #    structure data every iteration instead of keeping the loop warm
    plain_shuffle: bool = False

    # -- distributed execution: a MeshConfig turns the same spec into the
    #    shard_map + all_to_all engine (§4.3); no separate entry point
    mesh: Optional[MeshConfig] = None

    # -- checkpointing (§6): directory + cadence in epochs (0 = manual via
    #    Session.checkpoint only)
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0

    # -- telemetry: RunReports kept on Session.history (the raw material
    #    for the streaming layer's online refresh-cost models)
    report_history: int = 64

    # -- latency tail control: deltas entering Session.update() are padded
    #    up to the next power-of-two bucket (>= delta_bucket_min rows) so
    #    the refresh path traces once per bucket, not once per row count;
    #    compilation_cache_dir points JAX's persistent executable cache at
    #    a directory so compiles survive process restarts
    delta_bucket_min: int = 64
    compilation_cache_dir: Optional[str] = None

    def __post_init__(self):
        if self.onestep_path not in ONESTEP_PATHS:
            raise ValueError(
                f"onestep_path must be one of {ONESTEP_PATHS}, "
                f"got {self.onestep_path!r}")
        if self.store_policy not in POLICIES:
            raise ValueError(
                f"store_policy must be one of {POLICIES}, "
                f"got {self.store_policy!r}")
        if self.report_history < 1:
            raise ValueError("report_history must be >= 1 (the trim in "
                             "Session._finish keeps the newest reports)")
        if self.delta_bucket_min < 1:
            raise ValueError("delta_bucket_min must be >= 1")
        if self.mesh is not None and not isinstance(self.mesh, MeshConfig):
            raise TypeError(
                "RunConfig(mesh=...) takes a MeshConfig; the pre-PR-7 flat "
                "spelling (bare Mesh + mesh_axis/pod_axis/shuffle_cap/"
                "partition_cap) was removed — pass "
                "RunConfig(mesh=MeshConfig(mesh, axis=..., ...)) "
                "(see the README migration table)")

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)

    @property
    def refresh_iters_(self) -> int:
        return self.max_iters if self.refresh_max_iters is None \
            else self.refresh_max_iters

    @property
    def refresh_tol_(self) -> float:
        return self.tol if self.refresh_tol is None else self.refresh_tol

    def store_kw(self) -> dict:
        """MRBG-Store constructor knobs beyond (num_keys, value_bytes)."""
        return {"gap_threshold": self.gap_threshold,
                "cache_bytes": self.cache_bytes,
                "fix_window_bytes": self.fix_window_bytes}


STREAM_POLICIES = ("latency", "throughput", "paper")


@dataclass(frozen=True)
class StreamConfig:
    """Knobs of the ``repro.stream`` serving layer (one per StreamSession).

    Micro-batching trades refresh latency against per-record overhead; the
    scheduler policy decides, per micro-batch, between the fine-grain
    incremental refresh and full re-computation (the paper's Fig. 8
    crossover, applied online).
    """

    # -- micro-batching: a refresh fires when ``max_batch_records`` delta
    #    rows are buffered or ``max_batch_delay`` seconds elapsed since the
    #    first buffered row, whichever comes first
    max_batch_records: int = 4096
    max_batch_delay: float = 0.05

    # -- ingestion: bounded buffer between producers and the refresh
    #    driver; a full buffer blocks submit() (backpressure)
    queue_capacity: int = 64
    poll_interval: float = 0.002       # idle sleep between source polls

    # -- coalescer: merge/cancel opposing +/- rows per record before the
    #    engine sees them (False streams raw rows through)
    coalesce: bool = True

    # -- input-mirror growth: streams may insert record ids past the seed
    #    data's capacity; the mirror (and every driver-side record
    #    structure) then grows geometrically up the power-of-two ladder.
    #    ``grow_records=False`` restores the historical hard rejection at
    #    the seed capacity; ``max_records`` bounds growth (ids at or past
    #    it are rejected at ingest) so a corrupt id cannot allocate the
    #    whole address space
    grow_records: bool = True
    max_records: Optional[int] = None

    # -- refresh scheduling
    policy: str = "paper"              # latency | throughput | paper
    crossover: float = 0.25            # |Δ|/|D| where full recompute wins
    cost_ema: float = 0.5              # EWMA factor of online cost estimates
    store_bloat: float = 4.0           # throughput: rerun when file/live > x

    # -- pre-warm: compile the delta bucket ladder (delta_bucket_min up to
    #    prewarm_rows, default max_batch_records) on start()/admission via
    #    no-op deltas, so the first real micro-batch hits warm executables.
    #    Off by default: each bucket costs one compile of the full refresh
    #    path, which a throughput-oriented tenant may not want to pay
    #    up-front.
    prewarm: bool = False
    prewarm_rows: Optional[int] = None

    def __post_init__(self):
        if self.policy not in STREAM_POLICIES:
            raise ValueError(
                f"policy must be one of {STREAM_POLICIES}, "
                f"got {self.policy!r}")
        if self.queue_capacity < 1 or self.max_batch_records < 1:
            raise ValueError("queue_capacity and max_batch_records must "
                             "be >= 1")
        if self.max_records is not None and self.max_records < 1:
            raise ValueError("max_records must be >= 1 (or None)")

    def replace(self, **kw) -> "StreamConfig":
        return dataclasses.replace(self, **kw)
