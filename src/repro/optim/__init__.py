from repro.optim.adamw import (  # noqa
    AdamWConfig, adamw_init, adamw_update, cosine_schedule, global_norm,
)
