"""AdamW with gradient clipping and schedules, pytree-native.

Optimizer moments inherit the parameter shardings (and can additionally be
ZeRO-sharded over the data axis via ``repro.launch.dryrun`` sharding
overrides, since they are plain pytrees).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10000
    opt_dtype: Any = jnp.float32


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    t = jnp.clip((step - cfg.warmup) /
                 jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    return cfg.lr * warm * (0.5 * (1.0 + jnp.cos(jnp.pi * t)))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.opt_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(grads, opt_state, params, cfg: AdamWConfig):
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else 1.0
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return (newp.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype))

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    newp = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda t: t[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda t: t[2], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"m": newm, "v": newv, "step": step}, {"grad_norm": gnorm,
                                                        "lr": lr}
