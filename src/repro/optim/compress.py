"""Compressed data-parallel gradient all-reduce with error feedback.

Beyond-paper distributed-optimization trick (requested for 1000+-node
deployments): the DP gradient all-reduce is the largest fixed collective in
training.  We quantize each gradient leaf to int8 with a per-leaf scale
(max-abs / 127), all-reduce the int8 payload (4× fewer bytes on the wire;
int32 accumulation avoids overflow up to ~2^23 replicas), and keep the
quantization residual in an *error-feedback* buffer added back before the
next step — the EF-SGD construction (Karimireddy et al., 2019), which keeps
SGD/Adam convergence unaffected to first order.

``compressed_psum`` is the shard_map building block; ``make_compressed_dp``
wraps a whole gradient pytree.  On the dry-run mesh this turns the fp32
grad all-reduce bytes into 1/4 — visible directly in the §Roofline
collective term (tag ``gradcomp``).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name, err: jax.Array):
    """Error-feedback int8 psum of ``x`` over ``axis_name``.

    Returns (mean-reduced fp32 tensor, new error buffer).  Call inside
    shard_map with ``x`` the local gradient shard and ``err`` the persistent
    residual from the previous step.
    """
    n = jax.lax.psum(1, axis_name)
    xe = x.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale = quantize_int8(xe)
    new_err = xe - dequantize_int8(q, scale)
    # int32 accumulation on the wire; scales are psum'd separately (each
    # replica may have a different scale -> reduce q*scale exactly by
    # reducing q in int32 weighted by its own scale: do scale-normalized
    # trick: send q (int8->int32) and its scale, combine as mean of
    # per-replica dequantized tensors.
    total = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale,
                         axis_name)
    return total / n, new_err.astype(err.dtype)


def init_error_buffers(grads: Any, dtype=jnp.bfloat16):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, dtype), grads)


def compressed_tree_psum(grads: Any, axis_name, err_tree: Any):
    """Apply compressed_psum leaf-wise over a gradient pytree."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_tree)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        rg, re = compressed_psum(g, axis_name, e)
        out_g.append(rg.astype(g.dtype))
        out_e.append(re)
    return jax.tree.unflatten(treedef, out_g), \
        jax.tree.unflatten(treedef, out_e)


def wire_bytes(grads: Any) -> Tuple[int, int]:
    """(uncompressed fp32 bytes, int8 bytes) per all-reduce round."""
    flat = jax.tree.leaves(grads)
    n = sum(int(g.size) for g in flat)
    return 4 * n, 1 * n + 4 * len(flat)
