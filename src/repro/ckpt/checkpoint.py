"""Checkpointing: atomic, manifest-driven, elastic-reshard on restore.

Layout (one directory per step):

  <root>/step_000010.tmp/   -> written, fsynced, then atomically renamed to
  <root>/step_000010/
      manifest.json         tree structure + shapes + dtypes + user metadata
      arrays.npz            flattened leaves keyed by path

Restore accepts an optional pytree of ShapeDtypeStructs *with shardings*;
leaves are ``jax.device_put`` onto the new sharding, so a checkpoint taken
on one mesh restores onto another (elastic re-scale) — the arrays are global
views, independent of the mesh they were saved under.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree):
    # jax.tree.flatten_with_path only exists on newer jax; go via tree_util
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    dtypes = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        a = np.asarray(leaf)
        dtypes[key] = str(a.dtype)
        if a.dtype.name == "bfloat16":   # npz has no bf16: store raw bits
            a = a.view(np.uint16)
        out[key] = a
    return out, dtypes


def save_pytree(root: str, step: int, tree, metadata: Optional[Dict] = None,
                keep: int = 3) -> Path:
    root_p = Path(root)
    root_p.mkdir(parents=True, exist_ok=True)
    final = root_p / f"step_{step:08d}"
    tmp = root_p / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat, dtypes = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    manifest = {
        "step": step,
        "keys": list(flat.keys()),
        "dtypes": dtypes,
        "metadata": metadata or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    # fsync before the atomic publish
    fd = os.open(tmp / "manifest.json", os.O_RDONLY)
    os.fsync(fd)
    os.close(fd)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(root_p, keep)
    return final


def _gc(root: Path, keep: int):
    steps = sorted(p for p in root.glob("step_????????") if p.is_dir())
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(root: str) -> Optional[int]:
    root_p = Path(root)
    if not root_p.exists():
        return None
    steps = sorted(root_p.glob("step_????????"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore_pytree(root: str, step: int, like=None):
    """Restore; ``like`` = pytree of ShapeDtypeStructs (elastic reshard)."""
    d = Path(root) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = np.load(d / "arrays.npz")

    def _load(k):
        a = arrays[k]
        if manifest.get("dtypes", {}).get(k) == "bfloat16":
            import ml_dtypes
            a = a.view(ml_dtypes.bfloat16)
        return a

    flat = [_load(k) for k in manifest["keys"]]
    if like is not None:
        like_flat, like_td = jax.tree.flatten(like)
        assert len(like_flat) == len(flat), \
            f"leaf count mismatch {len(like_flat)} != {len(flat)}"
        out = []
        for arr, tgt in zip(flat, like_flat):
            a = np.asarray(arr)
            if hasattr(tgt, "dtype") and a.dtype != tgt.dtype:
                a = a.astype(tgt.dtype)
            sh = getattr(tgt, "sharding", None)
            out.append(jax.device_put(a, sh) if sh is not None
                       else jax.device_put(a))
        return jax.tree.unflatten(like_td, out), manifest["metadata"]
    # fall back: reconstruct flat dict
    return ({k: _load(k) for k in manifest["keys"]},
            manifest["metadata"])


class CheckpointManager:
    """Keep-k rolling checkpoints with resume support."""

    def __init__(self, root: str, keep: int = 3, every: int = 50):
        self.root = root
        self.keep = keep
        self.every = every

    def maybe_save(self, step: int, tree, metadata=None) -> bool:
        if step % self.every != 0:
            return False
        save_pytree(self.root, step, tree, metadata, self.keep)
        return True

    def resume(self, like=None):
        s = latest_step(self.root)
        if s is None:
            return None, None, None
        tree, meta = restore_pytree(self.root, s, like)
        return s, tree, meta
