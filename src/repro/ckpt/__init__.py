from repro.ckpt.checkpoint import (  # noqa
    latest_step, restore_pytree, save_pytree, CheckpointManager,
)
