"""Kmeans on the iterative engine (paper Algorithm 3, all-to-one).

Structure: SK = point id, SV = feature vector [dim].
State:     DK = centroid id, DV = {"c": centroid [dim]} — but every Map
instance needs *all* centroids, so ``replicate_state=True`` (the paper's
all-to-one case / "smaller number of state kv-pairs": state is broadcast to
every partition rather than co-partitioned).

Map assigns each point to the nearest centroid and emits
<cid, (pval, 1)>; Reduce averages via (sum, count) partial accumulators —
the paper's own trick to make ``average`` accumulator-compatible (§3.5).

Any input change moves centroids, which changes every assignment: P_Δ = 100%,
so the engine's auto-off logic (Section 5.2) always runs Kmeans in iterMR
mode — exactly the paper's Fig. 8 behavior where i²MapReduce "falls back to
iterMR recomp" for Kmeans.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import emit_single
from repro.core.iterative import IterSpec
from repro.core.kvstore import KV, make_kv, sum_reducer


def make_struct(points: np.ndarray, valid_rows=None) -> KV:
    s = points.shape[0]
    if valid_rows is None:
        valid_rows = np.ones(s, bool)
    return make_kv(np.arange(s, dtype=np.int32),
                   {"p": jnp.asarray(points, jnp.float32)}, valid_rows)


def map_fn(struct: KV, dv, sign):
    pts = struct.values["p"]                 # [N, dim]
    cents = dv["c"]                          # [K, dim] (replicated state)
    d2 = ((pts[:, None, :] - cents[None, :, :]) ** 2).sum(-1)   # [N, K]
    cid = jnp.argmin(d2, axis=1).astype(jnp.int32)
    ones = jnp.ones(pts.shape[0], jnp.float32)
    return emit_single(cid, {"sum": pts, "cnt": ones}, struct.keys,
                       struct.valid, record_sign=sign)


def _finalize(keys, acc, counts):
    cnt = jnp.maximum(acc["cnt"], 1e-9)
    return {"c": acc["sum"] / cnt[:, None], "cnt_out": acc["cnt"]}


def make_spec(k: int, dim: int, init_centroids: np.ndarray) -> IterSpec:
    init = jnp.asarray(init_centroids, jnp.float32)

    def init_state(dks):
        return {"c": init, "cnt_out": jnp.zeros(k, jnp.float32)}

    def finalize(keys, acc, counts):
        cnt = jnp.maximum(acc["cnt"], 1e-9)
        return {"c": acc["sum"] / cnt[:, None], "cnt_out": acc["cnt"]}

    return IterSpec(
        map_fn=map_fn,
        reducer=sum_reducer(finalize),
        project=lambda sk: jnp.zeros_like(sk),
        num_state=k,
        init_state=init_state,
        difference=lambda c, p: jnp.abs(c["c"] - p["c"]).max(axis=1),
        replicate_state=True,
        stable_topology=False,
        name="kmeans",
    )


def make_job(points: np.ndarray, init_centroids: np.ndarray,
             valid_rows=None):
    """Uniform app entry: ``(spec, data)`` ready for ``repro.api.Session``."""
    k, dim = init_centroids.shape
    return make_spec(k, dim, init_centroids), make_struct(points, valid_rows)


def oracle(points: np.ndarray, init_centroids: np.ndarray,
           iters: int = 100, tol: float = 1e-6, valid_rows=None):
    pts = points.astype(np.float64)
    if valid_rows is not None:
        pts = pts[valid_rows]
    c = init_centroids.astype(np.float64).copy()
    for _ in range(iters):
        d2 = ((pts[:, None, :] - c[None, :, :]) ** 2).sum(-1)
        a = d2.argmin(1)
        newc = c.copy()
        for j in range(c.shape[0]):
            sel = pts[a == j]
            if sel.shape[0]:
                newc[j] = sel.mean(0)
        if np.abs(newc - c).max() < tol:
            c = newc
            break
        c = newc
    return c
