"""APriori frequent-pair counting (paper §8.1.3), one-step + accumulator.

After a preprocessing pass picks the candidate list of frequent word pairs,
the MapReduce job counts each pair's occurrences over the tweet corpus:
Map checks every candidate pair against a tweet's word set and emits
<pair_id, 1>; Reduce sums.  This is the paper's showcase for the
accumulator-Reduce optimization (12× on the 7.9% weekly delta) — no
MRBGraph is preserved at all.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import JobSpec, emit_multi
from repro.core.kvstore import KV, make_kv, sum_reducer


def make_input(tweet_ids: np.ndarray, tweets: np.ndarray, valid=None) -> KV:
    """tweets: [N, L] word ids, -1 padding."""
    if valid is None:
        valid = np.ones(len(tweet_ids), bool)
    return make_kv(np.asarray(tweet_ids, np.int32),
                   {"w": jnp.asarray(tweets, jnp.int32)}, valid)


def make_spec(pairs: np.ndarray) -> JobSpec:
    """pairs: [P, 2] candidate word-id pairs."""
    pa = jnp.asarray(pairs[:, 0], jnp.int32)
    pb = jnp.asarray(pairs[:, 1], jnp.int32)
    p = pairs.shape[0]

    def map_fn(kv: KV, sign):
        words = kv.values["w"]                              # [N, L]
        has_a = (words[:, None, :] == pa[None, :, None]).any(-1)   # [N, P]
        has_b = (words[:, None, :] == pb[None, :, None]).any(-1)
        present = has_a & has_b & kv.valid[:, None]
        k2 = jnp.broadcast_to(jnp.arange(p, dtype=jnp.int32)[None, :],
                              present.shape)
        ones = jnp.ones(present.shape, jnp.float32)
        return emit_multi(k2, {"c": ones}, kv.keys, present,
                          record_sign=sign)

    return JobSpec(map_fn, sum_reducer(), p, "apriori")


def make_job(tweets: np.ndarray, pairs: np.ndarray, tweet_ids=None,
             valid=None):
    """Uniform app entry: ``(spec, data)`` ready for ``repro.api.Session``."""
    if tweet_ids is None:
        tweet_ids = np.arange(len(tweets), dtype=np.int32)
    return make_spec(pairs), make_input(tweet_ids, tweets, valid)


def candidate_pairs(tweets: np.ndarray, vocab: int, top: int = 64,
                    seed: int = 0) -> np.ndarray:
    """Preprocessing job: pick candidate pairs from frequent words."""
    counts = np.bincount(tweets[tweets >= 0].reshape(-1), minlength=vocab)
    frequent = np.argsort(-counts)[:max(4, int(np.sqrt(2 * top)) + 2)]
    pairs = []
    for i in range(len(frequent)):
        for j in range(i + 1, len(frequent)):
            pairs.append((frequent[i], frequent[j]))
            if len(pairs) >= top:
                return np.asarray(pairs, np.int32)
    return np.asarray(pairs, np.int32)


def oracle(tweets: np.ndarray, pairs: np.ndarray, valid=None) -> np.ndarray:
    out = np.zeros(pairs.shape[0])
    for i, t in enumerate(tweets):
        if valid is not None and not valid[i]:
            continue
        ws = set(int(w) for w in t if w >= 0)
        for pi, (a, b) in enumerate(pairs):
            if a in ws and b in ws:
                out[pi] += 1
    return out
