# Apps are imported lazily (import repro.apps.<name>) to keep import costs low.
