# Apps are imported lazily (import repro.apps.<name>) to keep import costs low.
#
# Every app module follows the same convention:
#   make_job(...) -> (spec, data)   # JobSpec or IterSpec + the input KV,
#                                   # ready for repro.api.Session(spec).run(data)
#   make_spec / make_input / make_struct    # the underlying pieces
#   oracle(...)                             # dense numpy reference semantics
