"""GIM-V (Generalized Iterated Matrix-Vector multiplication) — paper
Algorithm 4, many-to-one dependency.

Structure: SK = matrix block id (i * nb + j), SV = dense sub-block m[bs,bs].
State:     DK = vector block id j, DV = {"v": [bs]}.
project((i,j)) = j — *many* matrix blocks depend on *one* vector block.

combine2   = block matmul  m_ij @ v_j        (the Map)
combineAll = sum over j                      (the Reduce)
assign     = damped update alpha * Mv + (1-alpha) * b   (finalize)

With alpha < 1/||M|| this is a contraction (Richardson/Jacobi-style
iteration), so it converges to v* = (I - alpha M)^-1 (1-alpha) b, giving a
deterministic oracle.  The concrete application mirrors the paper's
iterative matrix-vector multiplication on WikiTalk.

Our single-job iteration (no extra structure/state join job) is precisely
the iterMR advantage the paper shows in Fig. 8 for GIM-V.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import emit_single
from repro.core.iterative import IterSpec
from repro.core.kvstore import KV, make_kv, sum_reducer

ALPHA = 0.8


def make_struct(blocks: np.ndarray, nb: int, valid_rows=None) -> KV:
    """blocks: [nb*nb, bs, bs]; record id = i * nb + j (row-major)."""
    s = blocks.shape[0]
    assert s == nb * nb
    if valid_rows is None:
        valid_rows = np.ones(s, bool)
    return make_kv(np.arange(s, dtype=np.int32),
                   {"m": jnp.asarray(blocks, jnp.float32)}, valid_rows)


def make_spec(nb: int, bs: int, b_vec: np.ndarray) -> IterSpec:
    """b_vec: [nb, bs] the constant term (e.g. teleport vector)."""
    b = jnp.asarray(b_vec, jnp.float32)

    def map_fn(struct: KV, dv, sign):
        m = struct.values["m"]               # [N, bs, bs]
        vj = dv["v"]                         # [N, bs] gathered by project
        mv = jnp.einsum("nab,nb->na", m, vj)  # combine2
        i_block = struct.keys // nb
        return emit_single(i_block.astype(jnp.int32), {"v": mv},
                           struct.keys, struct.valid, record_sign=sign)

    def finalize(keys, acc, counts):          # combineAll + assign
        safe = jnp.clip(keys, 0, nb - 1)
        return {"v": ALPHA * acc["v"] + (1.0 - ALPHA) * b[safe]}

    return IterSpec(
        map_fn=map_fn,
        reducer=sum_reducer(finalize),
        project=lambda sk: (sk % nb).astype(jnp.int32),
        num_state=nb,
        init_state=lambda dks: {"v": jnp.zeros((nb, bs), jnp.float32)},
        difference=lambda c, p: jnp.abs(c["v"] - p["v"]).max(axis=1),
        stable_topology=True,
        name="gimv",
    )


def make_job(blocks: np.ndarray, nb: int, bs: int, b_vec: np.ndarray,
             valid_rows=None):
    """Uniform app entry: ``(spec, data)`` ready for ``repro.api.Session``."""
    return make_spec(nb, bs, b_vec), make_struct(blocks, nb, valid_rows)


def oracle(blocks: np.ndarray, nb: int, bs: int, b_vec: np.ndarray,
           iters: int = 300, tol: float = 1e-10,
           valid_rows=None) -> np.ndarray:
    """Dense fixpoint of v = alpha * M v + (1 - alpha) * b."""
    m = np.zeros((nb * bs, nb * bs))
    for r in range(nb * nb):
        if valid_rows is not None and not valid_rows[r]:
            continue
        i, j = divmod(r, nb)
        m[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs] = blocks[r]
    b = b_vec.reshape(-1).astype(np.float64)
    v = np.zeros(nb * bs)
    for _ in range(iters):
        nv = ALPHA * (m @ v) + (1 - ALPHA) * b
        done = np.abs(nv - v).max() < tol
        v = nv
        if done:
            break
    return v.reshape(nb, bs)


def random_blocks(nb: int, bs: int, seed: int = 0, density: float = 0.6):
    """Random sub-stochastic blocked matrix (spectral radius < 1)."""
    rng = np.random.default_rng(seed)
    m = rng.random((nb * bs, nb * bs)) * (rng.random((nb * bs, nb * bs))
                                          < density)
    m = m / np.maximum(m.sum(axis=0, keepdims=True), 1.0)   # column-normalize
    blocks = np.zeros((nb * nb, bs, bs), np.float32)
    for r in range(nb * nb):
        i, j = divmod(r, nb)
        blocks[r] = m[i * bs:(i + 1) * bs, j * bs:(j + 1) * bs]
    return blocks
