"""Single-Source Shortest Path on the iterative engine (one-to-one).

Structure: SK = vertex id, SV = padded out-neighbors + weights.
State:     DK = vertex id, DV = {"d": dist}.
Map emits <j, d_i + w_ij>; Reduce is **min**; a virtual root record emits
<src, 0> so the source anchors the fixpoint.

Unlike the classic MapReduce SSSP that re-emits each vertex's own distance
(monotone non-increasing, wrong under edge deletions), contributions come
only from in-edges, so the MRBGraph merge handles deletions/weight increases
correctly — min is exactly the non-invertible reducer for which the paper's
fine-grain preserved state is *required* (no accumulator shortcut).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import emit_multi
from repro.core.iterative import IterSpec
from repro.core.kvstore import KV, make_kv, min_reducer

INF = np.float32(3.4e38) / 4


def make_struct(nbrs: np.ndarray, w: np.ndarray, src: int,
                valid_rows=None) -> KV:
    """Row i: out-edges of vertex i-1; row 0 is the virtual root -> src.

    nbrs/w: [S, F]; the caller provides vertex rows; we prepend the root.
    """
    s = nbrs.shape[0]
    f = nbrs.shape[1]
    root_n = np.full((1, f), -1, np.int32)
    root_n[0, 0] = src
    root_w = np.zeros((1, f), np.float32)
    root_w[0, 0] = -INF   # so that d_root + w = 0 given d_root = INF sentinel
    nbrs2 = np.concatenate([root_n, nbrs]).astype(np.int32)
    w2 = np.concatenate([root_w, w.astype(np.float32)])
    if valid_rows is None:
        valid_rows = np.ones(s, bool)
    valid2 = np.concatenate([[True], valid_rows])
    return make_kv(np.arange(s + 1, dtype=np.int32),
                   {"nbrs": jnp.asarray(nbrs2), "w": jnp.asarray(w2)},
                   valid2)


def map_fn(struct: KV, dv, sign):
    nbrs = struct.values["nbrs"]             # [N, F]
    w = struct.values["w"]
    dist = dv["d"]                           # [N]
    is_root = (struct.keys == 0)
    # root emits exactly 0; vertices emit min(d_i, INF) + w.  Unreachable
    # sources contribute ~INF (never the min), keeping the emission topology
    # *state-independent* so stable_topology incremental replay is exact.
    contrib = jnp.where(is_root[:, None], 0.0,
                        jnp.minimum(dist[:, None], INF) + w)
    nvalid = (nbrs >= 0) & struct.valid[:, None]
    return emit_multi(nbrs, {"d": contrib.astype(jnp.float32)}, struct.keys,
                      nvalid, record_sign=sign)


def make_spec(num_vertices: int) -> IterSpec:
    return IterSpec(
        map_fn=map_fn,
        reducer=min_reducer(),
        # structure record r corresponds to vertex r-1 (root -> src handled
        # in map); its state key is r-1 (root projects to a scratch key 0 --
        # the root's map never reads state)
        project=lambda sk: jnp.maximum(sk - 1, 0),
        num_state=num_vertices,
        init_state=lambda dks: {"d": jnp.full(dks.shape[0], INF, jnp.float32)},
        difference=lambda c, p: jnp.where(
            (c["d"] > INF / 2) & (p["d"] > INF / 2), 0.0,
            jnp.abs(jnp.minimum(c["d"], INF) - jnp.minimum(p["d"], INF))),
        stable_topology=True,
        name="sssp",
    )


def make_job(nbrs: np.ndarray, w: np.ndarray, src: int, valid_rows=None):
    """Uniform app entry: ``(spec, data)`` ready for ``repro.api.Session``."""
    return make_spec(nbrs.shape[0]), make_struct(nbrs, w, src, valid_rows)


def oracle(nbrs: np.ndarray, w: np.ndarray, src: int,
           valid_rows=None) -> np.ndarray:
    """Bellman-Ford reference."""
    s = nbrs.shape[0]
    if valid_rows is None:
        valid_rows = np.ones(s, bool)
    d = np.full(s, np.float64(INF))
    d[src] = 0.0
    for _ in range(s):
        changed = False
        for i in range(s):
            if not valid_rows[i] or d[i] >= INF / 2:
                continue
            for jj, jv in enumerate(nbrs[i]):
                if jv < 0:
                    continue
                nd = d[i] + w[i, jj]
                if nd < d[jv] - 1e-12:
                    d[jv] = nd
                    changed = True
        if not changed:
            break
    return d


def random_weighted_graph(num_vertices: int, max_out: int, seed: int = 0,
                          p_edge: float = 0.5):
    rng = np.random.default_rng(seed)
    nbrs = rng.integers(0, num_vertices, size=(num_vertices, max_out))
    mask = rng.random((num_vertices, max_out)) < p_edge
    nbrs = np.where(mask, nbrs, -1).astype(np.int32)
    w = np.abs(rng.normal(1.0, 0.3, size=(num_vertices, max_out))
               ).astype(np.float32)
    return nbrs, w
