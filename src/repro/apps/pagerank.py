"""PageRank on the iterative engine (paper Algorithm 2, one-to-one).

Structure <SK, SV>: SK = vertex id, SV = padded out-neighbor array.
State     <DK, DV>: DK = vertex id, DV = rank score {"r": [K]}.
project = identity; Map emits <j, R_i/|N_i|> per out-edge; Reduce sums with
the damping finalize R_j = d * sum + (1 - d).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import emit_multi
from repro.core.iterative import IterSpec
from repro.core.kvstore import KV, make_kv, sum_reducer

DAMPING = 0.85


def make_struct(nbrs: np.ndarray, valid_rows=None) -> KV:
    """nbrs: [S, F] int32 out-neighbor ids, -1 = padding."""
    s = nbrs.shape[0]
    if valid_rows is None:
        valid_rows = np.ones(s, bool)
    return make_kv(np.arange(s, dtype=np.int32),
                   {"nbrs": jnp.asarray(nbrs, jnp.int32)}, valid_rows)


def map_fn(struct: KV, dv, sign):
    nbrs = struct.values["nbrs"]                     # [N, F]
    rank = dv["r"]                                   # [N]
    nvalid = (nbrs >= 0) & struct.valid[:, None]
    deg = jnp.maximum(nvalid.sum(axis=1), 1)
    contrib = jnp.broadcast_to((rank / deg.astype(rank.dtype))[:, None],
                               nbrs.shape)
    return emit_multi(nbrs, {"r": contrib}, struct.keys, nvalid,
                      record_sign=sign)


def make_spec(num_vertices: int) -> IterSpec:
    return IterSpec(
        map_fn=map_fn,
        reducer=sum_reducer(lambda k, a, c:
                            {"r": DAMPING * a["r"] + (1.0 - DAMPING)}),
        project=lambda sk: sk,
        num_state=num_vertices,
        init_state=lambda dks: {"r": jnp.ones(dks.shape[0], jnp.float32)},
        difference=lambda c, p: jnp.abs(c["r"] - p["r"]),
        stable_topology=True,
        name="pagerank",
    )


def make_job(nbrs: np.ndarray, valid_rows=None):
    """Uniform app entry: ``(spec, data)`` ready for ``repro.api.Session``."""
    return make_spec(nbrs.shape[0]), make_struct(nbrs, valid_rows)


def graph_mutator(num_vertices: int, p_edge: float = 0.5):
    """Evolving-graph mutator: rewire the selected vertices' out-edges."""
    def mut(rng, rows, old):
        shape = old["nbrs"].shape
        return {"nbrs": np.where(rng.random(shape) < p_edge,
                                 rng.integers(0, num_vertices, shape),
                                 -1).astype(np.int32)}
    return mut


def make_stream(nbrs: np.ndarray, frac: float = 0.02, seed: int = 7,
                epochs: int = 3, p_edge: float = 0.5):
    """Streaming app entry: ``(spec, struct, source)`` ready for
    ``repro.stream.StreamSession`` — one synthetic delta epoch rewires
    ``frac`` of the vertices; ``source.values["nbrs"]`` tracks the
    fully-updated graph for oracle checks."""
    from repro.stream.source import SyntheticSource
    spec, struct = make_job(nbrs)
    source = SyntheticSource({"nbrs": np.asarray(nbrs, np.int32)},
                             frac=frac, seed=seed, epochs=epochs,
                             mutator=graph_mutator(nbrs.shape[0], p_edge))
    return spec, struct, source


def oracle(nbrs: np.ndarray, valid_rows=None, iters: int = 200,
           tol: float = 1e-12) -> np.ndarray:
    """Dense numpy power iteration with identical semantics."""
    s = nbrs.shape[0]
    if valid_rows is None:
        valid_rows = np.ones(s, bool)
    r = np.ones(s, np.float64)
    for _ in range(iters):
        acc = np.zeros(s, np.float64)
        for i in range(s):
            if not valid_rows[i]:
                continue
            out = nbrs[i][nbrs[i] >= 0]
            if out.size == 0:
                continue
            np.add.at(acc, out, r[i] / out.size)
        new = DAMPING * acc + (1 - DAMPING)
        done = np.abs(new - r).max() < tol
        r = new
        if done:
            break
    return r


def random_graph(num_vertices: int, max_out: int, seed: int = 0,
                 p_edge: float = 0.5) -> np.ndarray:
    rng = np.random.default_rng(seed)
    nbrs = rng.integers(0, num_vertices, size=(num_vertices, max_out))
    mask = rng.random((num_vertices, max_out)) < p_edge
    return np.where(mask, nbrs, -1).astype(np.int32)
