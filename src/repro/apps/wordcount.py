"""WordCount — the canonical accumulator-Reduce example (paper §3.5).

Records are documents: fixed-width arrays of word ids (−1 padding).
Map emits <word, 1>; Reduce is integer sum — a distributive ⊕, so both the
MRBGraph engine and the accumulator fast path apply (tests assert they
agree with each other and with recomputation).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.engine import JobSpec, emit_multi
from repro.core.kvstore import KV, make_kv, sum_reducer


def make_input(doc_ids: np.ndarray, docs: np.ndarray, valid=None) -> KV:
    if valid is None:
        valid = np.ones(len(doc_ids), bool)
    return make_kv(np.asarray(doc_ids, np.int32),
                   {"w": jnp.asarray(docs, jnp.int32)}, valid)


def map_fn(kv: KV, sign):
    words = kv.values["w"]                    # [N, L]
    n, l = words.shape
    v2 = {"c": jnp.ones((n, l), jnp.float32)}
    valid = (words >= 0) & kv.valid[:, None]
    return emit_multi(words, v2, kv.keys, valid, record_sign=sign)


def make_spec(vocab: int) -> JobSpec:
    return JobSpec(map_fn, sum_reducer(), vocab, "wordcount")


def make_job(docs: np.ndarray, vocab: int, doc_ids=None, valid=None):
    """Uniform app entry: ``(spec, data)`` ready for ``repro.api.Session``."""
    if doc_ids is None:
        doc_ids = np.arange(len(docs), dtype=np.int32)
    return make_spec(vocab), make_input(doc_ids, docs, valid)


def doc_mutator(vocab: int):
    """Evolving-corpus mutator: rewrite the selected documents."""
    def mut(rng, rows, old):
        return {"w": rng.integers(0, vocab,
                                  old["w"].shape).astype(np.int32)}
    return mut


def make_stream(docs: np.ndarray, vocab: int, frac: float = 0.05,
                seed: int = 0, epochs: int = 5):
    """Streaming app entry: ``(spec, data, source)`` ready for
    ``repro.stream.StreamSession`` — one synthetic delta epoch rewrites
    ``frac`` of the corpus; ``source.values["w"]`` tracks the
    fully-updated corpus for oracle checks."""
    from repro.stream.source import SyntheticSource
    spec, data = make_job(docs, vocab)
    source = SyntheticSource({"w": np.asarray(docs, np.int32)}, frac=frac,
                             seed=seed, epochs=epochs,
                             mutator=doc_mutator(vocab))
    return spec, data, source


def oracle(docs: np.ndarray, vocab: int, valid=None) -> np.ndarray:
    counts = np.zeros(vocab)
    for i, d in enumerate(docs):
        if valid is not None and not valid[i]:
            continue
        for w in d:
            if w >= 0:
                counts[w] += 1
    return counts
