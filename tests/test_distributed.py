"""Distributed shuffle engine: shard_map all_to_all == single-device.

Needs >1 XLA host device, so each test runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the flag must be set
before jax initializes, which has already happened in the pytest process).
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


COMMON = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.apps import pagerank as pr
from repro.core.distributed import (partition_struct, partition_state,
                                    unpartition_state, run_distributed)
from repro.core.iterative import run_iterative

S, F = 256, 5
nbrs = pr.random_graph(S, F, seed=11, p_edge=0.5)
spec = pr.make_spec(S)
state, _ = run_iterative(spec, pr.make_struct(nbrs), max_iters=60, tol=1e-7)
ref = np.asarray(state.values["r"])
skeys, svals, svalid = partition_struct(
    spec, np.arange(S, dtype=np.int32), {"nbrs": nbrs},
    np.ones(S, bool), 8, 64)
state0 = partition_state({"r": np.ones(S, np.float32)}, S, 8)
"""


def test_single_axis_shuffle():
    _run(COMMON + """
mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
out, hist = run_distributed(spec, mesh, (skeys, svals, svalid), state0,
                            axis="data", shuffle_cap=512, max_iters=60,
                            tol=1e-7)
got = unpartition_state({k: np.asarray(v) for k, v in out.items()}, S)["r"]
assert np.abs(got - ref).max() < 1e-5, np.abs(got - ref).max()
print("OK")
""")


def test_multipod_flattened_shuffle():
    _run(COMMON + """
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("pod", "data"))
out, hist = run_distributed(spec, mesh, (skeys, svals, svalid), state0,
                            axis="data", pod_axis="pod", shuffle_cap=512,
                            max_iters=60, tol=1e-7)
got = unpartition_state({k: np.asarray(v) for k, v in out.items()}, S)["r"]
assert np.abs(got - ref).max() < 1e-5, np.abs(got - ref).max()
print("OK")
""")


def test_overflow_detection():
    """With auto_grow off, an undersized shuffle_cap must fail loudly."""
    _run(COMMON + """
mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
try:
    run_distributed(spec, mesh, (skeys, svals, svalid), state0,
                    axis="data", shuffle_cap=2, max_iters=2, tol=1e-7,
                    auto_grow=False)
    raise SystemExit("expected overflow error")
except RuntimeError as e:
    assert "overflow" in str(e)
print("OK")
""")


def test_overflow_auto_regrow():
    """Default auto_grow walks the cap up the bucket ladder instead of
    failing, and still matches the single-device fixed point."""
    _run(COMMON + """
mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
out, hist = run_distributed(spec, mesh, (skeys, svals, svalid), state0,
                            axis="data", shuffle_cap=2, max_iters=60,
                            tol=1e-7)
assert hist["regrows"] >= 1, hist["regrows"]
assert hist["shuffle_cap"] > 2
got = unpartition_state({k: np.asarray(v) for k, v in out.items()}, S)["r"]
assert np.abs(got - ref).max() < 1e-5, np.abs(got - ref).max()
print("OK")
""")


def test_small_mesh_lowering_lm():
    """2-3 archs lower+compile on an 8-device (2,4) mesh — the mini
    version of the production dry-run, actually runnable in CI."""
    _run("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
import repro.configs as C
from repro.launch.steps import input_specs
from repro.models.config import smoke_config, ShapeCell
import dataclasses

for arch in ["qwen3-1.7b", "gemma2-9b", "llama4-scout-17b-a16e"]:
    cfg = smoke_config(C.get(arch))
    cfg = cfg.replace(sharding=dataclasses.replace(
        cfg.sharding, batch=("data",)))
    mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
    cell = ShapeCell("mini", 64, 8, "train")
    with mesh:
        step, args = input_specs(cfg, cell, mesh)
        compiled = jax.jit(step).lower(*args).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0]
        assert ca.get("flops", 0) > 0
    print(arch, "ok")
print("OK")
""")
