"""Checkpoint substrate: atomic save/restore, keep-k GC, elastic reshard."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager, latest_step, restore_pytree, \
    save_pytree


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.float32),
            "b": [jnp.asarray(rng.integers(0, 5, 3), jnp.int32),
                  {"c": jnp.asarray(rng.normal(0, 1, 7), jnp.bfloat16)}]}


def test_roundtrip(tmp_path):
    t = _tree()
    save_pytree(tmp_path, 3, t, {"loss": 1.5})
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    got, meta = restore_pytree(tmp_path, 3, like)
    assert meta["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_keep_k_gc(tmp_path):
    for s in range(6):
        save_pytree(tmp_path, s, _tree(s), keep=2)
    import pathlib
    steps = sorted(pathlib.Path(tmp_path).glob("step_*"))
    assert len(steps) == 2
    assert latest_step(tmp_path) == 5


def test_manager_resume(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=2)
    t = _tree()
    assert not mgr.maybe_save(1, t)
    assert mgr.maybe_save(2, t)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t)
    s, tree, meta = mgr.resume(like)
    assert s == 2


def test_elastic_reshard_restore(tmp_path):
    """Restore under a different dtype/sharding target (elastic)."""
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_pytree(tmp_path, 1, t)
    like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    got, _ = restore_pytree(tmp_path, 1, like)
    assert got["w"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got["w"], np.float32),
                               np.arange(16).reshape(4, 4))
