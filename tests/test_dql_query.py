"""repro.dql end-to-end: wordcount-as-query bitwise parity with
``apps/wordcount.py``, oracle checks for the query workload family,
the ``update(delta) == full re-run`` property over random plans,
checkpoint/restore, the streaming adapter, and the zero-steady-retrace
witness (PR-6 bucketed ladder through the query driver)."""
import numpy as np
import pytest

from tests._hyp import given, settings, st
from repro import dql
from repro.api import RunConfig, Session
from repro.apps import wordcount as wc
from repro.core.engine import JobSpec
from repro.core.incremental import apply_delta_host, make_delta
from repro.core.kvstore import make_kv
from repro.dql import workloads as wl
from repro.kernels import jitcache, ops

BACKENDS = ("xla", "pallas")
VOCAB = 16


def _cfg(backend, **kw):
    return RunConfig(backend=backend, value_bytes=4, **kw)


def _doc_delta(rng, docs, k):
    """'-old'/'+new' rewrite of ``k`` random documents, mutating ``docs``."""
    rows = rng.choice(len(docs), size=k, replace=False).astype(np.int32)
    new = rng.integers(0, VOCAB, (k, docs.shape[1])).astype(np.int32)
    dk = np.repeat(rows, 2)
    sg = np.tile(np.array([-1, 1], np.int8), k)
    buf = np.empty((2 * k, docs.shape[1]), np.int32)
    buf[0::2] = docs[rows]
    buf[1::2] = new
    docs[rows] = new
    return make_delta(dk, {"w": buf}, sg)


# ---------------------------------------------------------------------------
# wordcount as a query: bit-for-bit parity with apps/wordcount.py
# ---------------------------------------------------------------------------

def test_wordcount_lowers_to_jobspec():
    plan = wl.wordcount_query(VOCAB)
    spec = plan.spec()
    assert isinstance(spec, JobSpec)
    assert spec.num_keys == VOCAB and spec.name == "wordcount"
    q = plan.compile(_cfg("xla"))
    assert q.sources == ("docs",)


@pytest.mark.parametrize("backend", BACKENDS)
def test_wordcount_bitwise_parity(backend):
    rng = np.random.default_rng(7)
    n, words, epochs = (24, 4, 3) if backend == "xla" else (12, 3, 2)
    docs = rng.integers(0, VOCAB, (n, words)).astype(np.int32)

    spec, data = wc.make_job(docs, VOCAB)
    app = Session(spec, _cfg(backend))
    rep_app = app.run(data)

    q = wl.wordcount_query(VOCAB).compile(_cfg(backend))
    rep_q = q.run(data)

    # same engine path (accumulator/MRBG pick), same kernels, same bits
    assert rep_q.mode == rep_app.mode
    np.testing.assert_array_equal(q.result["c"], app.result["c"])

    mirror = docs.copy()
    for _ in range(epochs):
        d = _doc_delta(rng, mirror, 3)
        app.update(d)
        q.update(d)
        np.testing.assert_array_equal(q.result["c"], app.result["c"])
    np.testing.assert_array_equal(
        q.result["c"].ravel(), wc.oracle(mirror, VOCAB))


# ---------------------------------------------------------------------------
# the workload family vs oracles
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_join_matches_oracle_and_fresh_run(backend):
    users = 32 if backend == "xla" else 16
    datas = wl.join_data(users, seed=3)
    q = wl.join_query(users).compile(_cfg(backend))
    q.run(datas)

    vals, valid = q.relation()
    ovals, ovalid = wl.join_oracle(datas)
    np.testing.assert_array_equal(valid, ovalid)
    for c in ("amt", "n"):
        np.testing.assert_array_equal(np.where(valid, vals[c], 0), ovals[c])

    # incremental refresh == compiling fresh on the mutated inputs
    d = wl.join_delta(datas, 0.125, seed=5)
    rep = q.update(d)
    assert rep.mode == "query-incremental" and rep.affected_keys >= 0

    mutated = {}
    for name, kv in datas.items():
        k = np.array(kv.keys)
        v = {c: np.array(a) for c, a in kv.values.items()}
        ok = np.array(kv.valid)
        apply_delta_host(k, v, ok, d[name])
        mutated[name] = make_kv(k, v, ok)
    twin = wl.join_query(users).compile(_cfg(backend))
    twin.run(mutated)
    tvals, tvalid = twin.relation()
    vals, valid = q.relation()
    np.testing.assert_array_equal(valid, tvalid)
    for c in ("amt", "n"):
        np.testing.assert_array_equal(np.where(valid, vals[c], 0),
                                      np.where(tvalid, tvals[c], 0))

    # rerun() (the Fig. 8 alternative) agrees too
    q.rerun()
    rvals, rvalid = q.relation()
    np.testing.assert_array_equal(rvalid, tvalid)
    for c in ("amt", "n"):
        np.testing.assert_array_equal(np.where(rvalid, rvals[c], 0),
                                      np.where(tvalid, tvals[c], 0))


@pytest.mark.parametrize("backend", BACKENDS)
def test_windowed_matches_oracle(backend):
    keys, size, slide, wins = (8, 8, 4, 8) if backend == "xla" \
        else (4, 8, 4, 4)
    n = 64 if backend == "xla" else 24
    t_max = wins * slide
    kv = wl.events_data(n, keys, t_max=t_max, seed=2)
    q = wl.windowed_query(keys, size=size, slide=slide,
                          num_windows=wins).compile(_cfg(backend))
    assert isinstance(q.qspec, JobSpec)      # window is key-space expansion
    q.run(kv)
    oracle = wl.windowed_oracle(kv, keys, size=size, slide=slide,
                                num_windows=wins)
    np.testing.assert_allclose(q.result["v"].ravel(), oracle, atol=1e-4)

    d = wl.events_delta(kv, 0.1, t_max=t_max, seed=4)
    q.update(d)
    k = np.array(kv.keys)
    v = {c: np.array(a) for c, a in kv.values.items()}
    ok = np.array(kv.valid)
    apply_delta_host(k, v, ok, d)
    oracle = wl.windowed_oracle(make_kv(k, v, ok), keys, size=size,
                                slide=slide, num_windows=wins)
    np.testing.assert_allclose(q.result["v"].ravel(), oracle, atol=1e-4)


def test_cooccurrence_counts():
    rng = np.random.default_rng(11)
    vocab, n, words = 8, 20, 5
    docs = rng.integers(0, vocab, (n, words)).astype(np.int32)
    docs[rng.random((n, words)) < 0.1] = -1        # padded slots
    kv = make_kv(np.arange(n, dtype=np.int32), {"w": docs})

    q = wl.cooccurrence_query(vocab).compile(_cfg("xla"))
    q.run(kv)
    np.testing.assert_array_equal(q.result["n"].ravel(),
                                  wl.cooccurrence_oracle(kv, vocab))

    mirror = docs.copy()
    rows = np.array([0, 3, 7], np.int32)
    new = rng.integers(0, vocab, (3, words)).astype(np.int32)
    dk = np.repeat(rows, 2)
    sg = np.tile(np.array([-1, 1], np.int8), 3)
    buf = np.empty((6, words), np.int32)
    buf[0::2] = mirror[rows]
    buf[1::2] = new
    mirror[rows] = new
    q.update(make_delta(dk, {"w": buf}, sg))
    np.testing.assert_array_equal(
        q.result["n"].ravel(),
        wl.cooccurrence_oracle(
            make_kv(np.arange(n, dtype=np.int32), {"w": mirror}), vocab))


# ---------------------------------------------------------------------------
# multi-stage change propagation: group_by -> filter -> group_by
# ---------------------------------------------------------------------------

def _chained_plan(k1, k2):
    return (dql.scan("x")
            .group_by("k", num_keys=k1, value="v", agg="sum", name="per_key")
            .filter(lambda v: v["v"] > 5)
            .map(lambda v: {"b": (v["v"] / 8).astype("int32").clip(0, k2 - 1),
                            "v": v["v"]})
            .group_by("b", num_keys=k2, value="v", agg="sum", name="bucket"))


def _chained_oracle(k, v, valid, k1, k2):
    s1 = np.zeros(k1)
    for ki, vi, ok in zip(k, v, valid):
        if ok:
            s1[ki] += vi
    out = np.zeros(k2)
    for ki in range(k1):
        if s1[ki] > 5:
            out[min(int(s1[ki] // 8), k2 - 1)] += s1[ki]
    return out


@pytest.mark.parametrize("backend", BACKENDS)
def test_chained_group_by(backend):
    rng = np.random.default_rng(5)
    n, k1, k2 = (48, 16, 4) if backend == "xla" else (24, 8, 4)
    k = rng.integers(0, k1, n).astype(np.int32)
    v = rng.integers(0, 10, n).astype(np.float32)
    valid = np.ones(n, bool)
    q = _chained_plan(k1, k2).compile(_cfg(backend))
    q.run(make_kv(np.arange(n, dtype=np.int32), {"k": k, "v": v}, valid))
    np.testing.assert_allclose(q.result["v"].ravel(),
                               _chained_oracle(k, v, valid, k1, k2))

    rows = rng.choice(n, size=4, replace=False).astype(np.int32)
    newv = rng.integers(0, 10, 4).astype(np.float32)
    newk = rng.integers(0, k1, 4).astype(np.int32)
    dk = np.repeat(rows, 2)
    sg = np.tile(np.array([-1, 1], np.int8), 4)
    kb = np.empty(8, np.int32)
    kb[0::2], kb[1::2] = k[rows], newk
    vb = np.empty(8, np.float32)
    vb[0::2], vb[1::2] = v[rows], newv
    k[rows], v[rows] = newk, newv
    rep = q.update(make_delta(dk, {"k": kb, "v": vb}, sg))
    assert rep.mode == "query-incremental"
    np.testing.assert_allclose(q.result["v"].ravel(),
                               _chained_oracle(k, v, valid, k1, k2))


# ---------------------------------------------------------------------------
# property: update(delta) == compiling fresh on the mutated input,
# over random map/filter/group_by/join plans (integer payloads: exact)
# ---------------------------------------------------------------------------

_OPS = (
    lambda q: q.map(lambda v: {**v, "v": v["v"] * 2}),
    lambda q: q.map(lambda v: {**v, "v": v["v"] + 1}),
    lambda q: q.filter(lambda v: (v["r"] % 3) > 0),
)


def _rand_plan(seed, n_ops, with_join, agg, num_keys):
    q = dql.scan("x")
    for i in range(n_ops):
        q = _OPS[(seed + i) % len(_OPS)](q)
    g = q.group_by("k", num_keys=num_keys, value="v", agg=agg, name="a")
    if not with_join:
        return g
    h = q.group_by("k", num_keys=num_keys, value={"u": "v"}, agg="sum",
                   name="b")
    return g.join(h, name="j")


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10**6), st.integers(0, 3), st.booleans(),
       st.sampled_from(("sum", "min", "max")))
def test_update_equals_full_run(seed, n_ops, with_join, agg):
    rng = np.random.default_rng(seed)
    n, num_keys = 24, 8
    k = rng.integers(0, num_keys, n).astype(np.int32)
    v = rng.integers(0, 10, n).astype(np.float32)
    r = rng.integers(0, 6, n).astype(np.int32)
    valid = np.ones(n, bool)

    plan = _rand_plan(seed, n_ops, with_join, agg, num_keys)
    q = plan.compile(_cfg("xla"))
    q.run(make_kv(np.arange(n, dtype=np.int32),
                  {"k": k.copy(), "v": v.copy(), "r": r.copy()},
                  valid.copy()))

    m = int(rng.integers(1, 6))
    rows = rng.choice(n, size=m, replace=False).astype(np.int32)
    cols = {}
    for name, arr, new in (
            ("k", k, rng.integers(0, num_keys, m).astype(np.int32)),
            ("v", v, rng.integers(0, 10, m).astype(np.float32)),
            ("r", r, rng.integers(0, 6, m).astype(np.int32))):
        buf = np.empty(2 * m, arr.dtype)
        buf[0::2], buf[1::2] = arr[rows], new
        cols[name] = buf
        arr[rows] = new
    d = make_delta(np.repeat(rows, 2), cols,
                   np.tile(np.array([-1, 1], np.int8), m))
    q.update(d)

    twin = plan.compile(_cfg("xla"))
    twin.run(make_kv(np.arange(n, dtype=np.int32),
                     {"k": k, "v": v, "r": r}, valid))

    vals, ok = q.relation()
    tvals, tok = twin.relation()
    np.testing.assert_array_equal(ok, tok)
    assert set(vals) == set(tvals)
    for c in vals:
        np.testing.assert_array_equal(np.where(ok, vals[c], 0),
                                      np.where(tok, tvals[c], 0))


# ---------------------------------------------------------------------------
# zero steady retraces: bucketed deltas through the query driver
# ---------------------------------------------------------------------------

def test_zero_steady_retraces():
    users = 64
    datas = wl.join_data(users, seed=9)
    q = wl.join_query(users).compile(_cfg("xla"))
    q.run(datas)
    q.update(wl.join_delta(datas, 0.05, seed=100))   # prewarm the ladder
    gen0 = jitcache.generation()
    for s in range(4):
        q.update(wl.join_delta(datas, 0.05, seed=101 + s))
    assert jitcache.generation() == gen0


# ---------------------------------------------------------------------------
# storeless evaluate() + the kernels lowering shim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_evaluate_matches_compiled(backend):
    users = 16
    datas = wl.join_data(users, seed=1)
    vals, valid = dql.evaluate(wl.join_query(users), datas, backend=backend)
    ovals, ovalid = wl.join_oracle(datas)
    np.testing.assert_array_equal(np.asarray(valid), ovalid)
    for c in ("amt", "n"):
        np.testing.assert_array_equal(
            np.where(ovalid, np.asarray(vals[c]), 0), ovals[c])


@pytest.mark.parametrize("backend", BACKENDS)
def test_group_reduce_masks_out_of_range(backend):
    from repro.core.kvstore import sum_reducer
    keys = np.array([0, 1, -1, 5, 2, 1], np.int32)
    vals = {"v": np.array([1., 2., 3., 4., 5., 6.], np.float32)}
    valid = np.array([1, 1, 1, 1, 0, 1], bool)
    acc, counts = ops.group_reduce(sum_reducer(), keys, vals, valid, 4,
                                   backend=backend)
    # -1 masked, 5 out of range, index 4 invalid
    np.testing.assert_allclose(np.asarray(acc["v"]), [1., 8., 0., 0.])
    np.testing.assert_array_equal(np.asarray(counts), [1, 2, 0, 0])


# ---------------------------------------------------------------------------
# checkpoint/restore + streaming adapter
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_query_kind(tmp_path):
    users = 32
    datas = wl.join_data(users, seed=6)
    plan = wl.join_query(users)
    q = plan.compile(_cfg("xla"))
    q.run(datas)
    q.update(wl.join_delta(datas, 0.1, seed=20))
    root = tmp_path / "ck"
    ep = q.checkpoint(str(root))
    assert ep.name == "ep_000001"            # committed epoch dir

    r = dql.Query.restore(plan, str(root), _cfg("xla"))
    vals, valid = q.relation()
    rvals, rvalid = r.relation()
    np.testing.assert_array_equal(valid, rvalid)
    for c in vals:
        np.testing.assert_array_equal(np.where(valid, vals[c], 0),
                                      np.where(rvalid, rvals[c], 0))

    d2 = wl.join_delta(datas, 0.1, seed=21)
    q.update(d2)
    r.update(d2)
    vals, valid = q.relation()
    rvals, rvalid = r.relation()
    np.testing.assert_array_equal(valid, rvalid)
    for c in vals:
        np.testing.assert_array_equal(np.where(valid, vals[c], 0),
                                      np.where(rvalid, rvals[c], 0))

    with pytest.raises(RuntimeError):
        r.rerun()            # restored queries have no input mirrors


def test_stream_adapter_over_query(tmp_path):
    from repro.stream import DeltaRecord, QueueSource
    rng = np.random.default_rng(13)
    docs = rng.integers(0, VOCAB, (24, 4)).astype(np.int32)
    mirror = docs.copy()
    src = QueueSource(capacity=4)
    for e in range(3):
        d = _doc_delta(rng, mirror, 3)
        src.push(DeltaRecord(record_ids=np.asarray(d.record_ids),
                             values={"w": np.asarray(d.values["w"])},
                             sign=np.asarray(d.sign), epoch=e))
    src.seal()

    q = wl.wordcount_query(VOCAB).compile(_cfg("xla"))
    kv = wc.make_input(np.arange(len(docs)), docs)
    ss = q.stream(kv, source=src)
    ss.start(background=False)
    ss.drain(timeout=60)
    np.testing.assert_array_equal(
        np.asarray(ss.session.result["c"]).ravel(),
        wc.oracle(mirror, VOCAB))
    ss.stop()


# ---------------------------------------------------------------------------
# planner error surface
# ---------------------------------------------------------------------------

def test_lowering_rejects_stateless_only_plan():
    with pytest.raises(ValueError, match="at least one group_by or join"):
        dql.scan("x").map(lambda v: v).compile(_cfg("xla"))


def test_lowering_rejects_trailing_window():
    plan = (dql.scan("x")
            .group_by("k", num_keys=4, value="v", name="g")
            .window(4, num_windows=2))
    with pytest.raises(ValueError, match="trailing window"):
        plan.compile(_cfg("xla"))


def test_join_requires_key_space():
    with pytest.raises(ValueError, match="num_keys"):
        dql.scan("a").join(dql.scan("b"))


def test_group_by_validates_agg():
    with pytest.raises(ValueError, match="agg"):
        dql.scan("x").group_by("k", num_keys=4, value="v", agg="median")


def test_join_column_collision_raises():
    users = 8
    uid = np.arange(users, dtype=np.int32)
    kv = {name: make_kv(uid, {"v": np.ones(users, np.float32)})
          for name in ("a", "b")}
    plan = dql.scan("a").join(dql.scan("b"), num_keys=users, name="bad")
    q = plan.compile(_cfg("xla"))
    with pytest.raises(ValueError, match="collide"):
        q.run(kv)
