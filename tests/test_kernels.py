"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode on CPU; identical code lowers natively on TPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.ref import sort_lex_ref
from repro.kernels.segment_reduce import (
    segment_minmax_mxu, segment_minmax_ref, segment_reduce_mxu,
    segment_reduce_ref, segment_sum_counts_mxu, segment_sum_mxu,
)
from repro.kernels.flash_attention import flash_attention, mha_ref
from repro.kernels.sort_u32 import sort_kv32, sort_kv32_ref, sort_lex_pallas
from repro.kernels.spmv_ell import spmv_ell, spmv_ell_ref


class TestSegmentReduce:
    @pytest.mark.parametrize("n,d,k", [(256, 8, 64), (1000, 16, 300),
                                       (64, 128, 17), (512, 1, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, n, d, k, dtype):
        rng = np.random.default_rng(n + d + k)
        seg = jnp.asarray(rng.integers(0, k + 3, n), jnp.int32)
        vals = jnp.asarray(rng.normal(0, 1, (n, d)), dtype)
        got = segment_reduce_mxu(seg, vals, k, rows=128, kblk=128)
        want = segment_reduce_ref(seg, vals, k)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kh,s,hd", [
        (1, 2, 2, 128, 32), (2, 4, 2, 256, 32), (1, 8, 1, 128, 64)])
    @pytest.mark.parametrize("opts", [
        dict(causal=True), dict(causal=False),
        dict(causal=True, window=64), dict(causal=True, softcap=50.0)])
    def test_sweep(self, b, h, kh, s, hd, opts):
        rng = np.random.default_rng(b * 100 + h)
        q = jnp.asarray(rng.normal(0, 1, (b, h, s, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), jnp.float32)
        got = flash_attention(q, k, v, q_blk=64, kv_blk=64, **opts)
        want = mha_ref(q, k, v, **opts)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(7)
        q = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.bfloat16)
        got = flash_attention(q, k, v, q_blk=64, kv_blk=64)
        want = mha_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=5e-2)


class TestSort:
    @pytest.mark.parametrize("n", [16, 100, 700, 1024, 4096])
    def test_sweep(self, n):
        rng = np.random.default_rng(n)
        keys = jnp.asarray(rng.integers(0, max(10, n), n), jnp.uint32)
        payload = jnp.arange(n, dtype=jnp.int32)
        gk, gp = sort_kv32(keys, payload)
        wk, _ = sort_kv32_ref(keys, payload)
        np.testing.assert_array_equal(np.asarray(gk), np.asarray(wk))
        # payload is a permutation consistent with the sorted keys
        np.testing.assert_array_equal(
            np.asarray(keys)[np.asarray(gp)], np.asarray(gk))
        assert sorted(np.asarray(gp).tolist()) == list(range(n))


class TestSortMultiTile:
    """The cross-tile bitonic merge: sizes straddling every tile boundary.

    ``tile=64`` keeps the multi-tile machinery cheap in interpret mode
    while exercising the same code path the default SORT_TILE takes for
    inputs past one VMEM tile.
    """

    TILE = 64

    @pytest.mark.parametrize("n", [0, 1, 63, 64, 65, 127, 128, 129,
                                   200, 256, 515, 1024])
    def test_boundary_sweep(self, n):
        rng = np.random.default_rng(n + 17)
        hi = jnp.asarray(rng.integers(0, max(n // 2, 2), n), jnp.int32)
        lo = jnp.asarray(rng.integers(0, 7, n), jnp.int32)
        gh, gl, gp = sort_lex_pallas(hi, lo, tile=self.TILE)
        wh, wl, wp = sort_lex_ref(hi, lo)
        np.testing.assert_array_equal(np.asarray(gh), np.asarray(wh))
        np.testing.assert_array_equal(np.asarray(gl), np.asarray(wl))
        # stability: with the unique index lane the permutation is unique,
        # so it must match the stable oracle exactly
        np.testing.assert_array_equal(np.asarray(gp), np.asarray(wp))

    def test_all_equal_keys_stability(self):
        n = 5 * self.TILE              # non-pow2 count of tiles
        hi = jnp.zeros(n, jnp.int32)
        lo = jnp.zeros(n, jnp.int32)
        _, _, perm = sort_lex_pallas(hi, lo, tile=self.TILE)
        np.testing.assert_array_equal(np.asarray(perm), np.arange(n))

    def test_vmem_bounded_padding(self):
        # a few tiles + 1 row must pad to the next tile multiple of the
        # network, not to the next power of two of a single giant tile
        n = 4 * self.TILE + 1
        rng = np.random.default_rng(0)
        hi = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
        lo = jnp.zeros(n, jnp.int32)
        gh, _, gp = sort_lex_pallas(hi, lo, tile=self.TILE)
        assert gh.shape == (n,)
        assert sorted(np.asarray(gp).tolist()) == list(range(n))

    def test_matches_default_tile(self):
        n = 300
        rng = np.random.default_rng(3)
        hi = jnp.asarray(rng.integers(0, 40, n), jnp.int32)
        lo = jnp.asarray(rng.integers(0, 5, n), jnp.int32)
        small = sort_lex_pallas(hi, lo, tile=self.TILE)
        big = sort_lex_pallas(hi, lo)          # single-tile path
        for a, b in zip(small, big):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSegmentReduceEdgeCases:
    """n=0 / num_segments=0 must return empty results, not crash."""

    def test_empty_rows(self):
        seg = jnp.zeros(0, jnp.int32)
        vals = jnp.zeros((0, 4), jnp.float32)
        out = segment_sum_mxu(seg, vals, 8)
        np.testing.assert_array_equal(np.asarray(out), np.zeros((8, 4)))
        acc, cnt = segment_sum_counts_mxu(seg, vals, 8)
        np.testing.assert_array_equal(np.asarray(cnt), np.zeros(8, np.int32))
        mn = segment_minmax_mxu("min", seg, vals, 8)
        assert np.all(np.asarray(mn) == np.inf)

    def test_zero_segments(self):
        rng = np.random.default_rng(1)
        seg = jnp.asarray(rng.integers(0, 4, 32), jnp.int32)
        vals = jnp.asarray(rng.normal(0, 1, (32, 3)), jnp.float32)
        assert segment_sum_mxu(seg, vals, 0).shape == (0, 3)
        acc, cnt = segment_sum_counts_mxu(seg, vals, 0)
        assert acc.shape == (0, 3) and cnt.shape == (0,)
        assert segment_minmax_mxu("max", seg, vals, 0).shape == (0, 3)

    def test_empty_both_backends_via_dispatcher(self):
        from repro.kernels import ops
        vals = {"v": jnp.zeros((0, 2), jnp.float32)}
        for bk in ("xla", "pallas"):
            acc, cnt = ops.segment_reduce("sum", jnp.zeros(0, jnp.int32),
                                          vals, jnp.zeros(0, bool), 4,
                                          backend=bk)
            np.testing.assert_array_equal(np.asarray(acc["v"]),
                                          np.zeros((4, 2)))
            np.testing.assert_array_equal(np.asarray(cnt),
                                          np.zeros(4, np.int32))


class TestSegmentMinMaxSublane:
    """The scatter-free sublane min/max against the jnp oracle."""

    @pytest.mark.parametrize("n,d,k", [(7, 3, 5), (256, 8, 64),
                                       (1000, 16, 300), (513, 4, 129)])
    @pytest.mark.parametrize("kind", ["min", "max"])
    def test_sweep(self, n, d, k, kind):
        rng = np.random.default_rng(n * 31 + d)
        seg = jnp.asarray(rng.integers(0, k + 2, n), jnp.int32)
        vals = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
        got = segment_minmax_mxu(kind, seg, vals, k, rows=64, kblk=64)
        want = segment_minmax_ref(kind, seg, vals, k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("kind", ["min", "max"])
    def test_int32(self, kind):
        rng = np.random.default_rng(5)
        seg = jnp.asarray(rng.integers(0, 9, 100), jnp.int32)
        vals = jnp.asarray(rng.integers(-50, 50, (100, 2)), jnp.int32)
        got = segment_minmax_mxu(kind, seg, vals, 9)
        want = segment_minmax_ref(kind, seg, vals, 9)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_counts_ride_sum_launch(self):
        rng = np.random.default_rng(2)
        n, d, k = 300, 4, 32
        seg = jnp.asarray(rng.integers(0, k, n), jnp.int32)
        vals = jnp.asarray(rng.normal(0, 1, (n, d)), jnp.float32)
        acc, cnt = segment_sum_counts_mxu(seg, vals, k)
        np.testing.assert_array_equal(np.asarray(acc),
                                      np.asarray(segment_reduce_ref(seg, vals, k)))
        np.testing.assert_array_equal(
            np.asarray(cnt), np.bincount(np.asarray(seg), minlength=k)[:k])


class TestFusedShuffleReduce:
    """kernels.fused vs the composed path: bitwise on integer-valued data.

    The composed xla path is the reference; the fused kernel must agree on
    every output (sorted lanes, permutation, live mask, accumulators,
    counts) at sizes straddling the fused tile boundary.
    """

    @staticmethod
    def _case(n, nkeys, d, seed):
        rng = np.random.default_rng(seed)
        k2 = rng.integers(0, nkeys, n).astype(np.int32)
        mk = rng.integers(0, 40, n).astype(np.int32)
        # integer-valued floats: sums are exact, parity is bitwise
        vals = rng.integers(-20, 20, (n, d)).astype(np.float32)
        valid = rng.random(n) < 0.9
        sign = np.where(rng.random(n) < 0.75, 1, -1).astype(np.int8)
        aff = np.unique(k2[valid])
        cap = 1 << max(int(np.ceil(np.log2(max(aff.size, 1)))), 3)
        keys = np.full(cap, 2**31 - 1, np.int32)
        keys[:aff.size] = aff
        return tuple(jnp.asarray(a) for a in (k2, mk, vals, valid, sign,
                                              keys))

    class _Sum:
        kind = "sum"

    @pytest.mark.parametrize("n", [5, 100, 513, 1000])
    def test_fused_vs_xla_bitwise(self, n):
        from repro.kernels import ops
        args = self._case(n, max(n // 4, 2), 3, n)
        ref = ops.shuffle_reduce(self._Sum(), *args, backend="xla")
        got = ops.shuffle_reduce(self._Sum(), *args, backend="pallas")
        for name in ("k2", "mk", "live", "perm", "counts"):
            np.testing.assert_array_equal(
                np.asarray(getattr(got, name)),
                np.asarray(getattr(ref, name)), err_msg=name)
        np.testing.assert_array_equal(np.asarray(got.acc),
                                      np.asarray(ref.acc))
        np.testing.assert_array_equal(np.asarray(got.values),
                                      np.asarray(ref.values))

    @pytest.mark.parametrize("n", [255, 256, 257, 515, 1024])
    def test_multitile_fused(self, n):
        """Small fused tile: the multi-tile sort + fused LWW/reduce pass."""
        from repro.kernels import ops
        from repro.kernels.fused import fused_shuffle_reduce
        k2, mk, vals, valid, sign, keys = self._case(n, max(n // 3, 2), 2,
                                                     n + 99)
        k2m = jnp.where(valid, k2, jnp.int32(2**31 - 1))
        out = fused_shuffle_reduce(k2m, mk, vals, valid, sign, keys,
                                   out_dtype=jnp.float32, tile=128, kblk=64)
        ref = ops.shuffle_reduce(self._Sum(), k2, mk, vals, valid, sign,
                                 keys, backend="xla")
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(ref.k2))
        np.testing.assert_array_equal(np.asarray(out[3]),
                                      np.asarray(ref.live))
        np.testing.assert_array_equal(np.asarray(out[4]),
                                      np.asarray(ref.perm))
        np.testing.assert_array_equal(np.asarray(out[5]), np.asarray(ref.acc))
        np.testing.assert_array_equal(np.asarray(out[6]),
                                      np.asarray(ref.counts))

    def test_stability_witness(self):
        """Duplicate (k2, mk) rows: the *last* writer must win through the
        multi-tile fused path (the engine's tombstone semantics)."""
        from repro.kernels.fused import fused_shuffle_reduce
        n, reps = 384, 3
        k2 = jnp.asarray(np.repeat(np.arange(n // reps, dtype=np.int32),
                                   reps))
        mk = jnp.zeros(n, jnp.int32)
        vals = jnp.asarray(np.arange(n, dtype=np.float32)[:, None])
        valid = jnp.ones(n, bool)
        sign = jnp.ones(n, np.int8)
        keys = jnp.asarray(np.arange(128, dtype=np.int32))
        out = fused_shuffle_reduce(k2, mk, vals, valid, sign, keys,
                                   out_dtype=jnp.float32, tile=128, kblk=128)
        live = np.asarray(out[3])
        v_s = np.asarray(out[2])[:, 0]
        # exactly one live row per key, and it is the last-arriving copy
        assert live.sum() == n // reps
        np.testing.assert_array_equal(
            v_s[live], np.arange(reps - 1, n, reps, dtype=np.float32))

    def test_tombstone_delete(self):
        from repro.kernels import ops
        k2 = jnp.asarray([3, 3, 5], jnp.int32)
        mk = jnp.asarray([0, 0, 0], jnp.int32)
        vals = jnp.asarray([[1.0], [2.0], [7.0]])
        valid = jnp.ones(3, bool)
        sign = jnp.asarray([1, -1, 1], jnp.int8)   # 3 deleted by tombstone
        keys = jnp.asarray([3, 5] + [2**31 - 1] * 6, jnp.int32)
        for bk in ("xla", "pallas"):
            sr = ops.shuffle_reduce(self._Sum(), k2, mk, vals, valid, sign,
                                    keys, backend=bk)
            counts = np.asarray(sr.counts)
            assert counts[0] == 0 and counts[1] == 1
            assert np.asarray(sr.acc)[1, 0] == 7.0


class TestSpmv:
    @pytest.mark.parametrize("s,f,v", [(100, 4, 50), (500, 6, 700),
                                       (256, 8, 1024)])
    def test_sweep(self, s, f, v):
        rng = np.random.default_rng(s)
        nbrs = rng.integers(0, v, (s, f))
        nbrs[rng.random((s, f)) < 0.3] = -1
        contrib = rng.normal(0, 1, (s, f)).astype(np.float32)
        got = spmv_ell(jnp.asarray(nbrs, jnp.int32), jnp.asarray(contrib),
                       v, rows=64, kblk=256)
        want = spmv_ell_ref(jnp.asarray(nbrs), jnp.asarray(contrib), v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
