"""Pallas kernel sweeps: shapes x dtypes vs the pure-jnp oracles
(interpret mode on CPU; identical code lowers natively on TPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.segment_reduce import segment_reduce_mxu, segment_reduce_ref
from repro.kernels.flash_attention import flash_attention, mha_ref
from repro.kernels.sort_u32 import sort_kv32, sort_kv32_ref
from repro.kernels.spmv_ell import spmv_ell, spmv_ell_ref


class TestSegmentReduce:
    @pytest.mark.parametrize("n,d,k", [(256, 8, 64), (1000, 16, 300),
                                       (64, 128, 17), (512, 1, 512)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, n, d, k, dtype):
        rng = np.random.default_rng(n + d + k)
        seg = jnp.asarray(rng.integers(0, k + 3, n), jnp.int32)
        vals = jnp.asarray(rng.normal(0, 1, (n, d)), dtype)
        got = segment_reduce_mxu(seg, vals, k, rows=128, kblk=128)
        want = segment_reduce_ref(seg, vals, k)
        tol = 1e-4 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=tol, atol=tol)


class TestFlashAttention:
    @pytest.mark.parametrize("b,h,kh,s,hd", [
        (1, 2, 2, 128, 32), (2, 4, 2, 256, 32), (1, 8, 1, 128, 64)])
    @pytest.mark.parametrize("opts", [
        dict(causal=True), dict(causal=False),
        dict(causal=True, window=64), dict(causal=True, softcap=50.0)])
    def test_sweep(self, b, h, kh, s, hd, opts):
        rng = np.random.default_rng(b * 100 + h)
        q = jnp.asarray(rng.normal(0, 1, (b, h, s, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (b, kh, s, hd)), jnp.float32)
        got = flash_attention(q, k, v, q_blk=64, kv_blk=64, **opts)
        want = mha_ref(q, k, v, **opts)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(7)
        q = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(0, 1, (1, 2, 128, 32)), jnp.bfloat16)
        got = flash_attention(q, k, v, q_blk=64, kv_blk=64)
        want = mha_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=5e-2)


class TestSort:
    @pytest.mark.parametrize("n", [16, 100, 700, 1024, 4096])
    def test_sweep(self, n):
        rng = np.random.default_rng(n)
        keys = jnp.asarray(rng.integers(0, max(10, n), n), jnp.uint32)
        payload = jnp.arange(n, dtype=jnp.int32)
        gk, gp = sort_kv32(keys, payload)
        wk, _ = sort_kv32_ref(keys, payload)
        np.testing.assert_array_equal(np.asarray(gk), np.asarray(wk))
        # payload is a permutation consistent with the sorted keys
        np.testing.assert_array_equal(
            np.asarray(keys)[np.asarray(gp)], np.asarray(gk))
        assert sorted(np.asarray(gp).tolist()) == list(range(n))


class TestSpmv:
    @pytest.mark.parametrize("s,f,v", [(100, 4, 50), (500, 6, 700),
                                       (256, 8, 1024)])
    def test_sweep(self, s, f, v):
        rng = np.random.default_rng(s)
        nbrs = rng.integers(0, v, (s, f))
        nbrs[rng.random((s, f)) < 0.3] = -1
        contrib = rng.normal(0, 1, (s, f)).astype(np.float32)
        got = spmv_ell(jnp.asarray(nbrs, jnp.int32), jnp.asarray(contrib),
                       v, rows=64, kblk=256)
        want = spmv_ell_ref(jnp.asarray(nbrs), jnp.asarray(contrib), v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
