"""Coalescer correctness: coalesce(deltas) + one update() must be
equivalent to applying the same deltas one row at a time, on both
shuffle/reduce backends (the hot path rides repro.kernels.ops)."""
import numpy as np
import pytest

from tests._hyp import given, settings, st
from repro.api import RunConfig, Session
from repro.apps import wordcount as wc
from repro.core.incremental import make_delta
from repro.stream import DeltaRecord, coalesce, coalesce_rows

BACKENDS = ("xla", "pallas")
VOCAB = 16
WORDS = 3


# ---------------------------------------------------------------------------
# unit semantics
# ---------------------------------------------------------------------------

def test_first_last_rules():
    rid = np.array([3, 3, 5, 7, 7, 7, 7, 9, 9], np.int32)
    sg = np.array([-1, 1, 1, -1, 1, -1, 1, 1, -1], np.int8)
    vals = {"w": np.arange(9 * 2, dtype=np.int32).reshape(9, 2)}
    res = coalesce_rows(rid, vals, sg)
    # 3: update (-,+) kept; 5: net insert; 7: (-,+,-,+) -> (-,+);
    # 9: (+,-) created-and-destroyed -> cancelled entirely
    assert (res.n_in, res.n_out, res.n_records) == (9, 5, 4)
    assert res.n_cancelled == 4
    assert (res.n_inserts, res.n_deletes) == (1, 0)
    np.testing.assert_array_equal(np.asarray(res.delta.record_ids),
                                  [3, 3, 5, 7, 7])
    np.testing.assert_array_equal(np.asarray(res.delta.sign),
                                  [-1, 1, 1, -1, 1])
    # kept rows carry the right payloads: first '-' row, last '+' row
    np.testing.assert_array_equal(np.asarray(res.delta.values["w"]),
                                  vals["w"][[0, 1, 2, 3, 6]])


def test_everything_cancels():
    res = coalesce_rows(np.array([4, 4], np.int32),
                        {"w": np.zeros((2, 2), np.int32)},
                        np.array([1, -1], np.int8))
    assert res.delta is None
    assert res.n_out == 0 and res.n_cancelled == 2
    assert res.n_records == 1


def test_empty_batch():
    res = coalesce([])
    assert res.delta is None and res.n_in == 0


def test_coalesce_concatenates_records():
    a = DeltaRecord(record_ids=[1, 1], sign=[-1, 1],
                    values={"w": np.zeros((2, 2), np.int32)}, epoch=0)
    b = DeltaRecord(record_ids=[1, 1], sign=[-1, 1],
                    values={"w": np.ones((2, 2), np.int32)}, epoch=1)
    res = coalesce([a, b])
    # two sequential updates of record 1 collapse to (- first old, + last new)
    assert res.n_out == 2
    np.testing.assert_array_equal(np.asarray(res.delta.values["w"]),
                                  [[0, 0], [1, 1]])
    np.testing.assert_array_equal(np.asarray(res.delta.sign), [-1, 1])


# ---------------------------------------------------------------------------
# the equivalence property, per backend
# ---------------------------------------------------------------------------

def _well_formed_rows(rng, rids, docs0):
    """Turn a raw rid sequence into a valid signed op-row sequence over an
    exists-mirror, returning (rows, final corpus, final validity)."""
    mirror = docs0.copy()
    exists = np.ones(len(docs0), bool)
    rows = []                            # (rid, value row, sign)
    for r in rids:
        if exists[r]:
            if rng.integers(0, 3) == 0:              # delete
                rows.append((r, mirror[r].copy(), -1))
                exists[r] = False
            else:                                    # update: '-' old, '+' new
                new = rng.integers(0, VOCAB, (WORDS,)).astype(np.int32)
                rows.append((r, mirror[r].copy(), -1))
                rows.append((r, new, +1))
                mirror[r] = new
        else:                                        # re-insert
            new = rng.integers(0, VOCAB, (WORDS,)).astype(np.int32)
            rows.append((r, new, +1))
            mirror[r] = new
            exists[r] = True
    return rows, mirror, exists


@pytest.mark.parametrize("backend", BACKENDS)
@settings(max_examples=6, deadline=None)
@given(st.lists(st.integers(0, 5), min_size=0, max_size=8),
       st.integers(0, 10**6))
def test_coalesced_update_equivalent_to_one_by_one(backend, rids, seed):
    rng = np.random.default_rng(seed)
    docs0 = rng.integers(0, VOCAB, (6, WORDS)).astype(np.int32)
    rows, mirror, exists = _well_formed_rows(rng, rids, docs0)

    spec, data = wc.make_job(docs0, VOCAB)
    cfg = RunConfig(backend=backend, onestep_path="mrbg", value_bytes=4)
    one_by_one = Session(spec, cfg)
    one_by_one.run(data)
    for r, v, s in rows:
        one_by_one.update(make_delta([r], {"w": v[None]}, [s]))

    batched = Session(spec, cfg)
    batched.run(data)
    if rows:
        res = coalesce_rows(
            np.array([r for r, _, _ in rows], np.int32),
            {"w": np.stack([v for _, v, _ in rows])},
            np.array([s for _, _, s in rows], np.int8), backend=backend)
        assert res.n_out <= res.n_in
        if res.delta is not None:
            batched.update(res.delta)

    np.testing.assert_array_equal(batched.result["c"],
                                  one_by_one.result["c"])
    np.testing.assert_array_equal(batched.result["c"],
                                  wc.oracle(mirror, VOCAB, valid=exists))


def test_coalesced_update_equivalent_accumulator_path():
    """Same property through the §3.5 accumulator fast path."""
    rng = np.random.default_rng(3)
    docs0 = rng.integers(0, VOCAB, (6, WORDS)).astype(np.int32)
    rows, mirror, exists = _well_formed_rows(rng, [0, 1, 1, 4, 4, 2], docs0)

    spec, data = wc.make_job(docs0, VOCAB)
    cfg = RunConfig(onestep_path="accumulator")
    one_by_one = Session(spec, cfg)
    one_by_one.run(data)
    for r, v, s in rows:
        one_by_one.update(make_delta([r], {"w": v[None]}, [s]))

    batched = Session(spec, cfg)
    batched.run(data)
    res = coalesce_rows(np.array([r for r, _, _ in rows], np.int32),
                        {"w": np.stack([v for _, v, _ in rows])},
                        np.array([s for _, _, s in rows], np.int8))
    batched.update(res.delta)
    np.testing.assert_array_equal(batched.result["c"],
                                  one_by_one.result["c"])
