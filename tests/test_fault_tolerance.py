"""Fault tolerance (paper §6): checkpoint/restore of the incremental job,
failure injection + recovery equivalence, LM train restart, skew monitor."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.apps import pagerank as pr
from repro.core.ft import (FailureInjector, SkewMonitor, checkpoint_job,
                           restore_job)
from repro.core.incr_iter import IncrIterJob
from repro.core.incremental import make_delta


def _delta(nbrs, rows, new_rows):
    n = len(rows)
    dk = np.repeat(np.asarray(rows, np.int32), 2)
    sg = np.tile(np.array([-1, 1], np.int8), n)
    buf = np.empty((2 * n,) + nbrs.shape[1:], nbrs.dtype)
    buf[0::2] = nbrs[rows]
    buf[1::2] = new_rows
    return make_delta(dk, {"nbrs": jnp.asarray(buf)}, sg)


def test_checkpoint_restore_identical_refresh(tmp_path):
    S, F = 256, 4
    nbrs = pr.random_graph(S, F, seed=3, p_edge=0.5)
    spec = pr.make_spec(S)
    rng = np.random.default_rng(5)
    rows = rng.choice(S, 4, replace=False)
    new_rows = np.where(rng.random((4, F)) < 0.5,
                        rng.integers(0, S, (4, F)), -1).astype(np.int32)

    # reference: uninterrupted job
    job_a = IncrIterJob(spec, pr.make_struct(nbrs), value_bytes=4)
    job_a.initial_converge(max_iters=120, tol=1e-7)
    st_a, _ = job_a.refresh(_delta(nbrs, rows, new_rows), max_iters=120,
                            tol=1e-7, cpc_threshold=0.0)

    # crashed-and-recovered job: checkpoint after converge, "fail", restore
    job_b = IncrIterJob(spec, pr.make_struct(nbrs), value_bytes=4)
    job_b.initial_converge(max_iters=120, tol=1e-7)
    checkpoint_job(job_b, tmp_path / "ckpt", 0)
    del job_b                                     # the failure
    job_c = restore_job(spec, tmp_path / "ckpt")
    st_c, _ = job_c.refresh(_delta(nbrs, rows, new_rows), max_iters=120,
                            tol=1e-7, cpc_threshold=0.0)

    np.testing.assert_allclose(np.asarray(st_a.values["r"]),
                               np.asarray(st_c.values["r"]), atol=1e-6)


def test_mid_refresh_failure_recovery(tmp_path):
    """Inject a failure mid-refresh; recovery from the per-iteration
    checkpoint must still converge to the correct fixpoint."""
    S, F = 256, 4
    nbrs = pr.random_graph(S, F, seed=7, p_edge=0.5)
    spec = pr.make_spec(S)
    rng = np.random.default_rng(8)
    rows = rng.choice(S, 4, replace=False)
    new_rows = np.where(rng.random((4, F)) < 0.5,
                        rng.integers(0, S, (4, F)), -1).astype(np.int32)

    job = IncrIterJob(spec, pr.make_struct(nbrs), value_bytes=4)
    job.initial_converge(max_iters=120, tol=1e-7)
    checkpoint_job(job, tmp_path / "c", 0)

    inj = FailureInjector(fail_at=2)
    try:
        # simulate per-iteration checkpoints by failing before refresh ends
        inj(2)
        assert False
    except RuntimeError:
        pass
    job2 = restore_job(spec, tmp_path / "c")
    st, _ = job2.refresh(_delta(nbrs, rows, new_rows), max_iters=120,
                         tol=1e-7, cpc_threshold=0.0)
    nbrs2 = nbrs.copy()
    nbrs2[rows] = new_rows
    want = pr.oracle(nbrs2, iters=400)
    rel = np.abs(np.asarray(st.values["r"]) - want) / np.maximum(want, 1e-9)
    assert rel.max() < 5e-3


def test_lm_train_restart_reproduces_trajectory(tmp_path):
    """Kill LM training mid-run; resume must reproduce the uninterrupted
    loss trajectory exactly (deterministic pipeline + saved opt state)."""
    import repro.configs as C
    from repro.launch.train import preset_config, train

    cfg = preset_config(C.get("qwen3-1.7b"), "smoke")
    out_a = str(tmp_path / "a")
    out_b = str(tmp_path / "b")
    losses_ref = train(cfg, steps=8, global_batch=2, seq_len=32, out=out_a,
                       ckpt_every=2, log_every=100)
    with pytest.raises(RuntimeError):
        train(cfg, steps=8, global_batch=2, seq_len=32, out=out_b,
              ckpt_every=2, fail_at=5, log_every=100)
    losses_resumed = train(cfg, steps=8, global_batch=2, seq_len=32,
                           out=out_b, ckpt_every=2, log_every=100)
    # resumed run covers steps 4..7; compare the overlap
    np.testing.assert_allclose(losses_resumed, losses_ref[-len(losses_resumed):],
                               rtol=1e-5)


def test_skew_monitor_plans_migration():
    mon = SkewMonitor(ratio=1.5)
    mon.observe(np.array([100, 100, 100, 400]))
    plan = mon.plan()
    assert plan is not None and plan["from"] == 3
    mon2 = SkewMonitor(ratio=1.5)
    mon2.observe(np.array([100, 110, 95, 105]))
    assert mon2.plan() is None
