"""Session fault tolerance: checkpoint -> restore -> update(delta) produces
exactly what the uninterrupted session produces, on both shuffle/reduce
backends (xla and pallas-interpret)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import RunConfig, Session, make_delta
from repro.apps import pagerank as pr, wordcount as wc

BACKENDS = ("xla", "pallas")


def _wc_delta(docs, row, vocab, seed):
    new = np.random.default_rng(seed).integers(
        0, vocab, (1, docs.shape[1])).astype(np.int32)
    rid = np.array([row, row], np.int32)
    buf = np.concatenate([docs[[row]], new])
    return make_delta(rid, {"w": jnp.asarray(buf)},
                      np.array([-1, 1], np.int8))


def _pr_delta(nbrs, rows, seed):
    rng = np.random.default_rng(seed)
    k, f = len(rows), nbrs.shape[1]
    new = np.where(rng.random((k, f)) < 0.5,
                   rng.integers(0, nbrs.shape[0], (k, f)), -1
                   ).astype(np.int32)
    rid = np.repeat(np.asarray(rows, np.int32), 2)
    buf = np.empty((2 * k, f), np.int32)
    buf[0::2] = nbrs[rows]
    buf[1::2] = new
    return make_delta(rid, {"nbrs": jnp.asarray(buf)},
                      np.tile(np.array([-1, 1], np.int8), k))


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("path", ["mrbg", "accumulator"])
def test_onestep_roundtrip(tmp_path, backend, path):
    vocab = 40
    rng = np.random.default_rng(0)
    docs = rng.integers(0, vocab, (24, 6)).astype(np.int32)
    cfg = RunConfig(onestep_path=path, value_bytes=4, backend=backend)

    spec, data = wc.make_job(docs, vocab)
    sess = Session(spec, cfg)
    sess.run(data)
    sess.update(_wc_delta(docs, 3, vocab, 1))
    sess.checkpoint(tmp_path / "ck")

    d2 = _wc_delta(docs, 7, vocab, 2)
    sess.update(d2)                               # uninterrupted

    restored = Session.restore(spec, tmp_path / "ck", cfg)
    assert restored.epoch == 1
    restored.update(d2)                           # resumed
    assert restored.epoch == 2
    np.testing.assert_array_equal(restored.result["c"], sess.result["c"])


@pytest.mark.parametrize("backend", BACKENDS)
def test_incr_iter_roundtrip(tmp_path, backend):
    S, F = 48, 3
    nbrs = pr.random_graph(S, F, seed=1, p_edge=0.4)
    cfg = RunConfig(max_iters=60, tol=1e-6, value_bytes=4, backend=backend)

    spec, struct = pr.make_job(nbrs)
    sess = Session(spec, cfg)
    sess.run(struct)
    sess.checkpoint(tmp_path / "ck")

    delta = _pr_delta(nbrs, [5, 9], seed=4)
    rep_live = sess.update(delta)                 # uninterrupted

    restored = Session.restore(spec, tmp_path / "ck", cfg)
    rep_rest = restored.update(delta)             # resumed
    assert rep_rest.mode == rep_live.mode
    assert rep_rest.iters == rep_live.iters
    np.testing.assert_allclose(restored.result["r"], sess.result["r"],
                               rtol=1e-6, atol=0)


def test_auto_checkpoint_cadence(tmp_path):
    """RunConfig(checkpoint_dir, checkpoint_every) snapshots inside
    run/update without explicit checkpoint() calls."""
    vocab = 40
    rng = np.random.default_rng(3)
    docs = rng.integers(0, vocab, (16, 6)).astype(np.int32)
    spec, data = wc.make_job(docs, vocab)
    cfg = RunConfig(onestep_path="mrbg", value_bytes=4,
                    checkpoint_dir=str(tmp_path / "auto"),
                    checkpoint_every=2)
    sess = Session(spec, cfg)
    sess.run(data)                                # epoch 0 -> snapshot
    sess.update(_wc_delta(docs, 1, vocab, 1))     # epoch 1 -> no snapshot
    assert (tmp_path / "auto" / "ep_000000").exists()
    assert not (tmp_path / "auto" / "ep_000001").exists()
    sess.update(_wc_delta(docs, 2, vocab, 2))     # epoch 2 -> snapshot
    assert (tmp_path / "auto" / "ep_000002").exists()

    restored = Session.restore(spec, tmp_path / "auto", cfg.replace(
        checkpoint_dir=None))
    assert restored.epoch == 2
    np.testing.assert_array_equal(restored.result["c"], sess.result["c"])
