"""Property-testing shim: real hypothesis when installed, seeded fallback
otherwise.

The container this repo grows in does not ship ``hypothesis`` (and new deps
cannot be installed), so the property tests import ``given``/``settings``/
``st`` from here.  When hypothesis is available (CI installs it via the
``test`` extra in pyproject.toml) it is used unchanged — shrinking, edge-case
bias and all.  Otherwise a miniature deterministic sampler provides the same
decorator API: each test runs ``max_examples`` times over examples drawn from
a per-test seeded RNG, with the boundary values pinned as the first examples.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import functools
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def draw(self, rng):
            raise NotImplementedError

        def boundary(self):
            """Deterministic edge-case examples tried before random draws."""
            return []

    class _Integers(_Strategy):
        def __init__(self, min_value, max_value):
            self.lo, self.hi = int(min_value), int(max_value)

        def draw(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

        def boundary(self):
            vals = {self.lo, self.hi, min(max(0, self.lo), self.hi),
                    min(max(1, self.lo), self.hi)}
            return sorted(vals)

    class _Lists(_Strategy):
        def __init__(self, elements, min_size=0, max_size=10):
            self.elements = elements
            self.min_size, self.max_size = int(min_size), int(max_size)

        def draw(self, rng):
            n = int(rng.integers(self.min_size, self.max_size + 1))
            return [self.elements.draw(rng) for _ in range(n)]

        def boundary(self):
            out = []
            rng = np.random.default_rng(0)
            for size in {self.min_size, self.max_size}:
                out.append([self.elements.draw(rng) for _ in range(size)])
            return out

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def draw(self, rng):
            return self.options[int(rng.integers(0, len(self.options)))]

        def boundary(self):
            return self.options[:2]

    class _Booleans(_SampledFrom):
        def __init__(self):
            super().__init__([False, True])

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10):
            return _Lists(elements, min_size, max_size)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

        @staticmethod
        def booleans():
            return _Booleans()

    st = _St()

    def settings(max_examples: int = 20, deadline=None, **_kw):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            max_examples = getattr(fn, "_hyp_max_examples", 20)

            import inspect
            sig = inspect.signature(fn)
            all_params = list(sig.parameters.values())
            bound_names = [p.name for p in all_params[-len(strategies):]]

            @functools.wraps(fn)
            def wrapper(*args, **kw):  # noqa: ANN001
                return _run_examples(fn, strategies, max_examples,
                                     bound_names, args, kw)

            # hide the strategy-bound trailing params from pytest, which
            # would otherwise look for fixtures named after them
            wrapper.__signature__ = sig.replace(
                parameters=all_params[:-len(strategies)])
            del wrapper.__wrapped__
            return wrapper
        return deco

    def _run_examples(fn, strategies, max_examples, bound_names, args, kw):
        seed = zlib.crc32(fn.__qualname__.encode())
        rng = np.random.default_rng(seed)
        # boundary examples first (zip pads shorter lists with random draws)
        bounds = [s.boundary() for s in strategies]
        n_bound = min(max(map(len, bounds)), max_examples)
        examples = []
        for i in range(n_bound):
            examples.append(tuple(
                b[i] if i < len(b) else s.draw(rng)
                for s, b in zip(strategies, bounds)))
        while len(examples) < max_examples:
            examples.append(tuple(s.draw(rng) for s in strategies))
        for ex in examples:
            try:
                fn(*args, **kw, **dict(zip(bound_names, ex)))
            except Exception as e:
                raise AssertionError(
                    f"{fn.__qualname__} failed on example {ex!r}: {e}"
                ) from e
