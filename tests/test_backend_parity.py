"""Backend parity: the Pallas kernels and the XLA fallback must agree
bit-for-bit through the dispatcher (``repro.kernels.ops``).

Both backends implement a total order for the shuffle sort (k2, mk, row
index), so the permutation — not just the sorted keys — must match exactly.
Segment reductions are compared on integer-valued data (ints, and floats
holding small integers) where the sum is exact regardless of accumulation
order, so equality is bitwise there too.
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from _hyp import given, settings, st  # hypothesis, or seeded fallback

from repro.core.incremental import _combine_edges, _merge_reduce
from repro.core.kvstore import (
    INVALID_KEY, make_edges, max_reducer, mean_reducer, min_reducer,
    segment_reduce, sort_edges, sum_reducer,
)
from repro.kernels import ops

REDUCERS = {
    "sum": sum_reducer(),
    "min": min_reducer(),
    "max": max_reducer(),
    "mean": mean_reducer(),
}


def _both(fn):
    return fn("xla"), fn("pallas")


# ---------------------------------------------------------------------------
# sort_pairs
# ---------------------------------------------------------------------------

@given(st.integers(1, 300), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_sort_pairs_permutation_parity(n, seed):
    """Non-power-of-two lengths, duplicate keys, ties broken identically."""
    rng = np.random.default_rng(seed % 2**31)
    k2 = jnp.asarray(rng.integers(0, max(n // 4, 2), n), jnp.int32)
    mk = jnp.asarray(rng.integers(0, 3, n), jnp.int32)
    payload = {"a": jnp.asarray(rng.integers(-100, 100, n), jnp.int32),
               "b": jnp.asarray(rng.integers(0, 9, (n, 2)), jnp.int32)}
    rx, rp = _both(lambda bk: ops.sort_pairs(k2, mk, payload, backend=bk))
    np.testing.assert_array_equal(np.asarray(rx.perm), np.asarray(rp.perm))
    np.testing.assert_array_equal(np.asarray(rx.k2), np.asarray(rp.k2))
    np.testing.assert_array_equal(np.asarray(rx.mk), np.asarray(rp.mk))
    for name in payload:
        np.testing.assert_array_equal(np.asarray(rx.payload[name]),
                                      np.asarray(rp.payload[name]))


@given(st.integers(1, 200), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_sort_edges_parity_with_invalid_rows(n, seed):
    rng = np.random.default_rng(seed % 2**31)
    e = make_edges(rng.integers(0, 8, n), rng.integers(0, 50, n),
                   {"v": jnp.asarray(rng.integers(-4, 5, (n, 3)),
                                     jnp.float32)},
                   valid=rng.random(n) < 0.7,
                   sign=np.where(rng.random(n) < 0.2, -1, 1).astype(np.int8))
    sx, sp = _both(lambda bk: sort_edges(e, backend=bk))
    for name in ("k2", "mk", "valid", "sign"):
        np.testing.assert_array_equal(np.asarray(getattr(sx, name)),
                                      np.asarray(getattr(sp, name)))
    np.testing.assert_array_equal(np.asarray(sx.v2["v"]),
                                  np.asarray(sp.v2["v"]))
    # invalid rows masked to INVALID_KEY and pushed to the tail
    k2 = np.asarray(sp.k2)
    valid = np.asarray(sp.valid)
    assert (k2[~valid] == int(INVALID_KEY)).all()


def test_sort_pairs_single_key_stable():
    rng = np.random.default_rng(0)
    n = 129                                     # non-power-of-two
    k2 = jnp.asarray(rng.integers(0, 4, n), jnp.int32)
    rx, rp = _both(lambda bk: ops.sort_pairs(k2, None, num_keys=1,
                                             backend=bk))
    np.testing.assert_array_equal(np.asarray(rx.perm), np.asarray(rp.perm))
    # stability: equal keys keep input order
    perm = np.asarray(rp.perm)
    k2n = np.asarray(k2)
    for key in range(4):
        idx = perm[k2n[perm] == key]
        assert (np.diff(idx) > 0).all()


# ---------------------------------------------------------------------------
# segment_reduce: all four Reducer kinds, pytree values, >1-D leaves
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["sum", "min", "max", "mean"])
@given(st.integers(1, 257), st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_segment_reduce_parity(kind, n, seed):
    rng = np.random.default_rng(seed % 2**31)
    k = int(rng.integers(1, 40))
    seg = jnp.asarray(rng.integers(0, k + 2, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    values = {
        # integer-valued float32: order-independent exact sums
        "f": jnp.asarray(rng.integers(-8, 9, n).astype(np.float32)),
        "m": jnp.asarray(rng.integers(-8, 9, (n, 3)).astype(np.float32)),
        "i": jnp.asarray(rng.integers(-100, 100, n), jnp.int32),
        # 3-D leaf: the pallas path flattens trailing dims
        "t": jnp.asarray(rng.integers(0, 5, (n, 2, 2)).astype(np.float32)),
    }
    (ax, cx), (ap, cp) = _both(
        lambda bk: segment_reduce(REDUCERS[kind], seg, values, valid, k,
                                  backend=bk))
    np.testing.assert_array_equal(np.asarray(cx), np.asarray(cp))
    for name in values:
        np.testing.assert_array_equal(
            np.asarray(ax[name]), np.asarray(ap[name]),
            err_msg=f"kind={kind} leaf={name}")


def test_segment_reduce_empty_groups_identity_parity():
    """Groups with no valid rows must agree (sum: 0, min/max: identity)."""
    seg = jnp.asarray([0, 0, 5], jnp.int32)
    valid = jnp.asarray([True, True, False])
    vals = {"v": jnp.asarray([1.0, 2.0, 7.0], jnp.float32)}
    for kind in ("sum", "min", "max", "mean"):
        (ax, cx), (ap, cp) = _both(
            lambda bk: segment_reduce(REDUCERS[kind], seg, vals, valid, 8,
                                      backend=bk))
        np.testing.assert_array_equal(np.asarray(ax["v"]),
                                      np.asarray(ap["v"]))
        np.testing.assert_array_equal(np.asarray(cx), np.asarray(cp))
        assert int(np.asarray(cp)[5]) == 0


# ---------------------------------------------------------------------------
# tombstone merge (incremental._merge_reduce): last writer wins on both
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_merge_reduce_tombstone_parity(seed):
    rng = np.random.default_rng(seed % 2**31)
    key_cap = 64
    npres, ndelta = int(rng.integers(1, 60)), int(rng.integers(1, 60))
    # preserved edges: all +1; delta edges: mix of tombstones and inserts,
    # some hitting the same (k2, mk) as preserved rows (updates)
    pk2 = rng.integers(0, 8, npres).astype(np.int32)
    pmk = rng.integers(0, 20, npres).astype(np.int32)
    pv = {"v": rng.integers(-8, 9, npres).astype(np.float32)}
    dk2 = rng.integers(0, 8, ndelta).astype(np.int32)
    dmk = rng.integers(0, 20, ndelta).astype(np.int32)
    dv = {"v": rng.integers(-8, 9, ndelta).astype(np.float32)}
    dsign = np.where(rng.random(ndelta) < 0.4, -1, 1).astype(np.int8)

    affected = np.unique(np.concatenate([pk2, dk2]))
    keys_pad = np.full(key_cap, np.int32(2**31 - 1), np.int32)
    keys_pad[:affected.size] = affected

    def run(bk):
        # combined buffer is donated, so build it fresh per backend
        combined = _combine_edges(pk2, pmk, pv, dk2, dmk, dv, dsign)
        return _merge_reduce(sum_reducer(), key_cap, bk, combined,
                             jnp.asarray(keys_pad))

    (mx, vx, cx), (mp, vp, cp) = _both(run)
    np.testing.assert_array_equal(np.asarray(cx), np.asarray(cp))
    np.testing.assert_array_equal(np.asarray(vx["v"]), np.asarray(vp["v"]))
    # the merged (live) edge sets agree
    lx = {(int(a), int(b)) for a, b, ok in
          zip(np.asarray(mx.k2), np.asarray(mx.mk), np.asarray(mx.valid))
          if ok}
    lp = {(int(a), int(b)) for a, b, ok in
          zip(np.asarray(mp.k2), np.asarray(mp.mk), np.asarray(mp.valid))
          if ok}
    assert lx == lp
    # last-writer-wins: a (k2, mk) whose final delta row is a tombstone
    # must not be live
    final_sign = {}
    for a, b in zip(pk2, pmk):
        final_sign[(int(a), int(b))] = 1
    for a, b, s in zip(dk2, dmk, dsign):
        final_sign[(int(a), int(b))] = int(s)
    want_live = {k for k, s in final_sign.items() if s > 0}
    assert lp == want_live


# ---------------------------------------------------------------------------
# backend selection plumbing
# ---------------------------------------------------------------------------

def test_backend_selection_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_BACKEND", raising=False)
    assert ops.resolve_backend("xla") == "xla"
    assert ops.resolve_backend("pallas") == "pallas"
    # auto resolves by platform (cpu container => xla)
    import jax
    want_auto = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert ops.resolve_backend(None) == want_auto
    # env var
    monkeypatch.setenv("REPRO_BACKEND", "pallas")
    assert ops.resolve_backend(None) == "pallas"
    # config beats env; context manager restores
    with ops.use_backend("xla"):
        assert ops.resolve_backend(None) == "xla"
        # per-call beats config
        assert ops.resolve_backend("pallas") == "pallas"
    assert ops.resolve_backend(None) == "pallas"
    monkeypatch.setenv("REPRO_BACKEND", "bogus")
    with pytest.raises(ValueError):
        ops.resolve_backend(None)


def test_invalid_backend_rejected():
    with pytest.raises(ValueError):
        ops.set_backend("cuda")
    with pytest.raises(ValueError):
        ops.resolve_backend("bogus")
