"""Per-app correctness vs dense numpy oracles (full + incremental)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.incr_iter import IncrIterJob
from repro.core.incremental import make_delta
from repro.core.iterative import run_iterative, run_plain


def _update_delta(rows, olds, news, key):
    n = len(rows)
    dk = np.repeat(np.asarray(rows, np.int32), 2)
    sg = np.tile(np.array([-1, 1], np.int8), n)
    buf = np.empty((2 * n,) + olds.shape[1:], olds.dtype)
    buf[0::2] = olds
    buf[1::2] = news
    return make_delta(dk, {key: jnp.asarray(buf)}, sg)


class TestPageRank:
    def test_converges_to_oracle(self):
        from repro.apps import pagerank as pr
        nbrs = pr.random_graph(128, 5, seed=1)
        st, hist = run_iterative(pr.make_spec(128), pr.make_struct(nbrs),
                                 max_iters=150, tol=1e-8)
        want = pr.oracle(nbrs)
        np.testing.assert_allclose(np.asarray(st.values["r"]), want,
                                   atol=1e-4)

    def test_plain_equals_iter(self):
        from repro.apps import pagerank as pr
        nbrs = pr.random_graph(64, 4, seed=2)
        s1, _ = run_iterative(pr.make_spec(64), pr.make_struct(nbrs),
                              max_iters=80, tol=1e-7)
        s2, _ = run_plain(pr.make_spec(64), pr.make_struct(nbrs),
                          max_iters=80, tol=1e-7)
        np.testing.assert_allclose(np.asarray(s1.values["r"]),
                                   np.asarray(s2.values["r"]), atol=1e-6)


class TestSSSP:
    def test_converges_to_bellman_ford(self):
        from repro.apps import sssp
        nbrs, w = sssp.random_weighted_graph(96, 5, seed=2, p_edge=0.35)
        st, _ = run_iterative(sssp.make_spec(96),
                              sssp.make_struct(nbrs, w, src=0),
                              max_iters=150, tol=1e-7)
        want = sssp.oracle(nbrs, w, 0)
        got = np.asarray(st.values["d"])
        finite = want < sssp.INF / 2
        np.testing.assert_allclose(got[finite], want[finite], atol=1e-3)
        assert (got[~finite] > sssp.INF / 2).all()

    def test_incremental_edge_deletion_increases_distances(self):
        """min-reduce requires the MRBGraph: deletions must propagate
        distance *increases* — impossible for accumulator shortcuts."""
        from repro.apps import sssp
        nbrs, w = sssp.random_weighted_graph(96, 5, seed=2, p_edge=0.35)
        spec = sssp.make_spec(96)
        job = IncrIterJob(spec, sssp.make_struct(nbrs, w, src=0),
                          value_bytes=4)
        job.initial_converge(max_iters=150, tol=1e-7)
        rows = np.array([3, 11], np.int32)
        new_n = nbrs[rows].copy()
        new_n[:, :2] = -1
        # record id = vertex + 1 (row 0 is the virtual root)
        dk = np.repeat(rows + 1, 2)
        sg = np.tile(np.array([-1, 1], np.int8), 2)
        nb = np.empty((4,) + nbrs.shape[1:], nbrs.dtype)
        nb[0::2] = nbrs[rows]
        nb[1::2] = new_n
        wb = np.repeat(w[rows], 2, axis=0)
        delta = make_delta(dk, {"nbrs": jnp.asarray(nb),
                                 "w": jnp.asarray(wb)}, sg)
        st, hist = job.refresh(delta, max_iters=150, tol=1e-7,
                               cpc_threshold=0.0)
        nbrs2 = nbrs.copy()
        nbrs2[rows] = new_n
        want = sssp.oracle(nbrs2, w, 0)
        got = np.asarray(st.values["d"])
        finite = want < sssp.INF / 2
        np.testing.assert_allclose(got[finite], want[finite], atol=1e-3)
        assert (got[~finite] > sssp.INF / 2).all()


class TestKmeans:
    def test_converges_to_oracle(self):
        from repro.apps import kmeans
        rng = np.random.default_rng(0)
        k, dim = 4, 3
        centers = rng.normal(0, 5, (k, dim))
        pts = np.concatenate(
            [rng.normal(c, 0.3, (50, dim)) for c in centers]
        ).astype(np.float32)
        init = pts[rng.choice(len(pts), k, replace=False)]
        st, _ = run_iterative(kmeans.make_spec(k, dim, init),
                              kmeans.make_struct(pts), max_iters=50,
                              tol=1e-6)
        want = kmeans.oracle(pts, init)
        got = np.sort(np.asarray(st.values["c"]), axis=0)
        np.testing.assert_allclose(got, np.sort(want, axis=0), atol=1e-3)


class TestGIMV:
    def test_converges_to_dense_fixpoint(self):
        from repro.apps import gimv
        nb, bs = 8, 16
        blocks = gimv.random_blocks(nb, bs, seed=4)
        bvec = np.ones((nb, bs), np.float32)
        st, _ = run_iterative(gimv.make_spec(nb, bs, bvec),
                              gimv.make_struct(blocks, nb),
                              max_iters=300, tol=1e-9)
        want = gimv.oracle(blocks, nb, bs, bvec)
        np.testing.assert_allclose(np.asarray(st.values["v"]), want,
                                   atol=1e-4)

    def test_incremental_block_update(self):
        from repro.apps import gimv
        nb, bs = 8, 8
        blocks = gimv.random_blocks(nb, bs, seed=5)
        bvec = np.ones((nb, bs), np.float32)
        spec = gimv.make_spec(nb, bs, bvec)
        job = IncrIterJob(spec, gimv.make_struct(blocks, nb),
                          value_bytes=4 * bs)
        job.initial_converge(max_iters=300, tol=1e-9)
        rids = np.array([5], np.int32)
        newb = blocks.copy()
        newb[5] = blocks[5] * 0.25
        delta = _update_delta(rids, blocks[rids], newb[rids], "m")
        st, hist = job.refresh(delta, max_iters=300, tol=1e-9,
                               cpc_threshold=0.0)
        want = gimv.oracle(newb, nb, bs, bvec)
        np.testing.assert_allclose(np.asarray(st.values["v"]), want,
                                   atol=1e-4)


class TestAPriori:
    def test_accumulator_matches_oracle(self):
        from repro.apps import apriori
        from repro.core.accumulator import AccumulatorJob
        rng = np.random.default_rng(1)
        V, L, N = 40, 10, 150
        tweets = rng.integers(0, V, (N, L)).astype(np.int32)
        tweets[rng.random((N, L)) < 0.2] = -1
        pairs = apriori.candidate_pairs(tweets, V, top=24)
        job = AccumulatorJob(apriori.make_spec(pairs))
        job.initial_run(apriori.make_input(np.arange(N), tweets))
        new = rng.integers(0, V, (20, L)).astype(np.int32)
        ids = np.arange(N, N + 20, dtype=np.int32)
        delta = make_delta(ids, {"w": jnp.asarray(new)},
                           np.ones(20, np.int8))
        job.incremental_run(delta)
        want = apriori.oracle(np.concatenate([tweets, new]), pairs)
        np.testing.assert_allclose(job.view.as_dict()["c"], want)
