"""MRBG-Store: all four Table-4 read policies, incremental append,
multi-batch retrieval, compaction, I/O accounting."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis, or seeded fallback

from repro.core.mrbg_store import MRBGStore, POLICIES


def _mk_store(policy, num_keys=200, value_bytes=8):
    return MRBGStore(num_keys, value_bytes, policy=policy,
                     gap_threshold=64, cache_bytes=4096,
                     fix_window_bytes=512)


def _append_random(store, rng, keys):
    keys = np.sort(np.asarray(keys, np.int32))
    mk = rng.integers(0, 1000, keys.shape[0]).astype(np.int32)
    v2 = {"v": rng.normal(0, 1, keys.shape[0]).astype(np.float32)}
    store.append(keys, mk, v2)
    return keys, mk, v2


@pytest.mark.parametrize("policy", POLICIES)
def test_roundtrip_single_batch(policy):
    rng = np.random.default_rng(0)
    store = _mk_store(policy)
    keys = np.repeat(np.arange(0, 50, 2), 3)      # chunks of 3 records
    keys, mk, v2 = _append_random(store, rng, keys)
    q = np.arange(0, 50, 2)
    k2, mk_out, v2_out, lens = store.query(q)
    assert (lens == 3).all()
    np.testing.assert_array_equal(k2, keys)


@pytest.mark.parametrize("policy", POLICIES)
def test_latest_version_wins_across_batches(policy):
    rng = np.random.default_rng(1)
    store = _mk_store(policy)
    base = np.repeat(np.arange(20), 2)
    _append_random(store, rng, base)
    # new batch overwrites chunks 3 and 7 with single records
    nk = np.array([3, 7], np.int32)
    nmk = np.array([900, 901], np.int32)
    nv = {"v": np.array([42.0, 43.0], np.float32)}
    store.append(nk, nmk, nv)
    k2, mk, v2, lens = store.query(np.array([3, 7]))
    np.testing.assert_array_equal(mk, nmk)
    np.testing.assert_allclose(v2["v"], nv["v"])
    assert store.n_batches == 2


def test_deletion_and_compaction():
    rng = np.random.default_rng(2)
    store = _mk_store("multi-dynamic-window")
    _append_random(store, rng, np.repeat(np.arange(30), 2))
    store.mark_deleted(np.array([5, 6]))
    _, _, _, lens = store.query(np.array([5, 6, 7]))
    assert list(lens) == [0, 0, 2]
    live_before = store.live_bytes()
    store.compact()
    assert store.n_batches == 1
    assert store.live_bytes() == live_before
    assert store.file_bytes() == live_before     # obsolete space reclaimed
    _, _, _, lens = store.query(np.array([5, 7]))
    assert list(lens) == [0, 2]


def test_policies_agree_but_io_differs():
    """All four policies return identical data; dynamic windows do fewer
    reads than index-only (Table 4's qualitative ordering)."""
    rng = np.random.default_rng(3)
    results = {}
    stats = {}
    for policy in POLICIES:
        store = _mk_store(policy, num_keys=500)
        rng2 = np.random.default_rng(3)
        for _ in range(3):     # several batches => multiple windows
            keys = np.repeat(np.sort(rng2.choice(500, 80, replace=False)), 2)
            _append_random(store, rng2, keys)
        q = np.arange(0, 500, 7)
        k2, mk, v2, lens = store.query(q)
        results[policy] = (k2.copy(), mk.copy(), lens.copy())
        stats[policy] = (store.stats.n_reads, store.stats.bytes_read)
    base = results[POLICIES[0]]
    for policy in POLICIES[1:]:
        np.testing.assert_array_equal(results[policy][0], base[0])
        np.testing.assert_array_equal(results[policy][2], base[2])
    assert stats["multi-dynamic-window"][0] <= stats["index-only"][0]


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_query_random_subsets(seed):
    rng = np.random.default_rng(seed % 2**31)
    store = _mk_store("multi-dynamic-window", num_keys=100)
    mirror = {}
    for batch in range(3):
        ks = np.sort(rng.choice(100, rng.integers(5, 30), replace=False))
        ks_rep = np.repeat(ks, rng.integers(1, 4))
        keys, mk, v2 = _append_random(store, rng, ks_rep)
        for k in ks:
            sel = keys == k
            mirror[k] = (mk[sel], v2["v"][sel])
    q = np.sort(rng.choice(100, 20, replace=False))
    k2, mk, v2, lens = store.query(q)
    off = 0
    for key, ln in zip(q, lens):
        if key in mirror:
            want_mk, want_v = mirror[key]
            assert ln == want_mk.shape[0]
            np.testing.assert_array_equal(mk[off:off + ln], want_mk)
            np.testing.assert_allclose(v2["v"][off:off + ln], want_v)
        else:
            assert ln == 0
        off += ln
