"""End-to-end behaviour tests for the paper's system: incremental one-step
and incremental iterative refreshes match from-scratch recomputation."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.apps import pagerank as pr
from repro.apps import wordcount as wc
from repro.core.accumulator import AccumulatorJob
from repro.core.incr_iter import IncrIterJob
from repro.core.incremental import IncrementalJob, make_delta
from repro.core.iterative import run_iterative


def _wc_corpus(n=30, vocab=60, length=8, seed=0):
    rng = np.random.default_rng(seed)
    docs = rng.integers(0, vocab, size=(n, length)).astype(np.int32)
    docs[rng.random(docs.shape) < 0.1] = -1
    return docs


def _update_delta(rows, old_rows, new_rows, values_key="w"):
    n = len(rows)
    dk = np.repeat(np.asarray(rows, np.int32), 2)
    sg = np.tile(np.array([-1, 1], np.int8), n)
    buf = np.empty((2 * n,) + old_rows.shape[1:], old_rows.dtype)
    buf[0::2] = old_rows
    buf[1::2] = new_rows
    return make_delta(dk, {values_key: jnp.asarray(buf)}, sg)


class TestIncrementalOneStep:
    VOCAB = 60

    def test_incremental_equals_recompute(self):
        docs = _wc_corpus()
        spec = wc.make_spec(self.VOCAB)
        job = IncrementalJob(spec, value_bytes=4)
        job.initial_run(wc.make_input(np.arange(len(docs)), docs))

        rng = np.random.default_rng(1)
        new3 = rng.integers(0, self.VOCAB, (1, docs.shape[1])).astype(np.int32)
        delta = _update_delta([3], docs[[3]], new3)
        job.incremental_run(delta)

        docs2 = docs.copy()
        docs2[3] = new3[0]
        want = wc.oracle(docs2, self.VOCAB)
        got = job.view.as_dict()["c"]
        np.testing.assert_allclose(got, want)

    def test_insert_and_delete(self):
        docs = _wc_corpus()
        spec = wc.make_spec(self.VOCAB)
        job = IncrementalJob(spec, value_bytes=4)
        job.initial_run(wc.make_input(np.arange(len(docs)), docs))
        rng = np.random.default_rng(2)
        newdocs = rng.integers(0, self.VOCAB, (2, docs.shape[1])
                               ).astype(np.int32)
        # delete doc 0, insert docs 30, 31
        dk = np.array([0, 30, 31], np.int32)
        vals = {"w": jnp.asarray(np.concatenate([docs[[0]], newdocs]))}
        delta = make_delta(dk, vals, np.array([-1, 1, 1], np.int8))
        job.incremental_run(delta)
        valid = np.ones(32, bool)
        valid[0] = False
        all_docs = np.concatenate([docs, newdocs])
        want = wc.oracle(all_docs, self.VOCAB, valid)
        np.testing.assert_allclose(job.view.as_dict()["c"], want)

    def test_chained_refreshes_vs_accumulator(self):
        docs = _wc_corpus()
        spec = wc.make_spec(self.VOCAB)
        mrbg = IncrementalJob(spec, value_bytes=4)
        mrbg.initial_run(wc.make_input(np.arange(len(docs)), docs))
        acc = AccumulatorJob(spec)
        acc.initial_run(wc.make_input(np.arange(len(docs)), docs))

        rng = np.random.default_rng(3)
        cur = docs.copy()
        for epoch in range(4):
            row = int(rng.integers(0, len(docs)))
            new = rng.integers(0, self.VOCAB,
                               (1, docs.shape[1])).astype(np.int32)
            delta = _update_delta([row], cur[[row]], new)
            mrbg.incremental_run(delta)
            acc.incremental_run(delta)
            cur[row] = new[0]
        want = wc.oracle(cur, self.VOCAB)
        np.testing.assert_allclose(mrbg.view.as_dict()["c"], want)
        np.testing.assert_allclose(acc.view.as_dict()["c"], want)


class TestIncrementalIterative:
    def test_pagerank_refresh_matches_recompute(self):
        S, F = 512, 4
        nbrs = pr.random_graph(S, F, seed=3, p_edge=0.5)
        spec = pr.make_spec(S)
        job = IncrIterJob(spec, pr.make_struct(nbrs), value_bytes=4)
        job.initial_converge(max_iters=150, tol=1e-7)

        rng = np.random.default_rng(5)
        rows = rng.choice(S, 5, replace=False)
        new_rows = np.where(rng.random((5, F)) < 0.5,
                            rng.integers(0, S, (5, F)), -1).astype(np.int32)
        delta = _update_delta(rows, nbrs[rows], new_rows, "nbrs")
        st, hist = job.refresh(delta, max_iters=150, tol=1e-7,
                               cpc_threshold=0.0)
        nbrs2 = nbrs.copy()
        nbrs2[rows] = new_rows
        want = pr.oracle(nbrs2, iters=400)
        got = np.asarray(st.values["r"])
        rel = np.abs(got - want) / np.maximum(want, 1e-9)
        assert rel.max() < 5e-3, rel.max()

    def test_cpc_bounded_error_and_less_work(self):
        S, F = 2048, 4
        nbrs = pr.random_graph(S, F, seed=3, p_edge=0.6)
        spec = pr.make_spec(S)
        rng = np.random.default_rng(9)
        rows = rng.choice(S, 20, replace=False)
        new_rows = np.where(rng.random((20, F)) < 0.6,
                            rng.integers(0, S, (20, F)), -1).astype(np.int32)

        results = {}
        for ft in (0.01, 0.05):
            job = IncrIterJob(spec, pr.make_struct(nbrs), value_bytes=4)
            job.initial_converge(max_iters=200, tol=1e-7)
            delta = _update_delta(rows, nbrs[rows], new_rows, "nbrs")
            st, hist = job.refresh(delta, max_iters=60, tol=1e-7,
                                   cpc_threshold=ft)
            assert hist["mode"] == "i2"
            nbrs2 = nbrs.copy()
            nbrs2[rows] = new_rows
            want = pr.oracle(nbrs2, iters=300)
            got = np.asarray(st.values["r"])
            rel = (np.abs(got - want) / np.maximum(want, 1e-9)).mean()
            work = sum(l.n_affected_dks for l in hist["logs"])
            results[ft] = (rel, work)
        # paper §8.5: mean error small; larger threshold => less work
        assert results[0.01][0] < 2e-2
        assert results[0.05][1] < results[0.01][1]

    def test_auto_mrbg_off_kmeans(self):
        from repro.apps import kmeans
        rng = np.random.default_rng(0)
        k, dim = 3, 2
        centers = rng.normal(0, 6, (k, dim))
        pts = np.concatenate(
            [rng.normal(c, 0.3, (40, dim)) for c in centers]
        ).astype(np.float32)
        init = pts[rng.choice(len(pts), k, replace=False)]
        spec = kmeans.make_spec(k, dim, init)
        job = IncrIterJob(spec, kmeans.make_struct(pts),
                          value_bytes=4 * (dim + 1))
        job.initial_converge(max_iters=50, tol=1e-6)
        new = rng.normal(centers[0], 0.3, (3, dim)).astype(np.float32)
        delta = _update_delta([0, 1, 2], pts[:3], new, "p")
        st, hist = job.refresh(delta, max_iters=50, tol=1e-6)
        assert hist["mode"] == "iterMR-fallback"   # paper Fig. 8 Kmeans
