"""Unit + hypothesis property tests for the kv substrate."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st  # hypothesis, or seeded fallback

from repro.core.kvstore import (
    Edges, compact_edges, make_edges, next_bucket, segment_reduce,
    sort_edges, sum_reducer, min_reducer, max_reducer, mean_reducer,
)


@given(st.lists(st.integers(0, 30), min_size=1, max_size=200),
       st.integers(0, 2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_segment_sum_matches_numpy(keys, seed):
    keys = np.asarray(keys, np.int32)
    rng = np.random.default_rng(seed % 2**31)
    vals = rng.normal(0, 1, keys.shape[0]).astype(np.float32)
    valid = rng.random(keys.shape[0]) < 0.8
    acc, counts = segment_reduce(sum_reducer(), jnp.asarray(keys),
                                 {"v": jnp.asarray(vals)},
                                 jnp.asarray(valid), 31)
    want = np.zeros(31)
    wc = np.zeros(31, np.int64)
    for k, v, ok in zip(keys, vals, valid):
        if ok:
            want[k] += v
            wc[k] += 1
    np.testing.assert_allclose(np.asarray(acc["v"]), want, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_array_equal(np.asarray(counts), wc)


@pytest.mark.parametrize("reducer,npop", [
    (min_reducer(), np.minimum), (max_reducer(), np.maximum)])
def test_min_max_reduce(reducer, npop):
    rng = np.random.default_rng(0)
    keys = rng.integers(0, 10, 100).astype(np.int32)
    vals = rng.normal(0, 1, 100).astype(np.float32)
    acc, counts = segment_reduce(reducer, jnp.asarray(keys),
                                 {"v": jnp.asarray(vals)},
                                 jnp.ones(100, bool), 10)
    got = np.asarray(acc["v"])
    for k in range(10):
        sel = vals[keys == k]
        if sel.size:
            expected = sel.min() if reducer.kind == "min" else sel.max()
            assert abs(got[k] - expected) < 1e-6


def test_sort_edges_orders_by_k2_mk_and_masks_invalid():
    rng = np.random.default_rng(1)
    n = 64
    e = make_edges(rng.integers(0, 8, n), rng.integers(0, 100, n),
                   {"v": jnp.asarray(rng.normal(0, 1, (n, 3)),
                                     jnp.float32)},
                   valid=rng.random(n) < 0.7)
    s = sort_edges(e)
    k2 = np.asarray(s.k2)
    mk = np.asarray(s.mk)
    valid = np.asarray(s.valid)
    nv = int(valid.sum())
    assert valid[:nv].all() and not valid[nv:].any()
    pairs = list(zip(k2[:nv], mk[:nv]))
    assert pairs == sorted(pairs)


def test_compact_edges_gathers_valid_prefix():
    rng = np.random.default_rng(2)
    n = 40
    e = make_edges(rng.integers(0, 8, n), np.arange(n),
                   {"v": jnp.asarray(rng.normal(0, 1, n), jnp.float32)},
                   valid=rng.random(n) < 0.5)
    c = compact_edges(e, 64)
    nv = int(np.asarray(e.valid).sum())
    assert int(np.asarray(c.valid).sum()) == nv
    got = set(np.asarray(c.mk)[np.asarray(c.valid)])
    want = set(np.asarray(e.mk)[np.asarray(e.valid)])
    assert got == want


@given(st.integers(1, 10**7))
@settings(max_examples=50, deadline=None)
def test_next_bucket_power_of_two(n):
    b = next_bucket(n)
    assert b >= n and b >= 256
    assert b & (b - 1) == 0
    assert b < 2 * max(n, 256)


def test_mean_reducer_finalize():
    keys = jnp.asarray([0, 0, 1], jnp.int32)
    vals = {"v": jnp.asarray([2.0, 4.0, 10.0], jnp.float32)}
    from repro.core.kvstore import finalize_reduce
    acc, counts = segment_reduce(mean_reducer(), keys, vals,
                                 jnp.ones(3, bool), 2)
    out = finalize_reduce(mean_reducer(), jnp.arange(2), acc, counts)
    np.testing.assert_allclose(np.asarray(out["v"]), [3.0, 10.0])
