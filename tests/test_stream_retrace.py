"""Latency-tail regression tests: shape bucketing must make the refresh
path trace once per delta bucket, not once per distinct row count.

Trace counting rides :mod:`repro.kernels.jitcache`: every jitted kernel on
the refresh path bumps a counter from inside its Python body, which only
executes on a jit-cache miss — so ``jitcache.generation()`` staying flat
across a batch is an exact "no retrace" witness.

The workload is sized so every shape knob lands in one bucket per stage:
vocab <= 64 keys (key bucket 64 always) and 4 words per doc (a power of
two, so delta-row buckets and edge-count buckets stay aligned across
varying batch sizes within a row bucket).
"""
import numpy as np
import pytest

from repro.api import RunConfig, StreamConfig
from repro.apps import wordcount as wc
from repro.kernels import jitcache
from repro.stream import RefreshScheduler, StreamSession

BACKENDS = ("xla", "pallas")
VOCAB = 32
L = 4                       # words per doc: power of two keeps buckets aligned


def _make(backend, n_docs=32, seed=0, **stream_kw):
    rng = np.random.default_rng(seed)
    docs = rng.integers(0, VOCAB, (n_docs, L)).astype(np.int32)
    spec, data = wc.make_job(docs, VOCAB)
    kw = dict(max_batch_delay=0.0, crossover=2.0)   # always update
    kw.update(stream_kw)
    ss = StreamSession(spec, data,
                       config=RunConfig(backend=backend, value_bytes=4),
                       stream=StreamConfig(**kw))
    return ss, docs, rng


def _push_pairs(ss, mirror, rng, n_pairs):
    """One micro-batch updating ``n_pairs`` distinct records ('-' old,
    '+' new) — 2 * n_pairs delta rows, no in-batch cancellation."""
    rows = rng.choice(len(mirror), size=n_pairs, replace=False)
    new = rng.integers(0, VOCAB, (n_pairs, L)).astype(np.int32)
    rid = np.repeat(rows.astype(np.int32), 2)
    buf = np.empty((2 * n_pairs, L), np.int32)
    buf[0::2] = mirror[rows]
    buf[1::2] = new
    mirror[rows] = new
    ss.submit(rid, {"w": buf}, np.tile(np.int8([-1, 1]), n_pairs))
    assert ss.step()


@pytest.mark.parametrize("backend", BACKENDS)
def test_no_retrace_within_bucket(backend):
    """Delta sizes that vary *within* one row bucket (and, because L is a
    power of two, one edge bucket) must not trace anything new once the
    bucket is warm."""
    ss, docs, rng = _make(backend)
    ss.start(background=False)
    mirror = docs.copy()

    # warm one batch per (row bucket, edge bucket) combination:
    # 4/12/24 pairs -> 8/24/48 rows (row bucket 64) -> 32/96/192 valid
    # edges (edge buckets 64/128/256)
    for pairs in (4, 12, 24):
        _push_pairs(ss, mirror, rng, pairs)

    gen0 = jitcache.generation()
    # probe sizes land in the same buckets: 6/20/40 rows -> 24/80/160
    # edges -> buckets 64/128/256
    for pairs in (3, 10, 20):
        _push_pairs(ss, mirror, rng, pairs)
    assert jitcache.generation() == gen0, (
        f"retraced within a warm bucket: {jitcache.trace_counts()}")
    assert ss.metrics.retrace_batches <= 3   # only the warm-up batches

    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, VOCAB))


@pytest.mark.parametrize("backend", BACKENDS)
def test_prewarm_compiles_the_ladder(backend):
    """With ``prewarm=True`` the bucket ladder is compiled on start();
    the first real full-bucket micro-batch then traces nothing."""
    ss, docs, rng = _make(backend, max_batch_records=64, prewarm=True)
    ss.start(background=False)
    mirror = docs.copy()

    gen0 = jitcache.generation()
    _push_pairs(ss, mirror, rng, 32)         # 64 rows: the full bucket
    assert jitcache.generation() == gen0, (
        f"first real batch retraced despite prewarm: "
        f"{jitcache.trace_counts()}")
    assert ss.metrics.retrace_batches == 0
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, VOCAB))


def test_prewarm_is_a_noop_on_the_result():
    """The warm-up deltas ('-' then '+' of current values) must not change
    the job's output or the mirror."""
    ss, docs, _ = _make("xla", max_batch_records=64, prewarm=True)
    ss.start(background=False)
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(docs, VOCAB))
    np.testing.assert_array_equal(
        np.asarray(ss.mirror_kv().values["w"]), docs)


def test_retraced_batches_marked_in_metrics():
    """A batch that lands in a cold bucket is flagged ``retraced`` (and its
    wall-clock excluded from the scheduler's cost model)."""
    # jit caches are process-global: 11 words per doc gives this test value
    # shapes no other test in the suite (or conftest import) has compiled yet
    rng = np.random.default_rng(21)
    docs = rng.integers(0, VOCAB, (32, 11)).astype(np.int32)
    spec, data = wc.make_job(docs, VOCAB)
    ss = StreamSession(spec, data,
                       config=RunConfig(backend="xla", value_bytes=4),
                       stream=StreamConfig(max_batch_delay=0.0,
                                           crossover=2.0))
    ss.start(background=False)
    mirror = docs.copy()

    def push(n_pairs):
        rows = rng.choice(len(mirror), size=n_pairs, replace=False)
        new = rng.integers(0, VOCAB, (n_pairs, 11)).astype(np.int32)
        rid = np.repeat(rows.astype(np.int32), 2)
        buf = np.empty((2 * n_pairs, 11), np.int32)
        buf[0::2] = mirror[rows]
        buf[1::2] = new
        mirror[rows] = new
        ss.submit(rid, {"w": buf}, np.tile(np.int8([-1, 1]), n_pairs))
        assert ss.step()

    push(4)                                  # cold bucket: traces
    assert ss.metrics.retrace_batches == 1
    assert ss.scheduler.compile_skips == 1
    push(4)                                  # warm now
    assert ss.metrics.retrace_batches == 1
    assert ss.scheduler.compile_skips == 1


def test_persistent_cache_dir_wired(tmp_path):
    """RunConfig(compilation_cache_dir=...) must flip JAX's persistent
    compilation cache on and populate the directory with executables."""
    import jax

    cache = tmp_path / "xc"
    rng = np.random.default_rng(5)
    docs = rng.integers(0, VOCAB, (16, L)).astype(np.int32)
    spec, data = wc.make_job(docs, VOCAB)
    ss = StreamSession(spec, data,
                       config=RunConfig(backend="xla", value_bytes=4,
                                        compilation_cache_dir=str(cache)),
                       stream=StreamConfig(max_batch_delay=0.0,
                                           crossover=2.0))
    ss.start(background=False)
    mirror = docs.copy()
    _push_pairs(ss, mirror, rng, 4)
    assert jax.config.jax_compilation_cache_dir == str(cache)
    assert jitcache.persistent_cache_dir() == str(cache)
    assert any(cache.iterdir()), "no executables written to the cache dir"
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, VOCAB))
