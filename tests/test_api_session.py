"""repro.api façade: one Session drives every paper mode, with parity
against the internal (pre-refactor) entry points on identical inputs."""
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import (
    IterSpec, RunConfig, Session, default_difference, make_delta,
)
from repro.apps import kmeans, pagerank as pr, wordcount as wc
from repro.core.accumulator import AccumulatorJob
from repro.core.incr_iter import IncrIterJob
from repro.core.incremental import IncrementalJob
from repro.core.iterative import run_iterative

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _wc_corpus(n=30, vocab=60, length=8, seed=0):
    rng = np.random.default_rng(seed)
    docs = rng.integers(0, vocab, size=(n, length)).astype(np.int32)
    docs[rng.random(docs.shape) < 0.1] = -1
    return docs


def _update_delta(rows, old_rows, new_rows, values_key="w"):
    n = len(rows)
    rid = np.repeat(np.asarray(rows, np.int32), 2)
    sg = np.tile(np.array([-1, 1], np.int8), n)
    buf = np.empty((2 * n,) + old_rows.shape[1:], old_rows.dtype)
    buf[0::2] = old_rows
    buf[1::2] = new_rows
    return make_delta(rid, {values_key: jnp.asarray(buf)}, sg)


# ---------------------------------------------------------------------------
# mode 1+2: one-step and incremental one-step
# ---------------------------------------------------------------------------

class TestOneStep:
    VOCAB = 60

    def test_parity_with_incremental_job(self):
        """Session(mrbg) == IncrementalJob on the same input and delta."""
        docs = _wc_corpus()
        rng = np.random.default_rng(1)
        new3 = rng.integers(0, self.VOCAB, (1, docs.shape[1])).astype(np.int32)
        delta = _update_delta([3], docs[[3]], new3)

        spec, data = wc.make_job(docs, self.VOCAB)
        sess = Session(spec, RunConfig(onestep_path="mrbg", value_bytes=4))
        rep0 = sess.run(data)
        rep1 = sess.update(delta)

        old = IncrementalJob(wc.make_spec(self.VOCAB), value_bytes=4)
        old.initial_run(wc.make_input(np.arange(len(docs)), docs))
        old.incremental_run(delta)

        np.testing.assert_array_equal(sess.result["c"],
                                      old.view.as_dict()["c"])
        assert rep0.mode == "onestep" and rep1.mode == "incremental"
        assert rep1.affected_keys > 0
        assert rep1.io is not None

    def test_accumulator_auto_path_agrees(self):
        """onestep_path='auto' picks the §3.5 accumulator for sum reducers
        and produces the same refreshed output as the MRBG engine."""
        docs = _wc_corpus()
        rng = np.random.default_rng(2)
        new5 = rng.integers(0, self.VOCAB, (1, docs.shape[1])).astype(np.int32)
        delta = _update_delta([5], docs[[5]], new5)

        spec, data = wc.make_job(docs, self.VOCAB)
        auto = Session(spec, RunConfig())          # auto -> accumulator
        auto.run(data)
        rep = auto.update(delta)
        assert rep.mode == "accumulator"

        old = AccumulatorJob(wc.make_spec(self.VOCAB))
        old.initial_run(wc.make_input(np.arange(len(docs)), docs))
        old.incremental_run(delta)
        np.testing.assert_array_equal(auto.result["c"],
                                      old.view.as_dict()["c"])

        docs2 = docs.copy()
        docs2[5] = new5[0]
        np.testing.assert_allclose(auto.result["c"],
                                   wc.oracle(docs2, self.VOCAB))


# ---------------------------------------------------------------------------
# mode 3: plain / iterative recomputation
# ---------------------------------------------------------------------------

class TestIterative:
    def test_parity_with_run_iterative(self):
        nbrs = pr.random_graph(128, 4, seed=7, p_edge=0.5)
        spec, struct = pr.make_job(nbrs)
        sess = Session(spec, RunConfig(max_iters=80, tol=1e-7))
        rep = sess.run(struct)

        state, hist = run_iterative(pr.make_spec(128), pr.make_struct(nbrs),
                                    max_iters=80, tol=1e-7)
        assert rep.mode == "iterative"
        assert rep.iters == hist["iters"]
        np.testing.assert_allclose(sess.result["r"],
                                   np.asarray(state.values["r"]),
                                   rtol=1e-6, atol=0)

    def test_plain_shuffle_same_results(self):
        """RunConfig(plain_shuffle=True) is the Algorithm-5 cost model:
        identical math, so results match the warm loop exactly."""
        nbrs = pr.random_graph(96, 4, seed=9, p_edge=0.5)
        spec, struct = pr.make_job(nbrs)
        warm = Session(spec, RunConfig(max_iters=60, tol=1e-7))
        warm.run(struct)
        spec2, struct2 = pr.make_job(nbrs)
        plain = Session(spec2, RunConfig(max_iters=60, tol=1e-7,
                                         plain_shuffle=True))
        rep = plain.run(struct2)
        assert rep.mode == "plainMR"
        np.testing.assert_allclose(plain.result["r"], warm.result["r"],
                                   rtol=1e-6, atol=0)


# ---------------------------------------------------------------------------
# mode 4: incremental iterative (+ §5.2 auto MRBG-off)
# ---------------------------------------------------------------------------

class TestIncrementalIterative:
    def test_parity_with_incr_iter_job(self):
        S, F = 512, 4
        nbrs = pr.random_graph(S, F, seed=3, p_edge=0.5)
        rng = np.random.default_rng(5)
        rows = rng.choice(S, 5, replace=False)
        new_rows = np.where(rng.random((5, F)) < 0.5,
                            rng.integers(0, S, (5, F)), -1).astype(np.int32)
        delta = _update_delta(rows, nbrs[rows], new_rows, "nbrs")

        spec, struct = pr.make_job(nbrs)
        sess = Session(spec, RunConfig(max_iters=150, tol=1e-7,
                                       value_bytes=4))
        sess.run(struct)
        rep = sess.update(delta)

        old = IncrIterJob(pr.make_spec(S), pr.make_struct(nbrs),
                          value_bytes=4)
        old.initial_converge(max_iters=150, tol=1e-7)
        st, hist = old.refresh(delta, max_iters=150, tol=1e-7)

        assert rep.mode == hist["mode"]
        assert rep.iters == hist["iters"]
        np.testing.assert_allclose(sess.result["r"],
                                   np.asarray(st.values["r"]),
                                   rtol=1e-6, atol=0)
        # refresh telemetry flows through the uniform report
        if rep.mode == "i2":
            assert rep.affected_keys == sum(
                l.n_affected_dks for l in hist["logs"])
            assert rep.io is not None

    def test_auto_mrbg_off_kmeans(self):
        """The Session decides the §5.2 fallback internally (paper Fig. 8:
        Kmeans always lands in iterMR recomp mode)."""
        rng = np.random.default_rng(0)
        k, dim = 3, 2
        centers = rng.normal(0, 6, (k, dim))
        pts = np.concatenate(
            [rng.normal(c, 0.3, (30, dim)) for c in centers]
        ).astype(np.float32)
        init = pts[rng.choice(len(pts), k, replace=False)]
        spec, struct = kmeans.make_job(pts, init)
        sess = Session(spec, RunConfig(max_iters=50, tol=1e-6,
                                       value_bytes=4 * (dim + 1)))
        sess.run(struct)
        new = rng.normal(centers[0], 0.3, (3, dim)).astype(np.float32)
        rep = sess.update(_update_delta([0, 1, 2], pts[:3], new, "p"))
        assert rep.mode == "iterMR-fallback"
        assert sess.result["c"].shape == (k, dim)


# ---------------------------------------------------------------------------
# mode 5: distributed via RunConfig(mesh=...) — needs 8 XLA host devices,
# so the parity run happens in a subprocess (flag must precede jax init)
# ---------------------------------------------------------------------------

def test_distributed_via_config_parity():
    script = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.api import Session, RunConfig, MeshConfig, make_delta
from repro.apps import pagerank as pr

S, F = 256, 5
nbrs = pr.random_graph(S, F, seed=11, p_edge=0.5)
spec, struct = pr.make_job(nbrs)
mesh = Mesh(np.array(jax.devices()[:8]), ("data",))

# the pre-PR-7 flat spelling was removed after its one-release
# deprecation window: bare Mesh now fails fast with a pointer to
# MeshConfig, and the flat knobs are unknown kwargs
try:
    RunConfig(mesh=mesh, max_iters=60)
except TypeError as e:
    assert "MeshConfig" in str(e), e
else:
    raise AssertionError("bare Mesh accepted")
try:
    RunConfig(mesh=MeshConfig(mesh, axis="data"), shuffle_cap=512)
except TypeError:
    pass
else:
    raise AssertionError("flat shuffle_cap accepted")

cfg = RunConfig(mesh=MeshConfig(mesh, axis="data", shuffle_cap=512),
                max_iters=60, tol=1e-7)
assert cfg.mesh.shuffle_cap == 512
sess = Session(spec, cfg)
rep = sess.run(struct)
assert rep.mode == "distributed", rep.mode

from repro.core.distributed import (partition_struct, partition_state,
                                    unpartition_state, run_distributed)
skeys, svals, svalid = partition_struct(
    spec, np.arange(S, dtype=np.int32), {"nbrs": nbrs},
    np.ones(S, bool), 8, sess._driver._partition_cap())
state0 = partition_state({"r": np.ones(S, np.float32)}, S, 8)
out, hist = run_distributed(spec, mesh, (skeys, svals, svalid), state0,
                            axis="data", shuffle_cap=512, max_iters=60,
                            tol=1e-7)
ref = unpartition_state({k: np.asarray(v) for k, v in out.items()}, S)

np.testing.assert_array_equal(sess.result["r"], ref["r"])
assert rep.iters == hist["iters"]

# refresh: delta -> repartition -> warm re-converge, all inside update()
rng = np.random.default_rng(5)
rows = rng.choice(S, 4, replace=False)
new = np.where(rng.random((4, F)) < 0.5,
               rng.integers(0, S, (4, F)), -1).astype(np.int32)
rid = np.repeat(rows.astype(np.int32), 2)
buf = np.empty((8, F), np.int32); buf[0::2] = nbrs[rows]; buf[1::2] = new
delta = make_delta(rid, {"nbrs": jnp.asarray(buf)},
                   np.tile(np.array([-1, 1], np.int8), 4))
rep = sess.update(delta)
nbrs2 = nbrs.copy(); nbrs2[rows] = new
want = pr.oracle(nbrs2, iters=300)
rel = np.abs(sess.result["r"] - want) / np.maximum(want, 1e-9)
assert rel.max() < 1e-3, rel.max()
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"


def test_distributed_accepts_onestep_rejects_replicated():
    from repro.api import MeshConfig
    from repro.core.engine import JobSpec
    from repro.core.kvstore import sum_reducer

    class FakeMesh:                     # stands in for a Mesh; never used
        shape = {"data": 2}

    # JobSpec + mesh drives the per-shard one-step engine
    sess = Session(JobSpec(lambda kv, s: None, sum_reducer(), 4, "j"),
                   RunConfig(mesh=MeshConfig(FakeMesh())))
    assert sess._driver.kind == "distributed-onestep"
    spec = kmeans.make_spec(2, 2, np.zeros((2, 2), np.float32))
    with pytest.raises(ValueError, match="replicate_state"):
        Session(spec, RunConfig(mesh=MeshConfig(FakeMesh())))


# ---------------------------------------------------------------------------
# API ergonomics and satellite fixes
# ---------------------------------------------------------------------------

def test_make_delta_keys_default_to_record_ids():
    d = make_delta([1, 2], {"w": jnp.zeros((2, 3))}, [1, 1])
    np.testing.assert_array_equal(np.asarray(d.keys),
                                  np.asarray(d.record_ids))
    np.testing.assert_array_equal(np.asarray(d.keys), [1, 2])
    assert bool(np.all(np.asarray(d.valid)))


def test_make_delta_legacy_order_rejected():
    # the pre-repro.api positional order (keys, record_ids, values, sign)
    # was shimmed for one release; keys/valid are now keyword-only
    with pytest.raises(TypeError):
        make_delta([9, 9], [1, 2], {"w": jnp.zeros((2, 3))}, [-1, 1])
    d = make_delta([1, 2], {"w": jnp.zeros((2, 3))}, [-1, 1], keys=[9, 9])
    np.testing.assert_array_equal(np.asarray(d.keys), [9, 9])
    np.testing.assert_array_equal(np.asarray(d.record_ids), [1, 2])
    np.testing.assert_array_equal(np.asarray(d.sign), [-1, 1])


def test_iterspec_difference_resolves_to_default():
    spec = IterSpec(map_fn=lambda s, d, g: None, reducer=None,
                    project=lambda sk: sk, num_state=4,
                    init_state=lambda dks: {"v": jnp.zeros(4)})
    assert spec.difference is default_difference
    # explicit differences are untouched
    f = lambda c, p: c["v"] - p["v"]
    spec2 = IterSpec(map_fn=lambda s, d, g: None, reducer=None,
                     project=lambda sk: sk, num_state=4,
                     init_state=lambda dks: {"v": jnp.zeros(4)},
                     difference=f)
    assert spec2.difference is f


def test_session_lifecycle_errors():
    docs = _wc_corpus(n=8)
    spec, data = wc.make_job(docs, 60)
    sess = Session(spec)
    with pytest.raises(RuntimeError, match="before run"):
        sess.update(make_delta([0], {"w": jnp.zeros((1, 8), jnp.int32)}, [1]))
    with pytest.raises(RuntimeError, match="no result"):
        sess.result
    sess.run(data)
    with pytest.raises(RuntimeError, match="already executed"):
        sess.run(data)


def test_old_entry_points_do_not_warn():
    """The one-release deprecation window is over: the internal entry
    points are plain functions again (no shim, no DeprecationWarning)."""
    import warnings
    docs = _wc_corpus(n=8)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        from repro.core.engine import run_onestep
        run_onestep(wc.make_spec(60), wc.make_input(np.arange(8), docs))


def test_every_app_has_make_job():
    """The uniform app convention: make_job(...) -> (spec, data)."""
    from repro.apps import apriori, gimv, sssp
    from repro.core.engine import JobSpec as JS

    rng = np.random.default_rng(0)
    docs = rng.integers(0, 20, (6, 4)).astype(np.int32)
    tweets = rng.integers(0, 20, (6, 4)).astype(np.int32)
    pairs = apriori.candidate_pairs(tweets, 20, top=4)
    nbrs = pr.random_graph(8, 2, seed=0)
    wnbrs, w = sssp.random_weighted_graph(8, 2, seed=0)
    blocks = gimv.random_blocks(2, 4, seed=0)
    pts = rng.normal(0, 1, (9, 2)).astype(np.float32)

    jobs = [wc.make_job(docs, 20), apriori.make_job(tweets, pairs),
            pr.make_job(nbrs), sssp.make_job(wnbrs, w, src=0),
            kmeans.make_job(pts, pts[:2]),
            gimv.make_job(blocks, 2, 4, np.ones((2, 4), np.float32))]
    for spec, data in jobs:
        assert isinstance(spec, (JS, IterSpec))
        assert data.capacity > 0
        Session(spec)                    # every job is Session-constructible
