"""repro.stream end-to-end: async StreamSession refreshes match cold runs
bit-for-bit, the scheduler switches refresh modes at the configured
crossover, and MultiSessionServer keeps tenants isolated."""
import os
import queue

import numpy as np
import pytest

from repro.api import RunConfig, Session, StreamConfig
from repro.apps import pagerank as pr, wordcount as wc
from repro.stream import (
    DeltaRecord, FileTailSource, MultiSessionServer, RefreshScheduler,
    StreamSession,
)

BACKENDS = ("xla", "pallas")


# ---------------------------------------------------------------------------
# end-to-end: async micro-batched refreshes == cold run on the final input
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_wordcount_stream_bit_identical(backend):
    rng = np.random.default_rng(0)
    docs = rng.integers(0, 48, (32, 5)).astype(np.int32)
    spec, data, source = wc.make_stream(docs, 48, frac=0.1, seed=4,
                                        epochs=6)
    # small batches so the six source epochs arrive as several micro-batches
    ss = StreamSession(spec, data, source=source,
                       config=RunConfig(backend=backend, value_bytes=4),
                       stream=StreamConfig(max_batch_records=8,
                                           max_batch_delay=0.005,
                                           crossover=0.5))
    with ss:
        ss.drain(timeout=120)
    assert ss.metrics.batches >= 2
    assert ss.metrics.last_epoch == 5

    cold = Session(spec, RunConfig(backend=backend, value_bytes=4))
    cold.run(wc.make_input(np.arange(len(docs)), source.values["w"]))
    np.testing.assert_array_equal(ss.result["c"], cold.result["c"])
    # the maintained input mirror agrees with the source's dataset mirror
    np.testing.assert_array_equal(
        np.asarray(ss.mirror_kv().values["w"]), source.values["w"])


@pytest.mark.parametrize("backend", BACKENDS)
def test_pagerank_stream_incremental(backend):
    n = 96 if backend == "pallas" else 192
    nbrs = pr.random_graph(n, 4, seed=2, p_edge=0.5)
    spec, struct, source = pr.make_stream(nbrs, frac=0.02, seed=9, epochs=3)
    cfg = RunConfig(backend=backend, max_iters=150, tol=1e-7, value_bytes=4)
    ss = StreamSession(spec, struct, source=source, config=cfg,
                       stream=StreamConfig(max_batch_records=4,
                                           max_batch_delay=0.005,
                                           crossover=0.5))
    with ss:
        ss.drain(timeout=300)
    assert ss.metrics.refreshes.get("update", 0) >= 1

    cold = Session(spec, cfg)
    cold.run(pr.make_struct(source.values["nbrs"]))
    got, want = ss.result["r"], cold.result["r"]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-7)


def test_pagerank_forced_rerun_bit_identical():
    """crossover=0 makes every micro-batch a full rerun; a rerun on the
    maintained mirror is the same program as a cold run -> bit-identical."""
    nbrs = pr.random_graph(128, 4, seed=5, p_edge=0.5)
    spec, struct, source = pr.make_stream(nbrs, frac=0.05, seed=1, epochs=2)
    cfg = RunConfig(max_iters=120, tol=1e-7)
    ss = StreamSession(spec, struct, source=source, config=cfg,
                       stream=StreamConfig(policy="paper", crossover=0.0))
    with ss:
        ss.drain(timeout=300)
    assert ss.metrics.refreshes == {"rerun": ss.metrics.batches}

    cold = Session(spec, cfg)
    cold.run(pr.make_struct(source.values["nbrs"]))
    np.testing.assert_array_equal(ss.result["r"], cold.result["r"])


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def test_scheduler_switches_at_crossover():
    """Below the configured delta ratio: incremental update; above: full
    rerun — the Fig. 8 crossover as an online policy."""
    rng = np.random.default_rng(1)
    docs = rng.integers(0, 40, (40, 4)).astype(np.int32)
    spec, data = wc.make_job(docs, 40)
    ss = StreamSession(spec, data,
                       stream=StreamConfig(policy="paper", crossover=0.3,
                                           max_batch_delay=0.0))
    ss.start(background=False)
    mirror = docs.copy()

    def push_epoch(rows):
        new = rng.integers(0, 40, (len(rows), 4)).astype(np.int32)
        rid = np.repeat(np.asarray(rows, np.int32), 2)
        buf = np.empty((2 * len(rows), 4), np.int32)
        buf[0::2] = mirror[rows]
        buf[1::2] = new
        mirror[rows] = new
        ss.submit(rid, {"w": buf}, np.tile(np.int8([-1, 1]), len(rows)))
        ss.drain(timeout=60)

    push_epoch([3, 9])                      # 4 rows / 40 live = 0.1 < 0.3
    push_epoch(list(range(20)))             # 40 rows / 40 live = 1.0 > 0.3
    actions = [d.action for d in ss.scheduler.decisions]
    assert actions == ["update", "rerun"]
    assert ss.scheduler.decisions[0].delta_ratio < 0.3
    assert ss.scheduler.decisions[1].delta_ratio > 0.3
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, 40))


def test_scheduler_policies_unit():
    sch = RefreshScheduler(StreamConfig(policy="latency", crossover=0.25))
    # cold model falls back to the crossover prior
    assert sch.decide(1, 100).action == "update"
    assert sch.decide(50, 100).action == "rerun"
    # once both paths are measured, the cheaper predicted path wins
    sch.observe("update", 10, 0.010)        # 1 ms per delta row
    sch.observe("rerun", 50, 0.005)         # full recompute: 5 ms
    assert sch.decide(2, 1000).action == "update"    # 2ms < 5ms
    assert sch.decide(50, 1000).action == "rerun"    # 50ms > 5ms

    tp = RefreshScheduler(StreamConfig(policy="throughput", crossover=0.9,
                                       store_bloat=2.0))
    d = tp.decide(1, 1000, store_file_bytes=3000, store_live_bytes=1000)
    assert d.action == "rerun" and "bloat" in d.reason
    assert tp.decide(1, 1000, store_file_bytes=1500,
                     store_live_bytes=1000).action == "update"


# ---------------------------------------------------------------------------
# multi-tenant serving
# ---------------------------------------------------------------------------

def test_multi_session_server_isolation_and_budget():
    rng = np.random.default_rng(7)
    corpora = {name: rng.integers(0, 32, (24, 4)).astype(np.int32)
               for name in ("alice", "bob")}
    server = MultiSessionServer(store_budget_bytes=64 * 1024)
    cfg = StreamConfig(max_batch_delay=0.0, crossover=2.0)  # always update
    for name, docs in corpora.items():
        spec, data = wc.make_job(docs, 32)
        server.add(StreamSession(spec, data, name=name,
                                 config=RunConfig(onestep_path="mrbg",
                                                  value_bytes=4),
                                 stream=cfg))
    mirrors = {n: d.copy() for n, d in corpora.items()}
    with server:
        for i in range(6):                  # interleaved tenant updates
            name = ("alice", "bob")[i % 2]
            row = int(rng.integers(0, 24))
            new = rng.integers(0, 32, (4,)).astype(np.int32)
            server[name].submit(
                [row, row], {"w": np.stack([mirrors[name][row], new])},
                [-1, 1], epoch=i)
            mirrors[name][row] = new
        server.drain(timeout=120)

    for name in corpora:                    # no cross-tenant state bleed
        np.testing.assert_array_equal(server[name].result["c"],
                                      wc.oracle(mirrors[name], 32))
    stats = server.stats()
    assert set(stats["tenants"]) == {"alice", "bob"}
    assert not stats["over_budget"]
    assert stats["total_store_bytes"] <= 64 * 1024


def test_server_budget_forces_compaction():
    rng = np.random.default_rng(11)
    docs = rng.integers(0, 32, (24, 4)).astype(np.int32)
    spec, data = wc.make_job(docs, 32)
    ss = StreamSession(spec, data, name="fat",
                       config=RunConfig(onestep_path="mrbg", value_bytes=4),
                       stream=StreamConfig(max_batch_delay=0.0,
                                           crossover=2.0))
    server = MultiSessionServer(store_budget_bytes=1)   # impossible budget
    server.add(ss)
    mirror = docs.copy()
    for i in range(4):
        row = int(rng.integers(0, 24))
        new = rng.integers(0, 32, (4,)).astype(np.int32)
        ss.submit([row, row], {"w": np.stack([mirror[row], new])}, [-1, 1])
        mirror[row] = new
        server.sweep()
    server.drain(timeout=60)
    assert ss.metrics.compactions >= 1      # budget pressure compacted
    assert server.stats()["over_budget"]    # ...but 1 byte is unreachable
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, 32))


# ---------------------------------------------------------------------------
# ingestion mechanics
# ---------------------------------------------------------------------------

def test_submit_backpressure():
    docs = np.zeros((4, 3), np.int32)
    spec, data = wc.make_job(docs, 8)
    ss = StreamSession(spec, data,
                       stream=StreamConfig(queue_capacity=2))
    ss.submit([0], {"w": np.zeros((1, 3), np.int32)}, [1])
    ss.submit([1], {"w": np.zeros((1, 3), np.int32)}, [1])
    with pytest.raises(queue.Full):         # nobody drains: bounded queue
        ss.submit([2], {"w": np.zeros((1, 3), np.int32)}, [1],
                  timeout=0.05)


def test_worker_error_surfaces_on_drain():
    """An engine error must not silently kill the worker thread: drain()
    (and result) re-raise it with the original cause attached."""
    docs = np.zeros((4, 3), np.int32)
    spec, data = wc.make_job(docs, 8)
    ss = StreamSession(spec, data,
                       stream=StreamConfig(max_batch_delay=0.0))
    with ss:
        ss.drain(timeout=30)                 # let the initial run settle

        def boom(delta):
            raise RuntimeError("injected engine failure")
        ss.session.update = boom
        ss.session.rerun = boom
        ss.submit([0], {"w": np.zeros((1, 3), np.int32)}, [1])
        with pytest.raises(RuntimeError, match="worker.*died"):
            ss.drain(timeout=30)
        with pytest.raises(RuntimeError, match="worker.*died"):
            ss.result


def test_stop_start_cycle_keeps_processing():
    rng = np.random.default_rng(4)
    docs = rng.integers(0, 16, (8, 3)).astype(np.int32)
    spec, data = wc.make_job(docs, 16)
    ss = StreamSession(spec, data,
                       stream=StreamConfig(max_batch_delay=0.0))
    ss.start()
    ss.stop()
    ss.start()                              # must spawn a live worker again
    mirror = docs.copy()
    new = rng.integers(0, 16, (3,)).astype(np.int32)
    ss.submit([2, 2], {"w": np.stack([mirror[2], new])}, [-1, 1])
    mirror[2] = new
    ss.drain(timeout=60)
    ss.stop()
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, 16))


def test_tenant_drain_under_running_server():
    """drain() on a server-managed tenant must wait for the server's
    sweep thread instead of becoming a second, racing consumer."""
    rng = np.random.default_rng(6)
    docs = rng.integers(0, 16, (8, 3)).astype(np.int32)
    spec, data = wc.make_job(docs, 16)
    ss = StreamSession(spec, data, name="t",
                       stream=StreamConfig(max_batch_delay=0.0,
                                           crossover=2.0))
    with MultiSessionServer() as server:
        server.add(ss)
        mirror = docs.copy()
        new = rng.integers(0, 16, (3,)).astype(np.int32)
        ss.submit([1, 1], {"w": np.stack([mirror[1], new])}, [-1, 1])
        mirror[1] = new
        ss.drain(timeout=60)                # served by the server thread
        np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, 16))


def test_record_id_outside_mirror_rejected():
    """With growth disabled, a bad record id is refused at submit() time —
    before it can join a batch and kill the worker.  Negative ids are
    always refused."""
    docs = np.zeros((4, 3), np.int32)
    spec, data = wc.make_job(docs, 8)
    ss = StreamSession(spec, data,
                       stream=StreamConfig(max_batch_delay=0.0,
                                           grow_records=False))
    ss.start(background=False)
    with pytest.raises(ValueError, match="mirror capacity"):
        ss.submit([17], {"w": np.zeros((1, 3), np.int32)}, [1])
    with pytest.raises(ValueError, match="outside"):
        ss.submit([-1], {"w": np.zeros((1, 3), np.int32)}, [1])
    # max_records caps growth the same way even when growth is on
    ss2 = StreamSession(spec, data, name="capped",
                        stream=StreamConfig(max_batch_delay=0.0,
                                            max_records=10))
    ss2.start(background=False)
    with pytest.raises(ValueError, match="mirror capacity"):
        ss2.submit([10], {"w": np.zeros((1, 3), np.int32)}, [1])
    with pytest.raises(ValueError, match="outside"):
        ss2.submit([-3], {"w": np.zeros((1, 3), np.int32)}, [1])


def test_bad_record_keeps_stream_alive():
    """One rejected record must not drop the batch or the worker: later
    submissions still process and the result stays correct."""
    rng = np.random.default_rng(3)
    docs = rng.integers(0, 16, (8, 3)).astype(np.int32)
    spec, data = wc.make_job(docs, 16)
    ss = StreamSession(spec, data,
                       stream=StreamConfig(max_batch_delay=0.0,
                                           grow_records=False))
    with ss:
        with pytest.raises(ValueError, match="mirror capacity"):
            ss.submit([99], {"w": np.zeros((1, 3), np.int32)}, [1])
        mirror = docs.copy()
        new = rng.integers(0, 16, (3,)).astype(np.int32)
        ss.submit([5, 5], {"w": np.stack([mirror[5], new])}, [-1, 1])
        mirror[5] = new
        ss.drain(timeout=60)                 # worker is alive and consuming
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, 16))


def test_source_bad_record_rejected_stream_continues():
    """A polled source record with out-of-range ids is dropped (counted in
    rows_rejected); the stream keeps processing the records around it."""
    from repro.stream import QueueSource
    rng = np.random.default_rng(8)
    docs = rng.integers(0, 16, (8, 3)).astype(np.int32)
    spec, data = wc.make_job(docs, 16)
    mirror = docs.copy()
    new = rng.integers(0, 16, (3,)).astype(np.int32)
    src = QueueSource()
    src.push(DeltaRecord(record_ids=[42], sign=[1],
                         values={"w": np.zeros((1, 3), np.int32)}, epoch=0))
    src.push(DeltaRecord(record_ids=[2, 2], sign=[-1, 1],
                         values={"w": np.stack([mirror[2], new])}, epoch=1))
    mirror[2] = new
    src.seal()
    ss = StreamSession(spec, data, source=src,
                       stream=StreamConfig(max_batch_delay=0.0,
                                           grow_records=False))
    ss.start(background=False)
    ss.drain(timeout=60)
    assert ss.metrics.rows_rejected == 1
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, 16))


# ---------------------------------------------------------------------------
# dynamic input-mirror growth (streams inserting brand-new record ids)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_mirror_grows_for_new_record_ids(backend):
    """Streaming inserts past the seed capacity grow the mirror (and the
    engine's record structures) geometrically; results keep matching a
    cold run over the full grown input."""
    rng = np.random.default_rng(21)
    docs = rng.integers(0, 32, (6, 3)).astype(np.int32)
    spec, data = wc.make_job(docs, 32)
    seed_cap = int(np.asarray(data.keys).shape[0])
    ss = StreamSession(spec, data,
                       config=RunConfig(backend=backend, value_bytes=4),
                       stream=StreamConfig(max_batch_delay=0.0,
                                           crossover=2.0))
    ss.start(background=False)
    # brand-new record ids, including one far past the seed capacity
    inserts = {seed_cap: rng.integers(0, 32, (3,)).astype(np.int32),
               seed_cap + 7: rng.integers(0, 32, (3,)).astype(np.int32),
               4 * seed_cap + 3: rng.integers(0, 32, (3,)).astype(np.int32)}
    for rid, row in inserts.items():
        ss.submit([rid], {"w": row[None]}, [1])
        ss.drain(timeout=60)
    assert ss.grow_events >= 2              # geometric: few events, not 3
    cap = ss.mirror_kv().capacity
    assert cap >= 4 * seed_cap + 4 and (cap & (cap - 1)) == 0
    full = np.concatenate([docs] + [row[None] for row in inserts.values()])
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(full, 32))
    # updating a grown-in record keeps working
    new = rng.integers(0, 32, (3,)).astype(np.int32)
    old = inserts[seed_cap + 7]
    ss.submit([seed_cap + 7] * 2, {"w": np.stack([old, new])}, [-1, 1])
    ss.drain(timeout=60)
    full[len(docs) + 1] = new
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(full, 32))


def test_mirror_growth_iterative_driver():
    """Growth reaches the iterative driver's structure mirror + reverse
    index: a pagerank stream can add brand-new pages.  (The state space is
    declared larger than the seed graph — record growth extends records,
    not the DK space.)"""
    nbrs = pr.random_graph(24, 3, seed=5, p_edge=0.9)
    spec = pr.make_spec(64)                 # headroom for streamed vertices
    struct = pr.make_struct(nbrs)
    seed_cap = int(np.asarray(struct.keys).shape[0])
    cfg = RunConfig(max_iters=150, tol=1e-7, value_bytes=4)
    ss = StreamSession(spec, struct, config=cfg,
                       stream=StreamConfig(max_batch_delay=0.0,
                                           crossover=2.0))
    ss.start(background=False)
    # a new page pointing at pages 0..2 (record id past the seed capacity)
    new_row = np.zeros_like(np.asarray(struct.values["nbrs"])[0])
    new_row[:] = -1
    new_row[:3] = [0, 1, 2]
    ss.submit([seed_cap + 1], {"nbrs": new_row[None]}, [1])
    ss.drain(timeout=120)
    assert ss.grow_events == 1
    job = ss.session._driver.job
    assert job.capacity == ss.mirror_kv().capacity
    assert bool(job.struct_valid[seed_cap + 1])
    # the refreshed ranks match a cold converge over the grown structure
    grown = ss.mirror_kv()
    cold = Session(spec, cfg)
    cold.run(grown)
    np.testing.assert_allclose(ss.result["r"], cold.result["r"],
                               rtol=0, atol=5e-5)


def test_failed_refresh_rolls_back_mirror():
    """If the refresh raises, the input mirror must be rolled back so it
    keeps matching the state the engine actually computed (no silent
    mirror/engine divergence on a later rerun or snapshot)."""
    rng = np.random.default_rng(12)
    docs = rng.integers(0, 16, (8, 3)).astype(np.int32)
    spec, data = wc.make_job(docs, 16)
    ss = StreamSession(spec, data,
                       stream=StreamConfig(max_batch_delay=0.0,
                                           crossover=2.0))
    ss.start(background=False)
    mirror = docs.copy()

    real_update = ss.session.update

    def boom(delta):
        raise RuntimeError("injected refresh failure")
    ss.session.update = boom
    new = rng.integers(0, 16, (3,)).astype(np.int32)
    ss.submit([4, 4], {"w": np.stack([mirror[4], new])}, [-1, 1])
    with pytest.raises(RuntimeError, match="injected"):
        ss.step()
    # mirror still reflects exactly what result was computed from
    np.testing.assert_array_equal(
        np.asarray(ss.mirror_kv().values["w"]), mirror)
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, 16))

    # recovered engine: the next batch processes against consistent state
    ss.session.update = real_update
    ss.submit([4, 4], {"w": np.stack([mirror[4], new])}, [-1, 1])
    mirror[4] = new
    ss.drain(timeout=60)
    np.testing.assert_array_equal(
        np.asarray(ss.mirror_kv().values["w"]), mirror)
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, 16))


def test_adversarial_burst_coalesces():
    """Repeated-record update bursts inside one micro-batch must cancel in
    the coalescer: fewer engine rows than ingested rows, same result."""
    rng = np.random.default_rng(13)
    docs = rng.integers(0, 16, (8, 3)).astype(np.int32)
    spec, data = wc.make_job(docs, 16)
    ss = StreamSession(spec, data,
                       stream=StreamConfig(max_batch_delay=0.0,
                                           crossover=2.0))
    ss.start(background=False)
    mirror = docs.copy()
    # one record rewritten 4 times in a single batch: 8 rows in, 2 needed
    row, cur = 3, mirror[3].copy()
    rids, bufs, signs = [], [], []
    for _ in range(4):
        new = rng.integers(0, 16, (3,)).astype(np.int32)
        rids += [row, row]
        bufs += [cur, new]
        signs += [-1, 1]
        cur = new
    mirror[row] = cur
    ss.submit(rids, {"w": np.stack(bufs)}, signs)
    ss.drain(timeout=60)
    snap = ss.metrics.snapshot()
    assert snap["rows_in"] == 8
    assert snap["rows_engine"] == 2          # first '-', last '+'
    assert snap["coalesce_savings"] > 0
    np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, 16))


def test_scheduler_excludes_compile_tainted_observations():
    """A one-off compile-dominated first batch must not flip the online
    cost model's update-vs-rerun decision."""
    sch = RefreshScheduler(StreamConfig(policy="latency", crossover=0.25))
    sch.observe("update", 10, 0.010)         # steady: 1 ms per delta row
    sch.observe("rerun", 50, 0.005)          # steady rerun: 5 ms
    assert sch.decide(2, 1000).action == "update"
    # a cold-bucket batch: 5 s wall-clock, almost all of it XLA compile
    sch.observe("update", 10, 5.0, compiled=True)
    assert sch.compile_skips == 1
    assert sch.decide(2, 1000).action == "update"    # model unpolluted
    # the same observation folded in would have flipped the decision
    bad = RefreshScheduler(StreamConfig(policy="latency", crossover=0.25))
    bad.observe("update", 10, 0.010)
    bad.observe("rerun", 50, 0.005)
    bad.observe("update", 10, 5.0)
    assert bad.decide(2, 1000).action == "rerun"


def test_file_tail_source_roundtrip_and_rewind(tmp_path):
    path = os.path.join(tmp_path, "deltas.jsonl")
    recs = [DeltaRecord(record_ids=[i, i],
                        values={"nbrs": np.full((2, 3), i, np.int32)},
                        sign=[-1, 1], timestamp=float(i), epoch=i)
            for i in range(3)]
    FileTailSource.write(path, recs, append=False)

    src = FileTailSource(path, dtypes={"nbrs": "int32"})
    got = src.poll(max_rows=100)
    assert [r.epoch for r in got] == [0, 1, 2]
    assert src.exhausted and src.watermark == 2
    np.testing.assert_array_equal(got[1].values["nbrs"],
                                  np.full((2, 3), 1, np.int32))
    assert got[1].values["nbrs"].dtype == np.int32

    # tail: appended records appear on the next poll
    FileTailSource.write(path, [DeltaRecord(
        record_ids=[9, 9], values={"nbrs": np.full((2, 3), 9, np.int32)},
        sign=[-1, 1], epoch=3)])
    more = src.poll(max_rows=100)
    assert [r.epoch for r in more] == [3]

    # recovery: rewind past a snapshot watermark replays only the suffix
    src.rewind(epoch=1)
    replay = src.poll(max_rows=100)
    assert [r.epoch for r in replay] == [2, 3]


def test_snapshot_carries_stream_watermark(tmp_path):
    rng = np.random.default_rng(2)
    docs = rng.integers(0, 24, (12, 4)).astype(np.int32)
    spec, data, source = wc.make_stream(docs, 24, frac=0.2, seed=0,
                                        epochs=3)
    ss = StreamSession(spec, data, source=source,
                       stream=StreamConfig(max_batch_records=4,
                                           max_batch_delay=0.0))
    ss.start(background=False)
    ss.drain(timeout=60)
    ss.snapshot(str(tmp_path))

    import json
    meta = json.loads((tmp_path / "stream.json").read_text())
    assert meta["watermark"] == 2 and meta["name"] == "session"
    restored = Session.restore(spec, str(tmp_path))
    np.testing.assert_array_equal(restored.result["c"], ss.result["c"])
