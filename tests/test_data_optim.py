"""Data pipeline determinism / delta streams; optimizer behavior."""
import numpy as np
import jax
import jax.numpy as jnp
from _hyp import given, settings, st  # hypothesis, or seeded fallback

from repro.data import DeltaStream, LMDataConfig, lm_batch_at_step, \
    synthetic_tokens
from repro.optim import AdamWConfig, adamw_init, adamw_update, \
    cosine_schedule, global_norm


class TestPipeline:
    def test_deterministic_and_restartable(self):
        cfg = LMDataConfig(vocab=1000, seq_len=64, global_batch=4, seed=7)
        a = lm_batch_at_step(cfg, 12)
        b = lm_batch_at_step(cfg, 12)
        np.testing.assert_array_equal(a["inputs"], b["inputs"])
        c = lm_batch_at_step(cfg, 13)
        assert not np.array_equal(a["inputs"], c["inputs"])

    def test_shard_independence(self):
        """Any slice of the stream can be generated standalone (elastic)."""
        toks = synthetic_tokens(0, 1000, 500, seed=3)
        part = synthetic_tokens(400, 100, 500, seed=3)
        np.testing.assert_array_equal(toks[400:500], part)

    def test_targets_shifted(self):
        cfg = LMDataConfig(vocab=1000, seq_len=32, global_batch=2, seed=0)
        b = lm_batch_at_step(cfg, 0)
        np.testing.assert_array_equal(b["inputs"][:, 1:], b["targets"][:, :-1])

    def test_delta_stream_format(self):
        vals = {"x": np.arange(50, dtype=np.int32).reshape(50, 1)}
        ds = DeltaStream(vals, frac=0.2, seed=1)
        rid, dvals, sign = ds.delta()
        assert rid.shape[0] == 20 and sign.shape[0] == 20
        np.testing.assert_array_equal(sign[0::2], -1)
        np.testing.assert_array_equal(sign[1::2], 1)
        # '-' rows carry the OLD values
        old = np.arange(50, dtype=np.int32).reshape(50, 1)
        np.testing.assert_array_equal(dvals["x"][0::2], old[rid[0::2]])


class TestAdamW:
    def _setup(self):
        params = {"w": jnp.ones((4, 4), jnp.float32),
                  "b": jnp.zeros(4, jnp.float32)}
        cfg = AdamWConfig(lr=1e-2, warmup=0, total_steps=100,
                          weight_decay=0.0)
        return params, adamw_init(params, cfg), cfg

    def test_descends_quadratic(self):
        params, opt, cfg = self._setup()
        loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1) ** 2)
        l0 = float(loss(params))
        for _ in range(60):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw_update(g, opt, params, cfg)
        assert float(loss(params)) < l0 * 0.5

    def test_clipping(self):
        params, opt, cfg = self._setup()
        g = {"w": jnp.full((4, 4), 1e6, jnp.float32),
             "b": jnp.zeros(4, jnp.float32)}
        p2, opt, info = adamw_update(g, opt, params, cfg)
        assert float(info["grad_norm"]) > 1e6
        delta = np.abs(np.asarray(p2["w"]) - 1.0).max()
        assert delta < 0.1     # clip kept the step bounded

    @given(st.integers(0, 10000))
    @settings(max_examples=30, deadline=None)
    def test_schedule_bounded(self, step):
        cfg = AdamWConfig(lr=3e-4, warmup=100, total_steps=10000)
        lr = float(cosine_schedule(cfg, jnp.int32(step)))
        assert 0.0 <= lr <= cfg.lr + 1e-12

    def test_global_norm(self):
        t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
        assert abs(float(global_norm(t)) - 5.0) < 1e-6
