"""Distributed fine-grain refresh: per-shard MRBG slices + delta exchange.

The contract under test is *bit-for-bit* parity: a meshed ``Session`` must
produce exactly the single-device result — on the initial converge, on
every ``update()``, and through CPC filtering and the §5.2 fallback — not
merely agree to a tolerance.  That only holds because the distributed step
sorts received edges by (K2, MK) before reducing, so per-key float
accumulation order matches the single-device shuffle.

Multi-device tests need >1 XLA host device, so they run in subprocesses
with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the flag must
precede jax init, which already happened in the pytest process).
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
BACKENDS = ("xla", "pallas")


def _run(script: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


PRELUDE = """
import numpy as np, jax
from jax.sharding import Mesh
from repro.api import Session, RunConfig, MeshConfig, make_delta
mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
"""

WC_PRELUDE = PRELUDE + """
from repro.apps import wordcount as wc
VOCAB, L = 32, 4
rng = np.random.default_rng(7)
docs = rng.integers(0, VOCAB, (64, L)).astype(np.int32)
spec, data = wc.make_job(docs, VOCAB)

def doc_delta(mirror, n_pairs):
    rows = rng.choice(len(mirror), size=n_pairs, replace=False)
    new = rng.integers(0, VOCAB, (n_pairs, L)).astype(np.int32)
    rid = np.repeat(rows.astype(np.int32), 2)
    buf = np.empty((2 * n_pairs, L), np.int32)
    buf[0::2] = mirror[rows]; buf[1::2] = new
    mirror[rows] = new
    return make_delta(rid, {"w": buf}, np.tile(np.int8([-1, 1]), n_pairs))
"""

PR_PRELUDE = PRELUDE + """
from repro.apps import pagerank as pr
S, F = 256, 5
nbrs = pr.random_graph(S, F, seed=11, p_edge=0.5)
spec, struct = pr.make_job(nbrs)

def graph_delta(mirror, n_rows):
    rows = rng.choice(S, n_rows, replace=False)
    new = np.where(rng.random((n_rows, F)) < 0.5,
                   rng.integers(0, S, (n_rows, F)), -1).astype(np.int32)
    rid = np.repeat(rows.astype(np.int32), 2)
    buf = np.empty((2 * n_rows, F), np.int32)
    buf[0::2] = mirror[rows]; buf[1::2] = new
    mirror[rows] = new
    return make_delta(rid, {"nbrs": buf},
                      np.tile(np.int8([-1, 1]), n_rows))
rng = np.random.default_rng(5)
"""


# ---------------------------------------------------------------------------
# bit-for-bit parity with the single-device engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_onestep_update_parity_bitwise(backend):
    """Wordcount run + fine updates on an 8-shard mesh == single device,
    exactly (integer counts leave no float slack to hide behind)."""
    _run(WC_PRELUDE + f"""
cfg = dict(backend="{backend}", value_bytes=4)
ref = Session(spec, RunConfig(**cfg)); ref.run(data)
dist = Session(spec, RunConfig(mesh=MeshConfig(mesh), **cfg))
rep = dist.run(data)
assert rep.mode == "distributed", rep.mode
np.testing.assert_array_equal(ref.result["c"], dist.result["c"])

mirror = docs.copy()
for pairs in (4, 12, 4):
    d = doc_delta(mirror, pairs)
    r1 = ref.update(d); r2 = dist.update(d)
    assert r2.mode == "distributed-incr", r2.mode
    np.testing.assert_array_equal(ref.result["c"], dist.result["c"])
    assert r2.shuffle.edges_exchanged > 0
    assert r2.shuffle.bytes_moved == r2.shuffle.edges_exchanged * 14
np.testing.assert_array_equal(dist.result["c"], wc.oracle(mirror, VOCAB))
print("OK")
""")


@pytest.mark.parametrize("backend", BACKENDS)
def test_iterative_cpc_update_parity_bitwise(backend):
    """Pagerank fine refresh (CPC filtering, no fallback) on the mesh is
    bit-for-bit the single-device i2 refresh, epoch after epoch.

    The xla backend is held to exact bits.  The pallas reduce kernels
    accumulate in buffer-shaped blocks, so the sharded layout shifts the
    float reduction tree by 1-2 ulp — there parity is held to one float32
    ulp of the converged rank mass instead.
    """
    exact = backend == "xla"
    _run(PR_PRELUDE + f"""
kw = dict(backend="{backend}", max_iters=60, tol=1e-7,
          cpc_threshold=5e-4, pdelta_threshold=1.0)
check = (np.testing.assert_array_equal if {exact!r}
         else lambda a, b: np.testing.assert_allclose(a, b, atol=5e-7))
ref = Session(spec, RunConfig(**kw)); ref.run(struct)
dist = Session(spec, RunConfig(mesh=MeshConfig(mesh, shuffle_cap=512), **kw))
dist.run(struct)
check(ref.result["r"], dist.result["r"])

mirror = nbrs.copy()
for _ in range(3):
    d = graph_delta(mirror, 4)
    r1 = ref.update(d); r2 = dist.update(d)
    assert (r1.mode, r2.mode) == ("i2", "distributed-i2"), (r1.mode, r2.mode)
    assert r1.iters == r2.iters
    check(ref.result["r"], dist.result["r"])
print("OK")
""")


def test_fallback_parity_bitwise():
    """When P_delta trips the §5.2 auto MRBG-off, the meshed session must
    fall back exactly like the single-device engine (same mode, same
    bits) and recover fine refresh after the re-seed."""
    _run(PR_PRELUDE + """
kw = dict(backend="xla", max_iters=60, tol=1e-7,
          cpc_threshold=5e-4, pdelta_threshold=0.05)
ref = Session(spec, RunConfig(**kw)); ref.run(struct)
dist = Session(spec, RunConfig(mesh=MeshConfig(mesh, shuffle_cap=512), **kw))
dist.run(struct)

mirror = nbrs.copy()
d = graph_delta(mirror, 32)            # big delta: blows past P_delta
r1 = ref.update(d); r2 = dist.update(d)
assert r1.mode == "iterMR-fallback", r1.mode
assert r2.mode == "distributed-warm", r2.mode
np.testing.assert_array_equal(ref.result["r"], dist.result["r"])
# the warm converge re-seeded the per-shard slices (the §5.2 recovery):
# the next update starts fine again, and whatever path the engine then
# picks must correspond across layouts, bit for bit
assert dist._driver.mrbg_on and dist._driver.stores
d = graph_delta(mirror, 2)
r1 = ref.update(d); r2 = dist.update(d)
mode_map = {"i2": "distributed-i2", "iterMR-fallback": "distributed-warm"}
assert r2.mode == mode_map[r1.mode], (r1.mode, r2.mode)
np.testing.assert_array_equal(ref.result["r"], dist.result["r"])
print("OK")
""")


# ---------------------------------------------------------------------------
# retrace discipline: the delta-exchange ladder compiles once per bucket
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_delta_exchange_zero_steady_retrace(backend):
    """Same bar as tests/test_stream_retrace.py: once a delta bucket is
    warm, refreshes of any size inside it trace nothing new."""
    _run(WC_PRELUDE + f"""
from repro.kernels import jitcache
dist = Session(spec, RunConfig(mesh=MeshConfig(mesh),
                               backend="{backend}", value_bytes=4))
dist.run(data)
mirror = docs.copy()
for pairs in (4, 12, 24):              # warm the row/edge buckets
    dist.update(doc_delta(mirror, pairs))
gen0 = jitcache.generation()
for pairs in (3, 10, 20):              # same buckets, different sizes
    dist.update(doc_delta(mirror, pairs))
assert jitcache.generation() == gen0, (
    f"retraced within a warm bucket: {{jitcache.trace_counts()}}")
np.testing.assert_array_equal(dist.result["c"], wc.oracle(mirror, VOCAB))
print("OK")
""")


def test_meshed_stream_session_prewarm():
    """A StreamSession over a meshed Session: prewarm covers the
    delta-exchange ladder, so the first real batch traces nothing."""
    _run(WC_PRELUDE + """
from repro.kernels import jitcache
from repro.api import StreamConfig
from repro.stream import StreamSession
ss = StreamSession(spec, data,
                   config=RunConfig(mesh=MeshConfig(mesh), backend="xla",
                                    value_bytes=4),
                   stream=StreamConfig(max_batch_delay=0.0, crossover=2.0,
                                       max_batch_records=64, prewarm=True))
ss.start(background=False)
mirror = docs.copy()
gen0 = jitcache.generation()
d = doc_delta(mirror, 32)              # 64 rows: the full bucket
ss.submit(np.asarray(d.record_ids), {"w": np.asarray(d.values["w"])},
          np.asarray(d.sign))
assert ss.step()
assert jitcache.generation() == gen0, (
    f"first real batch retraced despite prewarm: "
    f"{jitcache.trace_counts()}")
assert ss.metrics.retrace_batches == 0
np.testing.assert_array_equal(ss.result["c"], wc.oracle(mirror, VOCAB))
print("OK")
""")


# ---------------------------------------------------------------------------
# failure atomicity + capacity regrow
# ---------------------------------------------------------------------------

def test_update_failure_rolls_back():
    """A refresh that dies mid-flight (here: injected into the shard merge
    and into the warm converge) must leave the session at its pre-update
    state, and a retry must succeed."""
    _run(PR_PRELUDE + """
import repro.core.distributed as dist_mod
kw = dict(backend="xla", max_iters=60, tol=1e-7,
          cpc_threshold=5e-4, pdelta_threshold=1.0)
dist = Session(spec, RunConfig(mesh=MeshConfig(mesh, shuffle_cap=512), **kw))
dist.run(struct)
before = dist.result["r"].copy()

# fine path: die after some shards already merged/patched
mirror = nbrs.copy()
d = graph_delta(mirror, 4)
orig_merge = dist_mod.merge_shard_delta
calls = []
def bomb(*a, **k):
    if len(calls) >= 2:
        raise RuntimeError("injected merge failure")
    calls.append(1)
    return orig_merge(*a, **k)
dist_mod.merge_shard_delta = bomb
try:
    dist.update(d)
    raise SystemExit("expected injected failure")
except RuntimeError:
    pass
finally:
    dist_mod.merge_shard_delta = orig_merge
np.testing.assert_array_equal(dist.result["r"], before)

# warm path: converge itself dies
warm = Session(spec, RunConfig(
    mesh=MeshConfig(mesh, shuffle_cap=512, refresh="warm"), **kw))
warm.run(struct)
wbefore = warm.result["r"].copy()
orig_run = dist_mod.run_distributed
def boom(*a, **k):
    raise RuntimeError("shuffle capacity overflow: injected")
dist_mod.run_distributed = boom
try:
    warm.update(d)
    raise SystemExit("expected injected overflow")
except RuntimeError:
    pass
finally:
    dist_mod.run_distributed = orig_run
np.testing.assert_array_equal(warm.result["r"], wbefore)
rep = warm.update(d)                   # retry: same delta, now succeeds
assert rep.mode == "distributed-warm", rep.mode
print("OK")
""")


def test_converge_auto_regrow_reported():
    """An undersized MeshConfig.shuffle_cap self-heals up the bucket
    ladder and reports it, instead of raising."""
    _run(PR_PRELUDE + """
dist = Session(spec, RunConfig(mesh=MeshConfig(mesh, shuffle_cap=2),
                               backend="xla", max_iters=60, tol=1e-7))
rep = dist.run(struct)
assert rep.shuffle.regrows >= 1, rep.shuffle.regrows
assert rep.shuffle.shuffle_cap > 2
ref = Session(spec, RunConfig(backend="xla", max_iters=60, tol=1e-7))
ref.run(struct)
np.testing.assert_array_equal(ref.result["r"], dist.result["r"])
print("OK")
""")


# ---------------------------------------------------------------------------
# MeshConfig surface (no devices needed)
# ---------------------------------------------------------------------------

class _FakeMesh:
    shape = {"pod": 2, "data": 4}


def test_meshconfig_validation():
    from repro.api import MeshConfig, RunConfig

    mc = MeshConfig(_FakeMesh(), axis="data", pod_axis="pod")
    assert mc.n_parts == 8
    with pytest.raises(ValueError, match="axis"):
        MeshConfig(_FakeMesh(), axis="model")
    with pytest.raises(ValueError, match="pod axis"):
        MeshConfig(_FakeMesh(), pod_axis="rack")
    with pytest.raises(ValueError, match="shuffle_cap"):
        MeshConfig(_FakeMesh(), axis="data", shuffle_cap=0)
    with pytest.raises(ValueError, match="refresh"):
        MeshConfig(_FakeMesh(), axis="data", refresh="lukewarm")
    with pytest.raises(ValueError, match="mesh"):
        MeshConfig(object())


def test_flat_mesh_kwargs_removed():
    # the PR-7 deprecation window is over: RunConfig only takes a
    # MeshConfig, and the flat knobs are gone entirely
    from repro.api import MeshConfig, RunConfig

    with pytest.raises(TypeError, match="MeshConfig"):
        RunConfig(mesh=_FakeMesh())
    for bad in ({"mesh_axis": "data"}, {"pod_axis": "pod"},
                {"shuffle_cap": 128}, {"partition_cap": 64}):
        with pytest.raises(TypeError):
            RunConfig(**bad)

    mc = MeshConfig(_FakeMesh(), axis="data", pod_axis="pod",
                    shuffle_cap=128, partition_cap=64)
    cfg = RunConfig(mesh=mc)
    assert cfg.mesh is mc
    assert not hasattr(cfg, "shuffle_cap") and not hasattr(cfg, "mesh_axis")
    cfg2 = cfg.replace(tol=1e-5)
    assert cfg2.mesh is mc
