"""Per-architecture smoke tests: reduced same-family config, one forward +
train step on CPU, output shapes + finiteness; decode parity with prefill."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.configs as C
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import lm
from repro.models.config import smoke_config
from repro.optim import AdamWConfig, adamw_init


def _batch(cfg, rng, b=2, s=32):
    if cfg.embed_inputs:
        inputs = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    else:
        inputs = jnp.asarray(rng.normal(0, 1, (b, s, cfg.d_model)),
                             jnp.bfloat16)
    return {"inputs": inputs,
            "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32),
            "mask": jnp.ones((b, s), bool)}


@pytest.mark.parametrize("arch", C.ARCHS)
def test_train_step_smoke(arch):
    cfg = smoke_config(C.get(arch))
    rng = np.random.default_rng(0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    opt = adamw_init(params, AdamWConfig())
    step = jax.jit(make_train_step(cfg, AdamWConfig()))
    p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) > 0
    # params changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", [a for a in C.ARCHS
                                  if C.get(a).family != "encoder"])
def test_decode_matches_prefill(arch):
    """Greedy decode logits must match teacher-forced forward logits.

    Run in fp32: this asserts *algorithmic* parity of the cache paths.  In
    bf16 the two paths round differently, which can flip discrete top-k
    routing decisions in MoE blocks (a discrete-boundary effect, not a bug).
    """
    cfg = smoke_config(C.get(arch)).replace(param_dtype="float32",
                                            compute_dtype="float32")
    rng = np.random.default_rng(1)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)

    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    hidden, _ = lm.forward(cfg, params, toks, pos)
    from repro.models.common import softcap
    full_logits = np.asarray(softcap(
        lm.logits_fn(cfg, params, hidden).astype(jnp.float32),
        cfg.logit_softcap))

    caches = lm.init_caches(cfg, b, 32)
    serve = jax.jit(make_serve_step(cfg))
    scale = max(1.0, float(np.abs(full_logits).max()))
    errs = []
    for t in range(s):
        lg, caches = serve(params, caches, toks[:, t:t + 1])
        errs.append(np.abs(np.asarray(lg) - full_logits[:, t]).max() / scale)
    # fp32 algorithmic parity: tight bound (recurrent scans accumulate a
    # little more round-off than pure attention)
    tol = 1e-3 if cfg.family in ("hybrid", "xlstm") else 2e-4
    assert max(errs) < tol, (arch, errs)


def test_encoder_masked_lm():
    cfg = smoke_config(C.get("hubert_xlarge"))
    rng = np.random.default_rng(2)
    params = lm.init_params(cfg, jax.random.PRNGKey(2))
    batch = _batch(cfg, rng)
    batch["mask"] = jnp.asarray(rng.random((2, 32)) < 0.3)
    loss = lm.lm_loss(cfg, params, batch)
    assert np.isfinite(float(loss))


def test_all_cells_enumerated():
    cells = C.all_cells()
    # 10 archs x 4 shapes = 40 minus documented skips:
    #   hubert: no decode_32k/long_500k (-2)
    #   quadratic-attn archs skip long_500k (-7: all but rg-2b and xlstm)
    # = 20 train/prefill + 9 decode_32k + 2 long_500k
    assert len(cells) == 31
    names = {a for a, _ in cells}
    assert len(names) == 10
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"recurrentgemma_2b", "xlstm_125m"}
