"""Gradient compression: quantization error bounds, error feedback
convergence, and the distributed psum path (subprocess, 8 devices)."""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
from _hyp import given, settings, st  # hypothesis, or seeded fallback

from repro.optim.compress import (dequantize_int8, init_error_buffers,
                                  quantize_int8, wire_bytes)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_quantize_bounded_error(seed):
    rng = np.random.default_rng(seed % 2**31)
    x = jnp.asarray(rng.normal(0, rng.uniform(1e-3, 10), 256), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) / 2 + 1e-7    # half-ulp rounding bound


def test_error_feedback_unbiased_over_time():
    """Accumulated EF residual keeps the long-run average exact."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
    err = jnp.zeros(64, jnp.float32)
    sent = jnp.zeros(64, jnp.float32)
    for _ in range(200):
        xe = g_true + err
        q, s = quantize_int8(xe)
        deq = dequantize_int8(q, s)
        err = xe - deq
        sent = sent + deq
    avg = np.asarray(sent) / 200
    np.testing.assert_allclose(avg, np.asarray(g_true), atol=1e-3)


def test_wire_bytes():
    grads = {"a": jnp.zeros((100, 100)), "b": jnp.zeros(77)}
    full, comp = wire_bytes(grads)
    assert full == 4 * 10077
    assert comp < full / 3.9


def test_distributed_compressed_psum():
    script = """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim.compress import compressed_tree_psum, init_error_buffers

mesh = Mesh(np.array(jax.devices()[:8]), ("data",))
rng = np.random.default_rng(0)
# per-replica gradient shards [8, ...]
g = {"w": jnp.asarray(rng.normal(0, 1, (8, 16, 4)), jnp.float32),
     "b": jnp.asarray(rng.normal(0, 1, (8, 5)), jnp.float32)}
err = {"w": jnp.zeros((8, 16, 4), jnp.bfloat16),
       "b": jnp.zeros((8, 5), jnp.bfloat16)}

def f(gl, el):
    gl = jax.tree.map(lambda a: a[0], gl)
    el = jax.tree.map(lambda a: a[0], el)
    rg, re = compressed_tree_psum(gl, "data", el)
    return (jax.tree.map(lambda a: a[None], rg),
            jax.tree.map(lambda a: a[None], re))

fm = shard_map(f, mesh=mesh, in_specs=(P("data"), P("data")),
               out_specs=(P("data"), P("data")), check_rep=False)
rg, re = jax.jit(fm)(g, err)
want = {k: np.asarray(v).mean(axis=0) for k, v in g.items()}
for k in want:
    got = np.asarray(rg[k])[0]
    rel = np.abs(got - want[k]).max() / max(np.abs(want[k]).max(), 1e-9)
    assert rel < 0.05, (k, rel)     # int8 single-round error bound
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=600)
    assert r.returncode == 0, f"stdout:{r.stdout}\nstderr:{r.stderr}"
