"""repro.serve: SLO scheduling units, batched cross-tenant refresh parity,
admission shedding, store spill/reload, budget enforcement order, tenant
churn, and the MultiSessionServer compatibility shim."""
import threading

import numpy as np
import pytest

from repro.api import RunConfig, Session, StreamConfig
from repro.apps import wordcount as wc
from repro.serve import (
    AdmissionController, ServeTier, SLOClass, deadline_slack,
    order_by_priority,
)
from repro.serve import loadgen
from repro.stream import StreamSession

BACKENDS = ("xla", "pallas")


def _fleet(tier, n, backend, *, seed=0, vocab=32, n_docs=6, **kw):
    return loadgen.make_fleet(tier, n, backend=backend, seed=seed,
                              vocab=vocab, n_docs=n_docs, **kw)


def _apply_rounds(tier, mirrors, rounds, *, seed=1, vocab=32):
    """Scripted update stream: deterministic across tiers with equal
    seeds.  Synchronous (no scheduler thread): submit one round, drain."""
    rng = np.random.default_rng(seed)
    for _ in range(rounds):
        for name in mirrors:
            loadgen.submit_update(tier, mirrors, name, rng, vocab)
        tier.drain(timeout=120)


# ---------------------------------------------------------------------------
# SLO scheduling units
# ---------------------------------------------------------------------------

def test_slo_class_units():
    lat = SLOClass.latency(target_p95_ms=50.0)
    thr = SLOClass.throughput()
    be = SLOClass.best_effort()
    assert lat.rank < thr.rank < be.rank
    assert lat.deadline_ms == 50.0          # defaults to the p95 target
    assert not lat.sheddable and not thr.sheddable and be.sheddable
    with pytest.raises(ValueError):
        SLOClass(kind="gold")
    with pytest.raises(ValueError):
        SLOClass(deadline_ms=-1.0)


def test_order_by_priority_ranks_then_slack():
    tier = ServeTier(batch_refresh=False)
    mirrors = _fleet(tier, 3, "xla", seed=3)
    names = list(mirrors)
    tier.handle(names[0]).slo = SLOClass.best_effort()
    tier.handle(names[1]).slo = SLOClass.latency(target_p95_ms=20.0)
    tier.handle(names[2]).slo = SLOClass.throughput()
    ordered = order_by_priority(list(tier.handles.values()))
    assert [h.name for h in ordered] == [names[1], names[2], names[0]]
    # slack of an idle tenant is bounded by its deadline
    assert deadline_slack(tier.handle(names[1])) <= 0.020 + 1e-9


# ---------------------------------------------------------------------------
# batched cross-tenant refresh: bit-for-bit vs per-tenant and cold runs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_matches_solo_and_cold_bit_identical(backend):
    results = {}
    for mode in ("batched", "solo"):
        tier = ServeTier(batch_refresh=(mode == "batched"))
        mirrors = _fleet(tier, 4, backend, seed=11)
        _apply_rounds(tier, mirrors, rounds=3, seed=12)
        results[mode] = {n: np.asarray(tier[n].result["c"])
                        for n in mirrors}
        if mode == "batched":
            stats = tier.stats()
            assert stats["batched_launches"] >= 1
            assert stats["batched_refreshes"] >= 4
            final_docs = {n: m.copy() for n, m in mirrors.items()}
    for name, got in results["batched"].items():
        np.testing.assert_array_equal(got, results["solo"][name])
        cold = Session(wc.make_spec(32),
                       RunConfig(backend=backend, value_bytes=4))
        docs = final_docs[name]
        cold.run(wc.make_input(np.arange(len(docs)), docs))
        np.testing.assert_array_equal(got, np.asarray(cold.result["c"]))


def test_one_launch_per_compatible_group():
    tier = ServeTier()
    mirrors = _fleet(tier, 5, "xla", seed=21)
    rng = np.random.default_rng(22)
    for name in mirrors:
        loadgen.submit_update(tier, mirrors, name, rng, 32)
    tier.drain(timeout=120)     # synchronous: all five due on one sweep
    stats = tier.stats()
    assert stats["batched_launches"] == 1
    assert stats["batched_refreshes"] == 5


def test_group_partitions_batching():
    tier = ServeTier()
    mirrors = _fleet(tier, 4, "xla", seed=31,
                     group_of=lambda i: "a" if i < 2 else "b")
    rng = np.random.default_rng(32)
    for name in mirrors:
        loadgen.submit_update(tier, mirrors, name, rng, 32)
    tier.drain(timeout=120)
    assert tier.stats()["batched_launches"] == 2


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

def test_admission_sheds_best_effort_only():
    tier = ServeTier(admission=AdmissionController(max_backlog_seconds=1e-9))
    mirrors = _fleet(tier, 2, "xla", seed=41,
                     slo_of=lambda i: (SLOClass.latency(target_p95_ms=1e4)
                                       if i == 0 else SLOClass.best_effort()))
    lat, be = list(mirrors)
    # two clean rounds so the best-effort tenant has an update cost sample
    # (these may themselves shed: any queued row overflows a 1ns budget)
    _apply_rounds(tier, mirrors, rounds=2, seed=42)
    h = tier.handle(be)
    shed0 = h.shed_submits
    rng = np.random.default_rng(43)
    assert loadgen.submit_update(tier, mirrors, be, rng, 32)   # empty tier
    # queued rows now make the (tiny) backlog budget overflow
    assert not loadgen.submit_update(tier, mirrors, be, rng, 32)
    assert loadgen.submit_update(tier, mirrors, lat, rng, 32)  # never shed
    assert h.shed_submits == shed0 + 1 and h.shed_rows == 2 * (shed0 + 1)
    assert tier.stats()["admission"]["shed_submits"] == shed0 + 1
    tier.drain(timeout=120)
    # queue drained: best-effort admits again
    assert loadgen.submit_update(tier, mirrors, be, rng, 32)
    tier.drain(timeout=120)


def test_admission_prices_fleet_without_samples_at_zero():
    ctl = AdmissionController(max_backlog_seconds=0.5)
    tier = ServeTier(admission=ctl)
    mirrors = _fleet(tier, 2, "xla", seed=51)
    rng = np.random.default_rng(52)
    # no clean update sample yet: the seeded rerun estimate (which holds
    # cold-compile seconds) must not count, so everything is admitted
    for name in mirrors:
        assert loadgen.submit_update(tier, mirrors, name, rng, 32)
    assert ctl.backlog_seconds(tier.handles.values()) == 0.0
    tier.drain(timeout=120)


# ---------------------------------------------------------------------------
# spill / reload
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_spill_reload_bit_identical(backend, tmp_path):
    results = {}
    for mode in ("spilled", "resident"):
        tier = ServeTier(spill_dir=tmp_path / mode)
        mirrors = _fleet(tier, 2, backend, seed=61)
        cold_name, hot_name = list(mirrors)
        _apply_rounds(tier, mirrors, rounds=2, seed=62)
        if mode == "spilled":
            h = tier.handle(cold_name)
            freed = tier.spill.spill(h)
            assert freed > 0 and h.spilled
            assert tier[cold_name].store_bytes() == 0
            assert list((tmp_path / mode).glob("*.npz"))
        # the spilled tenant's next delta transparently reloads its store
        _apply_rounds(tier, mirrors, rounds=1, seed=63)
        if mode == "spilled":
            assert not tier.handle(cold_name).spilled
            assert not list((tmp_path / mode).glob("*.npz"))
        results[mode] = {n: np.asarray(tier[n].result["c"])
                        for n in mirrors}
    for name in results["spilled"]:
        np.testing.assert_array_equal(results["spilled"][name],
                                      results["resident"][name])


def test_remove_reloads_spilled_tenant(tmp_path):
    tier = ServeTier(spill_dir=tmp_path)
    mirrors = _fleet(tier, 1, "xla", seed=71)
    (name,) = mirrors
    _apply_rounds(tier, mirrors, rounds=1, seed=72)
    tier.spill.spill(tier.handle(name))
    ss = tier.remove(name)
    assert ss.store_bytes() > 0            # resident again
    assert not ss._managed


# ---------------------------------------------------------------------------
# S3: budget enforcement — obsolete bytes first, then LRU spill
# ---------------------------------------------------------------------------

def test_budget_compacts_obsolete_bytes_first():
    tier = ServeTier(batch_refresh=False)
    mirrors = _fleet(tier, 2, "xla", seed=81)
    churned, quiet = list(mirrors)
    rng = np.random.default_rng(82)
    for _ in range(6):                     # churn -> obsolete store bytes
        loadgen.submit_update(tier, mirrors, churned, rng, 32)
        tier.drain(timeout=120)
    loadgen.submit_update(tier, mirrors, quiet, rng, 32)
    tier.drain(timeout=120)
    assert tier[churned].session.store_obsolete_bytes() > 0
    tier.store_budget_bytes = 1            # force enforcement
    tier._enforce_budget()
    stats = tier.stats()
    assert stats["reclaimed_bytes"][churned] > 0
    assert stats["classes"][churned]["reclaimed_bytes"] > 0
    # compaction alone cannot reach an impossible budget
    assert stats["over_budget"]


def test_budget_spills_lru_after_compaction(tmp_path):
    tier = ServeTier(spill_dir=tmp_path, store_budget_bytes=1)
    mirrors = _fleet(tier, 3, "xla", seed=91)
    _apply_rounds(tier, mirrors, rounds=1, seed=92)
    oldest = list(mirrors)[0]
    tier.handle(oldest).last_active = 0.0  # make LRU order deterministic
    tier._enforce_budget()
    assert all(h.spilled for h in tier.handles.values())
    assert tier.total_store_bytes() == 0
    snap = tier.stats()["spill"]
    assert snap["spills"] == 3 and snap["bytes_spilled"] > 0


# ---------------------------------------------------------------------------
# S4: tenant churn — add / remove / re-add
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_tenant_churn_add_remove_readd(backend, tmp_path):
    before = threading.active_count()
    tier = ServeTier(spill_dir=tmp_path)
    mirrors = _fleet(tier, 3, backend, seed=101)
    names = list(mirrors)
    with tier:
        _apply_rounds(tier, mirrors, rounds=1, seed=102)
        # spill one tenant, then remove it: the store must come back
        tier.spill.spill(tier.handle(names[0]))
        parked = tier.remove(names[0])
        assert parked.store_bytes() > 0
        rng = np.random.default_rng(103)
        loadgen.submit_update(tier, mirrors, names[1], rng, 32)
        tier.drain(timeout=120)
        # re-admit the parked session under the tier (idempotent start)
        tier.add(parked, slo=SLOClass.throughput())
        assert tier.handle(names[0]).slo.kind == "throughput"
        _apply_rounds(tier, mirrors, rounds=1, seed=104)
    # compare against a churn-free twin fed the same scripted updates
    twin = ServeTier()
    twin_mirrors = _fleet(twin, 3, backend, seed=101)
    _apply_rounds(twin, twin_mirrors, rounds=1, seed=102)
    rng = np.random.default_rng(103)
    loadgen.submit_update(twin, twin_mirrors, names[1], rng, 32)
    twin.drain(timeout=120)
    _apply_rounds(twin, twin_mirrors, rounds=1, seed=104)
    for n in names:
        np.testing.assert_array_equal(np.asarray(tier[n].result["c"]),
                                      np.asarray(twin[n].result["c"]))
    tier.stop()
    assert threading.active_count() == before          # no leaked threads
    with pytest.raises(ValueError, match="already registered"):
        tier.add(parked)


# ---------------------------------------------------------------------------
# MultiSessionServer shim
# ---------------------------------------------------------------------------

def test_multi_session_server_shim():
    from repro.stream import MultiSessionServer

    with pytest.warns(DeprecationWarning, match="repro.serve.ServeTier"):
        server = MultiSessionServer(store_budget_bytes=64 * 1024)
    assert isinstance(server, ServeTier)
    assert not server.batch_refresh        # old per-tenant refresh path
    docs = np.random.default_rng(111).integers(0, 32, (6, 4)).astype(np.int32)
    spec, data = wc.make_job(docs, 32)
    server.add(StreamSession(spec, data, name="legacy",
                             config=RunConfig(backend="xla", value_bytes=4),
                             stream=StreamConfig(max_batch_delay=0.0)))
    with server:
        new = docs.copy()
        new[0] = 7
        server.submit("legacy", np.array([0, 0], np.int32),
                      {"w": np.stack([docs[0], new[0]])},
                      np.array([-1, 1], np.int8))
        server.drain(timeout=120)
    stats = server.stats()
    for key in ("tenants", "total_store_bytes", "sweeps", "jit"):
        assert key in stats
    cold = Session(spec, RunConfig(backend="xla", value_bytes=4))
    cold.run(wc.make_input(np.arange(len(new)), new))
    np.testing.assert_array_equal(np.asarray(server["legacy"].result["c"]),
                                  np.asarray(cold.result["c"]))
