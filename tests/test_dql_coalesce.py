"""The coalescer two ways: the production first-'-'/last-'+' kernel vs the
same rule re-derived as a dql plan (two min-monoid group_bys joined on the
record id), edge cases the algebra rework exposed, and the telemetry path
that surfaces ``CoalesceResult`` counts into ``StreamMetrics``,
``RunReport.coalesce`` and the serving tier's ``stats()``."""
import numpy as np
import pytest

from tests._hyp import given, settings, st
from repro.api import RunConfig, StreamConfig
from repro.api.report import RunReport
from repro.apps import wordcount as wc
from repro.dql.derived import coalesce_plan, coalesce_rows_dql
from repro.stream import StreamSession
from repro.stream.coalesce import coalesce_rows
from repro.stream.metrics import StreamMetrics

BACKENDS = ("xla", "pallas")


def _assert_same_result(got, want):
    assert (got.n_in, got.n_out, got.n_records) == \
        (want.n_in, want.n_out, want.n_records)
    assert (got.n_inserts, got.n_deletes, got.n_cancelled) == \
        (want.n_inserts, want.n_deletes, want.n_cancelled)
    if want.delta is None:
        assert got.delta is None
        return
    np.testing.assert_array_equal(np.asarray(got.delta.record_ids),
                                  np.asarray(want.delta.record_ids))
    np.testing.assert_array_equal(np.asarray(got.delta.sign),
                                  np.asarray(want.delta.sign))
    for c in want.delta.values:
        np.testing.assert_array_equal(np.asarray(got.delta.values[c]),
                                      np.asarray(want.delta.values[c]))


# ---------------------------------------------------------------------------
# the re-derivation: dql plan == production kernel, bit for bit
# ---------------------------------------------------------------------------

def test_derived_plan_shape():
    plan = coalesce_plan(8)
    spec = plan.spec()
    # two min/sum group stages + the rid join
    assert [s.kind for s in spec.stages] == ["group", "group", "join"]
    assert spec.sources == ("rows",)


def test_derived_matches_production_canonical():
    # the canonical example of test_stream_coalesce.test_first_last_rules
    rid = np.array([3, 3, 5, 7, 7, 7, 7, 9, 9], np.int32)
    sg = np.array([-1, 1, 1, -1, 1, -1, 1, 1, -1], np.int8)
    vals = {"w": np.arange(9 * 2, dtype=np.int32).reshape(9, 2)}
    _assert_same_result(coalesce_rows_dql(rid, vals, sg),
                        coalesce_rows(rid, vals, sg))


@pytest.mark.parametrize("backend", BACKENDS)
@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10**6), st.integers(0, 24))
def test_derived_matches_production_random(backend, seed, n):
    rng = np.random.default_rng(seed)
    rid = rng.integers(0, 6, n).astype(np.int32)
    sg = rng.choice(np.array([-1, 1], np.int8), n)
    vals = {"w": rng.integers(0, 99, (n, 2)).astype(np.int32),
            "x": rng.integers(0, 99, n).astype(np.float32)}
    _assert_same_result(
        coalesce_rows_dql(rid, vals, sg, backend=backend),
        coalesce_rows(rid, vals, sg, backend=backend))


# ---------------------------------------------------------------------------
# edge cases (satellite of the algebra rework), production + derived
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", (coalesce_rows, coalesce_rows_dql))
def test_empty_batch_rows(impl):
    res = impl(np.zeros(0, np.int32), {"w": np.zeros((0, 2), np.int32)},
               np.zeros(0, np.int8))
    assert res.delta is None
    assert (res.n_in, res.n_out, res.n_records) == (0, 0, 0)
    assert res.n_cancelled == 0


@pytest.mark.parametrize("impl", (coalesce_rows, coalesce_rows_dql))
def test_all_rows_cancel(impl):
    # every record is created-and-destroyed inside the batch
    rid = np.repeat(np.arange(4, dtype=np.int32), 2)
    sg = np.tile(np.array([1, -1], np.int8), 4)
    res = impl(rid, {"w": np.arange(8, dtype=np.int32)}, sg)
    assert res.delta is None
    assert res.n_out == 0 and res.n_cancelled == 8
    assert res.n_records == 4
    assert res.n_inserts == 0 and res.n_deletes == 0


@pytest.mark.parametrize("impl", (coalesce_rows, coalesce_rows_dql))
def test_single_record_cap_regrow(impl):
    # 70 rows on one record crosses the 64-row capacity bucket: the sort
    # cap must regrow, and only the first '-' / last '+' may survive
    n = 70
    rid = np.full(n, 3, np.int32)
    sg = np.tile(np.array([-1, 1], np.int8), n // 2)
    vals = {"w": np.arange(n * 2, dtype=np.int32).reshape(n, 2)}
    res = impl(rid, vals, sg)
    assert (res.n_in, res.n_out, res.n_records) == (n, 2, 1)
    assert res.n_cancelled == n - 2
    np.testing.assert_array_equal(np.asarray(res.delta.sign), [-1, 1])
    np.testing.assert_array_equal(np.asarray(res.delta.values["w"]),
                                  vals["w"][[0, n - 1]])


@pytest.mark.parametrize("impl", (coalesce_rows, coalesce_rows_dql))
def test_duplicate_rids_within_one_sign(impl):
    # rid 5: '+','+','+'  -> last '+' wins (LWW);  rid 6: '-','-' -> first
    rid = np.array([5, 5, 5, 6, 6], np.int32)
    sg = np.array([1, 1, 1, -1, -1], np.int8)
    vals = {"w": np.arange(10, dtype=np.int32).reshape(5, 2)}
    res = impl(rid, vals, sg)
    assert (res.n_out, res.n_records) == (2, 2)
    assert (res.n_inserts, res.n_deletes, res.n_cancelled) == (1, 1, 3)
    np.testing.assert_array_equal(np.asarray(res.delta.record_ids), [5, 6])
    np.testing.assert_array_equal(np.asarray(res.delta.sign), [1, -1])
    np.testing.assert_array_equal(np.asarray(res.delta.values["w"]),
                                  [[4, 5], [6, 7]])


# ---------------------------------------------------------------------------
# telemetry: CoalesceResult counts reach metrics / reports / tier stats
# ---------------------------------------------------------------------------

def test_metrics_carry_coalesce_counters():
    m = StreamMetrics()
    m.observe_batch(n_in=6, n_engine=2, action="update", latency_s=0.01,
                    refresh_s=0.005, n_cancelled=4, n_inserts=1,
                    n_deletes=2)
    snap = m.snapshot()
    assert snap["rows_cancelled"] == 4
    assert snap["net_inserts"] == 1 and snap["net_deletes"] == 2


def test_report_coalesce_summary():
    rep = RunReport(name="x", mode="accumulator", epoch=1, backend="xla",
                    coalesce={"n_in": 6, "n_out": 2, "n_records": 1,
                              "n_inserts": 0, "n_deletes": 0,
                              "n_cancelled": 4})
    assert "coalesced=-4rows" in rep.summary()
    rep.coalesce = None
    assert "coalesced" not in rep.summary()


def test_stream_session_surfaces_coalesce():
    vocab = 16
    rng = np.random.default_rng(3)
    docs = rng.integers(0, vocab, (12, 3)).astype(np.int32)
    spec, data = wc.make_job(docs, vocab)
    ss = StreamSession(spec, data,
                       config=RunConfig(backend="xla", value_bytes=4),
                       stream=StreamConfig(max_batch_records=64,
                                           max_batch_delay=0.01))
    ss.start(background=False)
    # one batch: doc 2 rewritten three times -> 4 interior rows cancel
    cur = docs[2].copy()
    rids, bufs, sgs = [], [], []
    for _ in range(3):
        new = rng.integers(0, vocab, cur.shape).astype(np.int32)
        rids += [2, 2]
        bufs += [cur, new]
        sgs += [-1, 1]
        cur = new
    ss.submit(np.asarray(rids, np.int32), {"w": np.stack(bufs)},
              np.asarray(sgs, np.int8))
    ss.drain(timeout=60)

    rep = ss.session.history[-1]
    assert rep.coalesce == {"n_in": 6, "n_out": 2, "n_records": 1,
                            "n_inserts": 0, "n_deletes": 0, "n_cancelled": 4}
    assert "coalesced=-4rows" in rep.summary()
    snap = ss.metrics.snapshot()
    assert snap["rows_cancelled"] == 4
    assert snap["net_inserts"] == 0 and snap["net_deletes"] == 0
    docs[2] = cur
    np.testing.assert_array_equal(
        np.asarray(ss.session.result["c"]).ravel(), wc.oracle(docs, vocab))
    ss.stop()


def test_serve_tier_aggregates_coalesce():
    from repro.serve import ServeTier, loadgen
    tier = ServeTier(batch_refresh=False)
    mirrors = loadgen.make_fleet(tier, 2, backend="xla", seed=5, vocab=16,
                                 n_docs=6)
    rng = np.random.default_rng(7)
    for name, docs in mirrors.items():
        cur = docs[0].copy()
        rids, bufs, sgs = [], [], []
        for _ in range(2):                  # one interior pair cancels
            new = rng.integers(0, 16, cur.shape).astype(np.int32)
            rids += [0, 0]
            bufs += [cur, new]
            sgs += [-1, 1]
            cur = new
        docs[0] = cur
        tier.submit(name, np.asarray(rids, np.int32),
                    {"w": np.stack(bufs)}, np.asarray(sgs, np.int8))
    tier.drain(timeout=120)
    stats = tier.stats()
    assert stats["rows_cancelled"] == 2 * len(mirrors)
    assert stats["net_inserts"] == 0 and stats["net_deletes"] == 0
    per_tenant = sum(h.ss.metrics.snapshot()["rows_cancelled"]
                     for h in tier.handles.values())
    assert per_tenant == stats["rows_cancelled"]
    tier.stop()
